//! Shared helpers for the paper-reproduction benches.

use std::path::PathBuf;

use greenflow::workload::arrival::{arrival_times, ArrivalProcess};
use greenflow::workload::stream::{Request, RequestStream, StreamConfig};
use greenflow::util::Rng;

/// Iteration count: the paper's 100 per configuration, trimmable via
/// GF_ITERS for CI.
pub fn iters() -> usize {
    std::env::var("GF_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(100)
}

/// Repository root (artifacts/ relative to the crate).
pub fn repo_root() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    root.join("repository.json").exists().then_some(root)
}

/// Skip message when artifacts are missing.
pub fn require_artifacts() -> Option<PathBuf> {
    let r = repo_root();
    if r.is_none() {
        println!("SKIP: artifacts/ not built (run `make artifacts`)");
    }
    r
}

/// Deterministic calibrated trace at a Poisson rate.
pub fn trace(n: usize, rate: f64, seed: u64, model: &str) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut arr = ArrivalProcess::poisson(rate);
    let times = arrival_times(&mut arr, n, &mut rng);
    RequestStream::new(StreamConfig { model: model.to_string(), ..Default::default() }, seed ^ 1)
        .take(&times)
}

/// Write a CSV artifact under bench_data/.
pub fn write_csv(name: &str, content: &str) {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_data").join(name);
    greenflow::telemetry::export::write_file(&path, content).expect("write bench csv");
    println!("wrote bench_data/{name}");
}
