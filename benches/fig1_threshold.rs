//! **Fig. 1** — bio-inspired optimisation over states/model confidence:
//! τ(t) = τ∞ + (τ0 − τ∞)e^(−kt) decays while the controller admits points
//! in the local stable basin. This bench emits the τ(t) curves for a k
//! sweep, verifies Eq. 3 analytically, and traces the admit-rate-over-time
//! of the default controller on the calibrated stream (the "shaded basin
//! narrows as τ tightens" story).
//!
//! ```bash
//! cargo bench --bench fig1_threshold
//! ```

mod common;

use greenflow::benchkit::Table;
use greenflow::controller::cost::{CostInputs, WeightPolicy};
use greenflow::controller::threshold::ThresholdSchedule;
use greenflow::controller::{AdmissionController, AdmissionPolicy, ControllerConfig};
use greenflow::models;
use greenflow::sim::landscape::tau_curve;

fn main() {
    // ---- Eq. 3 curves for a k sweep -----------------------------------
    let mut csv = String::from("k,t,tau\n");
    let mut t = Table::new(
        "Fig. 1 analog — τ(t) = τ∞ + (τ0−τ∞)e^(−kt), τ0=0.2, τ∞=0.51",
        &["k", "τ(0)", "τ(1s)", "τ(2s)", "τ(5s)", "95% settle (s)"],
    );
    for k in [0.5, 1.0, 2.0, 4.0] {
        let s = ThresholdSchedule::Exponential { tau0: 0.2, tau_inf: 0.51, k };
        t.row(vec![
            format!("{k}"),
            format!("{:.3}", s.tau(0.0)),
            format!("{:.3}", s.tau(1.0)),
            format!("{:.3}", s.tau(2.0)),
            format!("{:.3}", s.tau(5.0)),
            format!("{:.2}", s.settle_time_95().unwrap()),
        ]);
        for (tt, tau) in tau_curve(&s, 6.0, 61) {
            csv.push_str(&format!("{k},{tt:.2},{tau:.5}\n"));
        }
        // analytic check of Eq. 3 at a few points
        for tt in [0.0, 0.7, 3.3] {
            let want = 0.51 + (0.2 - 0.51) * (-k * tt).exp();
            assert!((s.tau(tt) - want).abs() < 1e-12, "Eq. 3 violated");
        }
    }
    print!("{}", t.render());
    println!("Eq. 3 verified analytically at sampled points.\n");
    common::write_csv("fig1_tau_curves.csv", &csv);

    // ---- admit-rate over time on the calibrated stream ----------------
    let reqs = common::trace(4000, 200.0, 11, models::DISTILBERT);
    let mut ctrl = AdmissionController::new(ControllerConfig {
        weights: WeightPolicy::Balanced.weights(),
        schedule: ThresholdSchedule::paper_default(),
        respond_from_cache: true,
    });
    let max_ent = 2f64.ln();
    let window = 200usize;
    let mut admitted_in_window = 0usize;
    let mut rate_table = Table::new(
        "Admission rate vs time (window = 200 requests) — the basin narrowing",
        &["t (s)", "τ(t)", "admit rate %"],
    );
    let mut rate_csv = String::from("t,tau,admit_rate\n");
    for (i, r) in reqs.iter().enumerate() {
        // idle-system inputs: isolates the τ(t) dynamic from congestion
        let mut x = CostInputs::from_entropy(r.entropy(), max_ent);
        x.energy_ewma = 0.5;
        x.energy_ref = 1.0; // steady-state e_norm = 0.5, as in serving
        if ctrl.decide(&x, r.arrival).admitted() {
            admitted_in_window += 1;
        }
        if (i + 1) % window == 0 {
            let rate = admitted_in_window as f64 / window as f64;
            rate_table.row(vec![
                format!("{:.2}", r.arrival),
                format!("{:.3}", ctrl.tau_at(r.arrival)),
                format!("{:.0}", rate * 100.0),
            ]);
            rate_csv.push_str(&format!(
                "{:.3},{:.4},{:.3}\n",
                r.arrival,
                ctrl.tau_at(r.arrival),
                rate
            ));
            admitted_in_window = 0;
        }
    }
    print!("{}", rate_table.render());
    println!(
        "\nshape check: admit rate starts at 100% (permissive τ0) and narrows as τ → τ∞.\n\
         Under these idle-system inputs (C=1) it settles at the entropy-only cut (~85%);\n\
         in the full closed loop, energy + congestion feedback push it to the calibrated\n\
         58% steady state — see `cargo bench --bench table3_ablation`."
    );
    common::write_csv("fig1_admit_rate.csv", &rate_csv);
}
