//! **Fig. 3** — throughput (req/s) by model and framework, plus the
//! concurrency regime the paper's caption predicts: "under production
//! traffic with concurrency N ≫ 1, Triton's bars rise as dynamic
//! batching fuses requests".
//!
//! ```bash
//! cargo bench --bench fig3_throughput
//! ```

mod common;

use std::sync::Arc;

use greenflow::benchkit::Table;
use greenflow::models;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::router::PathKind;

fn throughput(system: &Arc<ServingSystem>, model: &str, path: PathKind, clients: usize, per_client: usize) -> f64 {
    // warmup
    for r in &common::trace(2, 1000.0, 1, model) {
        let _ = system.infer_on(r, path);
    }
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let system = system.clone();
            let model = model.to_string();
            s.spawn(move || {
                let reqs = common::trace(per_client, 1e6, 100 + c as u64, &model);
                for r in &reqs {
                    let _ = system.infer_on(r, path);
                }
            });
        }
    });
    (clients * per_client) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let Some(root) = common::require_artifacts() else { return };
    let system = Arc::new(ServingSystem::start(SystemConfig::new(root)).expect("boot"));
    let per_client = (common::iters() / 4).max(8);

    // ---- the figure's bars: batch=1, one client --------------------------
    let mut bars = Table::new(
        "Fig. 3 analog — throughput bars (req/s), 1 client, batch=1",
        &["Model", "direct (FastAPI)", "batched (Triton)"],
    );
    let mut csv = String::from("model,clients,direct_rps,batched_rps\n");
    for model in [models::DISTILBERT, models::RESNET] {
        let d = throughput(&system, model, PathKind::Direct, 1, per_client);
        let b = throughput(&system, model, PathKind::Batched, 1, per_client);
        bars.row(vec![model.into(), format!("{d:.1}"), format!("{b:.1}")]);
        csv.push_str(&format!("{model},1,{d:.2},{b:.2}\n"));
    }
    print!("{}", bars.render());
    println!(
        "paper expectation at batch=1: FastAPI dominates (79.9 vs 5.3 and 326.2 vs 17.0 req/s)\n"
    );

    // ---- the caption's prediction: batched bars rise with concurrency ----
    let mut sweep = Table::new(
        "Concurrency sweep — batched-path throughput rises as batching fuses requests",
        &["Model", "Clients", "direct (req/s)", "batched (req/s)", "batched gain vs 1-client"],
    );
    for model in [models::DISTILBERT, models::RESNET] {
        let base_b = throughput(&system, model, PathKind::Batched, 1, per_client);
        for clients in [1usize, 4, 8, 16] {
            let d = throughput(&system, model, PathKind::Direct, clients, per_client);
            let b = throughput(&system, model, PathKind::Batched, clients, per_client);
            sweep.row(vec![
                model.into(),
                clients.to_string(),
                format!("{d:.1}"),
                format!("{b:.1}"),
                format!("{:.2}x", b / base_b),
            ]);
            csv.push_str(&format!("{model},{clients},{d:.2},{b:.2}\n"));
        }
    }
    print!("{}", sweep.render());
    common::write_csv("fig3_throughput.csv", &csv);
}
