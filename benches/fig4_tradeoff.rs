//! **Fig. 4** — latency vs energy scatter; marker size encodes σ (we
//! export it as a CSV column). Each (model, path, concurrency) operating
//! point is one marker; the paper reads a Pareto frontier where the
//! direct path owns the low-latency region and the batched path buys
//! throughput-per-joule under load.
//!
//! ```bash
//! cargo bench --bench fig4_tradeoff
//! ```

mod common;

use std::sync::{Arc, Mutex};

use greenflow::benchkit::Table;
use greenflow::models;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::router::PathKind;
use greenflow::stats;

struct Point {
    model: &'static str,
    path: &'static str,
    clients: usize,
    mean_ms: f64,
    std_ms: f64,
    joules_per_req: f64,
    rps: f64,
}

fn main() {
    let Some(root) = common::require_artifacts() else { return };
    let system = Arc::new(ServingSystem::start(SystemConfig::new(root)).expect("boot"));
    let per_client = (common::iters() / 4).max(8);

    let mut points: Vec<Point> = Vec::new();
    for (model, mname) in [(models::DISTILBERT, "distilbert_mini"), (models::RESNET, "resnet_tiny")] {
        for (path, pname) in [(PathKind::Direct, "direct"), (PathKind::Batched, "batched")] {
            for clients in [1usize, 4, 8] {
                // warmup
                for r in &common::trace(2, 1000.0, 1, model) {
                    let _ = system.infer_on(r, path);
                }
                system.meter().reset();
                let lats: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
                let t0 = std::time::Instant::now();
                std::thread::scope(|s| {
                    for c in 0..clients {
                        let system = system.clone();
                        let lats = lats.clone();
                        let model = model.to_string();
                        s.spawn(move || {
                            let reqs = common::trace(per_client, 1e6, 50 + c as u64, &model);
                            for r in &reqs {
                                if let Ok(res) = system.infer_on(r, path) {
                                    lats.lock().unwrap().push(res.latency_secs);
                                }
                            }
                        });
                    }
                });
                let wall = t0.elapsed().as_secs_f64();
                let lats = lats.lock().unwrap();
                let (nreq, mean, std) =
                    (lats.len(), stats::mean(&lats), stats::std_dev(&lats));
                let joules = system.meter().total_joules() / nreq.max(1) as f64;
                points.push(Point {
                    model: mname,
                    path: pname,
                    clients,
                    mean_ms: mean * 1e3,
                    std_ms: std * 1e3,
                    joules_per_req: joules,
                    rps: nreq as f64 / wall,
                });
            }
        }
    }

    let mut t = Table::new(
        "Fig. 4 analog — latency vs energy per operating point",
        &["Model", "Path", "Clients", "Lat (ms)", "σ (ms)", "J/req", "req/s"],
    );
    let mut csv = String::from("model,path,clients,mean_ms,std_ms,joules_per_req,rps\n");
    for p in &points {
        t.row(vec![
            p.model.into(),
            p.path.into(),
            p.clients.to_string(),
            format!("{:.3}", p.mean_ms),
            format!("{:.3}", p.std_ms),
            format!("{:.5}", p.joules_per_req),
            format!("{:.1}", p.rps),
        ]);
        csv.push_str(&format!(
            "{},{},{},{:.4},{:.4},{:.6},{:.2}\n",
            p.model, p.path, p.clients, p.mean_ms, p.std_ms, p.joules_per_req, p.rps
        ));
    }
    print!("{}", t.render());

    // Pareto check: the lowest-latency point must be a direct point; the
    // best throughput-per-joule under concurrency should improve for the
    // batched path as clients rise.
    let min_lat = points.iter().min_by(|a, b| a.mean_ms.total_cmp(&b.mean_ms)).unwrap();
    println!(
        "\nlowest-latency corner: {} {} @{} clients ({:.3} ms) [{}]",
        min_lat.model,
        min_lat.path,
        min_lat.clients,
        min_lat.mean_ms,
        if min_lat.path == "direct" { "OK: direct owns the low-latency region" } else { "MISMATCH" }
    );
    for model in ["distilbert_mini", "resnet_tiny"] {
        let b1 = points.iter().find(|p| p.model == model && p.path == "batched" && p.clients == 1).unwrap();
        let b8 = points.iter().find(|p| p.model == model && p.path == "batched" && p.clients == 8).unwrap();
        println!(
            "{model}: batched throughput-per-joule {:.2} → {:.2} req/s/J as clients 1→8 [{}]",
            b1.rps / b1.joules_per_req.max(1e-12),
            b8.rps / b8.joules_per_req.max(1e-12),
            if b8.rps / b8.joules_per_req.max(1e-12) > b1.rps / b1.joules_per_req.max(1e-12) {
                "OK: batching buys throughput per joule"
            } else {
                "flat"
            }
        );
    }
    common::write_csv("fig4_tradeoff.csv", &csv);
}
