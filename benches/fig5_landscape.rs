//! **Fig. 5** — the bio-inspired energy landscape with decaying τ(t):
//! a stylised multi-basin cost surface, τ level-sets at several times,
//! and the admit regions they carve out (the controller "selects a local
//! stable basin and ignores the costly global minimum").
//!
//! ```bash
//! cargo bench --bench fig5_landscape
//! ```

mod common;

use greenflow::benchkit::Table;
use greenflow::controller::threshold::ThresholdSchedule;
use greenflow::sim::landscape::{basins_below, local_minima, sample_surface, tau_curve};

fn main() {
    let pts = sample_surface(801);

    // ---- the surface itself (CSV for the figure) ----------------------
    let mut csv = String::from("s,j\n");
    for p in &pts {
        csv.push_str(&format!("{:.5},{:.6}\n", p.s, p.j));
    }
    common::write_csv("fig5_surface.csv", &csv);

    // ---- basins ---------------------------------------------------------
    let minima = local_minima(&pts);
    let mut t = Table::new("Fig. 5 analog — basin structure", &["Basin floor s", "J(s)", "Role"]);
    let global_j = minima.iter().map(|p| p.j).fold(f64::INFINITY, f64::min);
    for m in &minima {
        t.row(vec![
            format!("{:.3}", m.s),
            format!("{:.4}", m.j),
            if (m.j - global_j).abs() < 1e-9 { "global minimum (costly to reach)".into() } else { "local stable basin (controller settles here)".into() },
        ]);
    }
    print!("{}", t.render());

    // ---- τ(t) level sets and the regions they admit ---------------------
    let schedule = ThresholdSchedule::paper_default();
    let mut levels = Table::new(
        "τ(t) level sets over the landscape (admit region = J(s) <= level)",
        &["t (s)", "τ(t)", "admit intervals on s", "basins disconnected?"],
    );
    let mut tau_csv = String::from("t,tau\n");
    for (tt, tau) in tau_curve(&schedule, 4.0, 9) {
        // In landscape units the admission level sweeps downward as τ
        // tightens: map normalised τ ∈ [τ0, τ∞] onto J levels so that the
        // permissive start clears the barrier (level 1.35 at τ0 = 0.2)
        // and the strict limit strands the controller inside a basin.
        let level = 1.35 - (tau - 0.2) * 2.5;
        let regions = basins_below(&pts, level);
        let pretty: Vec<String> =
            regions.iter().map(|(a, b)| format!("[{a:.2},{b:.2}]")).collect();
        levels.row(vec![
            format!("{tt:.2}"),
            format!("{tau:.3}"),
            pretty.join(" "),
            if regions.len() > 1 { "yes".into() } else { "no".into() },
        ]);
        tau_csv.push_str(&format!("{tt:.3},{tau:.5}\n"));
    }
    print!("\n{}", levels.render());
    common::write_csv("fig5_tau.csv", &tau_csv);

    println!(
        "\nshape check: early (permissive) levels admit one connected region spanning both basins;\n\
         late (strict) levels leave disconnected basins — the controller stays in the local one\n\
         instead of crossing the barrier to the global minimum. That is Fig. 5's admit region story."
    );
}
