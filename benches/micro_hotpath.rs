//! Micro-benchmarks of the L3 hot path (§Perf): per-component cost of
//! everything that sits between a request and its PJRT execution.
//!
//! Targets (DESIGN.md §7): controller decision < 1 µs; queue hop < 5 µs;
//! histogram record < 1 µs; coordinator overhead ≪ model execute time.
//!
//! ```bash
//! cargo bench --bench micro_hotpath            # human-readable report
//! cargo bench --bench micro_hotpath -- --json micro_hotpath.json
//! ```
//!
//! `--json PATH` additionally writes every case's mean/p50/p95 (ns) as
//! one JSON object — the per-component input `greenflow perfgate` embeds
//! into the CI `BENCH_*.json` artifact (docs/BENCH.md).

mod common;

use greenflow::batching::policy::BatcherPolicy;
use greenflow::batching::queue::PendingQueue;
use greenflow::benchkit::{bench_fn, BenchResult};
use greenflow::control::{Adaptive, RateWindow};
use greenflow::controller::cost::{CostInputs, WeightPolicy};
use greenflow::controller::threshold::ThresholdSchedule;
use greenflow::controller::{AdmissionController, AdmissionPolicy, ControllerConfig};
use greenflow::energy::meter::{EnergyMeter, MeterMode};
use greenflow::energy::DeviceProfile;
use greenflow::models::inputgen;
use greenflow::pipeline::direct::DirectPath;
use greenflow::runtime::engine::ExecMode;
use greenflow::runtime::ModelManifest;
use greenflow::server::{HttpRequest, RequestParser};
use greenflow::stats::LatencyHistogram;

fn report(results: &[BenchResult]) {
    for r in results {
        println!("{}", r.summary());
    }
}

/// Serialise every case as `{name: {mean_ns, p50_ns, p95_ns, iters}}`.
fn write_json(path: &str, results: &[BenchResult]) {
    use greenflow::json::{num, obj, Value};
    let cases: Vec<(&str, Value)> = results
        .iter()
        .map(|r| {
            (
                r.name.as_str(),
                obj(vec![
                    ("mean_ns", num(r.mean() * 1e9)),
                    ("p50_ns", num(r.p50() * 1e9)),
                    ("p95_ns", num(r.p95() * 1e9)),
                    ("iters", num(r.samples.len() as f64)),
                ]),
            )
        })
        .collect();
    let body = obj(vec![
        ("schema", greenflow::json::s("greenflow.micro-hotpath/1")),
        ("cases", obj(cases)),
    ]);
    match std::fs::write(path, body.to_json()) {
        Ok(()) => println!("micro_hotpath: wrote {path}"),
        Err(e) => {
            eprintln!("micro_hotpath: cannot write {path}: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    // `cargo bench --bench micro_hotpath -- --json PATH` (everything
    // after `--` reaches argv).
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let iters = 100_000;
    let mut results = Vec::new();

    // ---- controller decision -----------------------------------------
    let mut ctrl = AdmissionController::new(ControllerConfig {
        weights: WeightPolicy::Balanced.weights(),
        schedule: ThresholdSchedule::paper_default(),
        respond_from_cache: true,
    });
    let x = CostInputs::from_entropy(0.4, 2f64.ln());
    let mut t = 0.0;
    results.push(bench_fn("controller.decide", 1000, iters, || {
        t += 1e-6;
        let _ = ctrl.decide(&x, t);
    }));

    // ---- queue push + drain --------------------------------------------
    let q: PendingQueue<u64> = PendingQueue::new(1024);
    let policy = BatcherPolicy::immediate(8);
    results.push(bench_fn("queue.push+next_batch", 1000, iters / 10, || {
        q.push(1).unwrap();
        let _ = q.next_batch(&policy);
    }));

    // ---- latency histogram record --------------------------------------
    let mut h = LatencyHistogram::for_latency();
    results.push(bench_fn("histogram.record", 1000, iters, || {
        h.record(0.00123);
    }));
    results.push(bench_fn("histogram.p95", 100, 10_000, || {
        let _ = h.p95();
    }));

    // ---- Adaptive<T> read vs a plain field load -------------------------
    // The control plane's promise: consumers read adaptive knobs on the
    // hot path at (near) the cost of a plain load.
    let plain: f64 = 0.51;
    let adaptive = Adaptive::new(0.51f64);
    let mut acc = 0.0f64;
    results.push(bench_fn("plain_f64.load", 1000, iters, || {
        acc += std::hint::black_box(plain);
    }));
    results.push(bench_fn("adaptive_f64.get", 1000, iters, || {
        acc += std::hint::black_box(&adaptive).get();
    }));
    let adaptive_us = Adaptive::new(2000u64);
    let mut acc_u = 0u64;
    results.push(bench_fn("adaptive_u64.get", 1000, iters, || {
        acc_u += std::hint::black_box(&adaptive_us).get();
    }));
    std::hint::black_box((acc, acc_u));

    // ---- RateWindow record+rate (router hot path) -----------------------
    let mut rw = RateWindow::new(32);
    let mut t_rw = 0.0;
    results.push(bench_fn("rate_window.record+rate", 1000, iters, || {
        t_rw += 1e-4;
        rw.record(t_rw);
        let _ = std::hint::black_box(rw.rate());
    }));

    // ---- replica scheduler read (power-of-two-choices pick) -------------
    // The per-request cost the replica-set redesign adds to the serving
    // hot path: one ticket hash plus two load probes over the replica
    // set. Gated in CI as `sched_read_ns` (docs/BENCH.md).
    {
        use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
        let loads: Vec<AtomicUsize> = (0..4usize).map(AtomicUsize::new).collect();
        let ticket = AtomicU64::new(0);
        let mut acc_s = 0usize;
        results.push(bench_fn("sched.p2c_pick", 1000, iters, || {
            let t = ticket.fetch_add(1, Ordering::Relaxed);
            let (i, j) = greenflow::pipeline::p2c_indices(t, loads.len());
            let a = loads[i].load(Ordering::Relaxed);
            let b = loads[j].load(Ordering::Relaxed);
            acc_s += if b < a { b } else { a };
        }));
        std::hint::black_box(acc_s);
    }

    // ---- response-cache probe (sharded vs single-mutex) -----------------
    // The per-request cache consult the coalescing subsystem runs on
    // every submit: signature hash + shard pick + one shard-lock get.
    // The single-mutex row is the pre-shard baseline for comparison
    // (uncontended here; sharding pays off under concurrent load).
    // Gated in CI as `cache_read_ns` (docs/BENCH.md).
    {
        use greenflow::controller::cache::{CachedResponse, ResponseCache};
        use greenflow::pipeline::ShardedResponseCache;
        let sharded = ShardedResponseCache::new(4096);
        let single = std::sync::Mutex::new(ResponseCache::new(4096));
        for seed in 0..1024u64 {
            let sig = ResponseCache::signature("bench", 1, seed, 1024);
            let resp = CachedResponse { label: seed as u32, confidence: 0.9 };
            sharded.put(sig, resp);
            single.lock().unwrap().put(sig, resp);
        }
        let mut next = 0u64;
        let mut acc_c = 0u64;
        results.push(bench_fn("cache.sharded_get", 1000, iters, || {
            let sig = ResponseCache::signature("bench", 1, next, 1024);
            next = (next + 1) & 1023;
            if let Some(hit) = std::hint::black_box(&sharded).get(sig) {
                acc_c += hit.label as u64;
            }
        }));
        let mut next_m = 0u64;
        results.push(bench_fn("cache.mutex_get", 1000, iters, || {
            let sig = ResponseCache::signature("bench", 1, next_m, 1024);
            next_m = (next_m + 1) & 1023;
            if let Some(hit) = std::hint::black_box(&single).lock().unwrap().get(sig) {
                acc_c += hit.label as u64;
            }
        }));
        std::hint::black_box(acc_c);
    }

    // ---- QoS admission decide (per-request tenant gate) -----------------
    // The per-request cost the multi-tenant QoS layer adds in front of
    // the admission controller: one shard read-lock, one tenant-mutex
    // GCRA step, and the counter bumps. The quota is set far above the
    // bench rate so every decide admits — sheds leave the hot path by
    // definition. Gated in CI as `qos_decide_ns` (docs/BENCH.md).
    {
        use greenflow::qos::{QosConfig, QosLayer};
        let layer = QosLayer::new(QosConfig {
            default_rate_rps: 1_000_000_000,
            default_burst: 1_000_000,
            ..QosConfig::default()
        });
        let mut t_q = 0.0f64;
        results.push(bench_fn("qos.decide", 1000, iters, || {
            t_q += 1e-6;
            std::hint::black_box(layer.decide("bench", 1, 0, t_q));
        }));
    }

    // ---- energy meter record --------------------------------------------
    let meter = EnergyMeter::new(DeviceProfile::rtx4000_ada(), MeterMode::SimulatedFlops, 16.0);
    results.push(bench_fn("energy_meter.record", 1000, iters, || {
        let _ = meter.record(4.7e6, 0.0);
    }));

    // ---- input generation (payload synth on the request path) ----------
    results.push(bench_fn("inputgen.tokens(32)", 100, 20_000, || {
        let _ = inputgen::tokens_one(42, 32, 512);
    }));

    // ---- recycled HTTP parse (reactor per-request cost) -----------------
    // The incremental parser against warm per-connection buffers — the
    // work the reactor does per keep-alive request before the handler.
    // Steady state allocates nothing (tests/alloc_http_parse.rs gates
    // this), so the row measures pure scan + copy.
    let raw: &[u8] = b"POST /v2/models/distilbert_mini/infer HTTP/1.1\r\n\
        Host: 127.0.0.1:8000\r\n\
        Content-Type: application/json\r\n\
        X-Request-Id: corr-42\r\n\
        Content-Length: 11\r\n\
        \r\n\
        {\"seed\": 7}";
    let mut parser = RequestParser::new();
    let mut req = HttpRequest::default();
    results.push(bench_fn("http.parse_recycled", 1000, iters, || {
        req.reset();
        parser.reset();
        let n = parser.poll(raw, &mut req).unwrap().expect("complete");
        std::hint::black_box(n);
    }));

    report(&results);

    // ---- engine execute per model/bucket (needs artifacts) -------------
    let Some(root) = common::require_artifacts() else {
        if let Some(path) = &json_path {
            write_json(path, &results);
        }
        return;
    };
    println!();
    for mode in [ExecMode::Literals, ExecMode::DeviceBuffers] {
        let direct = DirectPath::start(
            vec![
                root.join("distilbert_mini"),
                root.join("resnet_tiny"),
                root.join("screener"),
            ],
            mode,
        )
        .expect("start");
        let mut engine_results = Vec::new();
        for model in ["screener", "distilbert_mini", "resnet_tiny"] {
            let man = ModelManifest::load(&root.join(model)).unwrap();
            for &bucket in &man.batch_buckets {
                let seeds: Vec<u64> = (0..bucket as u64).collect();
                let input = inputgen::batch_for(&man, &seeds, 0);
                let name = format!("{model}.b{bucket} [{mode:?}]");
                let direct = &direct;
                let model = model.to_string();
                engine_results.push(bench_fn(&name, 3, 15, || {
                    let _ = direct.infer(&model, input.clone()).unwrap();
                }));
            }
        }
        report(&engine_results);
        // per-item efficiency of batching
        println!();
        results.extend(engine_results);
    }
    if let Some(path) = &json_path {
        write_json(path, &results);
    }
}
