//! **Table II** — FastAPI vs Triton: latency, throughput, energy at
//! batch = 1, 100 iterations per configuration (paper §V/§VI-A).
//!
//! Reproduces the *shape*: the direct path (FastAPI+ORT analog) beats the
//! dynamic-batching path (Triton analog) by >10x latency at batch=1, with
//! the batched path carrying a visible per-request energy premium.
//!
//! ```bash
//! cargo bench --bench table2_dualpath          # 100 iters (paper)
//! GF_ITERS=20 cargo bench --bench table2_dualpath
//! ```

mod common;

use greenflow::benchkit::Table;
use greenflow::energy::CarbonAccountant;
use greenflow::models;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::router::PathKind;
use greenflow::stats;

/// Paper Table II rows for shape comparison (model, framework, ms, σ,
/// req/s, kWh, CO₂ kg).
const PAPER_ROWS: &[(&str, &str, f64, f64, f64, f64, f64)] = &[
    ("DistilBERT", "FastAPI", 125.21, 21.52, 79.9, 0.1972, 0.0986),
    ("DistilBERT", "Triton", 1876.29, 68.29, 5.3, 0.2637, 0.1318),
    ("ResNet-18", "FastAPI", 30.65, 0.73, 326.2, 0.2100, 0.1050),
    ("ResNet-18", "Triton", 589.14, 133.08, 17.0, 0.2198, 0.1099),
];

fn main() {
    let Some(root) = common::require_artifacts() else { return };
    let n = common::iters();
    let system = ServingSystem::start(SystemConfig::new(root)).expect("boot");
    let carbon = CarbonAccountant::paper();

    let mut table = Table::new(
        &format!("Table II analog — batch=1, {n} iterations (real PJRT, RTX4000Ada energy profile)"),
        &["Model", "Path", "Avg Lat (ms)", "σ (ms)", "Thru (req/s)", "Energy (kWh)", "CO2 (kg)"],
    );
    let mut csv = String::from("model,path,mean_ms,std_ms,throughput,kwh,co2\n");
    let mut measured: Vec<(&str, &str, f64)> = Vec::new();

    for (model, paper_name) in
        [(models::DISTILBERT, "DistilBERT"), (models::RESNET, "ResNet-18")]
    {
        for (path, frame) in
            [(PathKind::Direct, "direct (FastAPI)"), (PathKind::Batched, "batched (Triton)")]
        {
            let reqs = common::trace(n + 3, 1000.0, 42, model);
            // warmup (3) then timed (n)
            for r in &reqs[..3] {
                let _ = system.infer_on(r, path).unwrap();
            }
            system.meter().reset();
            let mut lats = Vec::with_capacity(n);
            for r in &reqs[3..] {
                let res = system.infer_on(r, path).unwrap();
                lats.push(res.latency_secs);
            }
            let mean_ms = stats::mean(&lats) * 1e3;
            let std_ms = stats::std_dev(&lats) * 1e3;
            let thru = 1e3 / mean_ms;
            let kwh = system.meter().total_kwh();
            let co2 = carbon.co2_for_kwh(kwh);
            table.row(vec![
                paper_name.to_string(),
                frame.to_string(),
                format!("{mean_ms:.3}"),
                format!("{std_ms:.3}"),
                format!("{thru:.1}"),
                format!("{kwh:.9}"),
                format!("{co2:.9}"),
            ]);
            csv.push_str(&format!(
                "{model},{frame},{mean_ms:.4},{std_ms:.4},{thru:.1},{kwh:.10},{co2:.10}\n"
            ));
            measured.push((paper_name, frame, mean_ms));
        }
    }
    print!("{}", table.render());

    // -------- paper rows + shape verdicts ------------------------------
    let mut paper = Table::new(
        "Paper Table II (RTX 4000 Ada testbed — absolute numbers are testbed-bound)",
        &["Model", "Framework", "Avg Lat (ms)", "σ (ms)", "Thru (req/s)", "Energy (kWh)", "CO2 (kg)"],
    );
    for r in PAPER_ROWS {
        paper.row(vec![
            r.0.into(),
            r.1.into(),
            format!("{:.2}", r.2),
            format!("{:.2}", r.3),
            format!("{:.1}", r.4),
            format!("{:.4}", r.5),
            format!("{:.4}", r.6),
        ]);
    }
    print!("\n{}", paper.render());

    let get = |m: &str, f: &str| -> f64 {
        measured.iter().find(|(mm, ff, _)| *mm == m && ff.starts_with(f)).unwrap().2
    };
    let bert_factor = get("DistilBERT", "batched") / get("DistilBERT", "direct");
    let resnet_factor = get("ResNet-18", "batched") / get("ResNet-18", "direct");
    println!("\nShape checks (paper → measured):");
    println!(
        "  DistilBERT direct-vs-batched latency factor: paper x15.0 → measured x{bert_factor:.1}  [{}]",
        if bert_factor > 3.0 { "OK: direct wins by a large factor" } else { "MISMATCH" }
    );
    println!(
        "  ResNet-18  direct-vs-batched latency factor: paper x19.2 → measured x{resnet_factor:.1}  [{}]",
        if resnet_factor > 3.0 { "OK: direct wins by a large factor" } else { "MISMATCH" }
    );
    common::write_csv("table2_dualpath.csv", &csv);
}
