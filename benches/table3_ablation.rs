//! **Table III** — ablation: Standard (open-loop) vs Bio-Controller on
//! DistilBERT @ A100 (paper §VI-E): total time, latency/request, SST-2
//! accuracy, admission rate. Plus the baselines (static τ, random drop,
//! oracle) and the §IV-A weight-policy sweep.
//!
//! The paper's run is 100 requests; we print both the paper-n run and a
//! 5000-request run where the percentages are stable.
//!
//! ```bash
//! cargo bench --bench table3_ablation
//! ```

mod common;

use greenflow::benchkit::Table;
use greenflow::controller::baselines::{OpenLoop, Oracle, RandomDrop, StaticThreshold};
use greenflow::controller::cost::WeightPolicy;
use greenflow::controller::threshold::ThresholdSchedule;
use greenflow::controller::{AdmissionController, ControllerConfig};
use greenflow::models;
use greenflow::sim::{simulate, SimConfig, SimReport};
use greenflow::util::fmt::pct_delta;

const PAPER: &[(&str, f64, f64)] = &[
    // (metric, standard, bio)
    ("Total Time (s)", 0.50, 0.29),
    ("Latency/Req (ms)", 5.0, 2.9),
    ("Accuracy (SST2) %", 91.0, 90.5),
    ("Admission Rate %", 100.0, 58.0),
];

fn bio() -> AdmissionController {
    AdmissionController::new(ControllerConfig {
        weights: WeightPolicy::Balanced.weights(),
        schedule: ThresholdSchedule::paper_default(),
        respond_from_cache: true,
    })
}

/// 100-request variant: the paper's short run only makes sense with τ
/// already settled (100 req at 200 req/s = 0.5 s of trace, while the
/// default k = 2 settles in 1.5 s), so the paper-n table uses k = 20 —
/// same τ0/τ∞, settled within the first 15% of the run.
fn bio_fast() -> AdmissionController {
    AdmissionController::new(ControllerConfig {
        weights: WeightPolicy::Balanced.weights(),
        schedule: ThresholdSchedule::Exponential { tau0: 0.2, tau_inf: 0.51, k: 20.0 },
        respond_from_cache: true,
    })
}

fn run_pair(n: usize, seed: u64, fast: bool) -> (SimReport, SimReport) {
    let reqs = common::trace(n, 200.0, seed, models::DISTILBERT);
    let cfg = SimConfig::table3_default();
    let std_rep = simulate(&mut OpenLoop, &reqs, &cfg);
    let mut ctrl = if fast { bio_fast() } else { bio() };
    let bio_rep = simulate(&mut ctrl, &reqs, &cfg);
    (std_rep, bio_rep)
}

fn print_table(title: &str, std_rep: &SimReport, bio_rep: &SimReport) {
    let mut t = Table::new(title, &["Metric", "Standard", "Bio-Controller", "Delta", "Paper"]);
    let rows: Vec<(&str, f64, f64, String)> = vec![
        (
            "Total Time (s)",
            std_rep.total_busy_secs,
            bio_rep.total_busy_secs,
            format!("{:.2} → {:.2} (-42.0%)", PAPER[0].1, PAPER[0].2),
        ),
        (
            "Latency/Req (ms)",
            std_rep.latency_per_req * 1e3,
            bio_rep.latency_per_req * 1e3,
            format!("{:.1} → {:.1} (-42.0%)", PAPER[1].1, PAPER[1].2),
        ),
        (
            "Accuracy %",
            std_rep.accuracy * 100.0,
            bio_rep.accuracy * 100.0,
            format!("{:.1} → {:.1} (-0.5 pp)", PAPER[2].1, PAPER[2].2),
        ),
        (
            "Admission Rate %",
            100.0,
            bio_rep.admission_rate() * 100.0,
            format!("{:.0} → {:.0}", PAPER[3].1, PAPER[3].2),
        ),
    ];
    for (name, a, b, paper) in rows {
        t.row(vec![
            name.into(),
            format!("{a:.3}"),
            format!("{b:.3}"),
            pct_delta(a, b),
            paper,
        ]);
    }
    print!("{}", t.render());
}

fn main() {
    // paper-n run (100 requests, like Table III; k=20 so τ is settled)
    let (s100, b100) = run_pair(100, 20260710, true);
    print_table("Table III analog — 100 requests (paper n)", &s100, &b100);

    // stable run
    let (s5k, b5k) = run_pair(5000, 7, false);
    println!();
    print_table("Table III analog — 5000 requests (stable)", &s5k, &b5k);

    let mut csv = String::from("n,policy,total_time_s,latency_ms,accuracy,admit_rate,kwh\n");
    for (n, s, b) in [(100usize, &s100, &b100), (5000, &s5k, &b5k)] {
        for rep in [s, b] {
            csv.push_str(&format!(
                "{n},{},{:.5},{:.4},{:.5},{:.4},{:.8}\n",
                rep.policy,
                rep.total_busy_secs,
                rep.latency_per_req * 1e3,
                rep.accuracy,
                rep.admission_rate(),
                rep.energy_kwh
            ));
        }
    }

    // ---- baselines at matched admission rate --------------------------
    let reqs = common::trace(5000, 200.0, 7, models::DISTILBERT);
    let cfg = SimConfig::table3_default();
    let rate = b5k.admission_rate();
    let mut base = Table::new(
        "Baselines — selectivity matters, not just shedding (5000 req)",
        &["Policy", "Admit %", "Busy (s)", "Accuracy %", "Acc loss vs open (pp)"],
    );
    let open = simulate(&mut OpenLoop, &reqs, &cfg);
    let mut rows: Vec<(String, SimReport)> = vec![
        ("bio-controller".into(), simulate(&mut bio(), &reqs, &cfg)),
        ("static-tau".into(), simulate(&mut StaticThreshold::new(0.51), &reqs, &cfg)),
        (format!("random@{:.0}%", rate * 100.0), simulate(&mut RandomDrop::new(rate, 3), &reqs, &cfg)),
        ("oracle".into(), simulate(&mut Oracle::new(0.35), &reqs, &cfg)),
    ];
    rows.insert(0, ("open-loop".into(), open.clone()));
    for (name, rep) in &rows {
        base.row(vec![
            name.clone(),
            format!("{:.0}", rep.admission_rate() * 100.0),
            format!("{:.3}", rep.total_busy_secs),
            format!("{:.2}", rep.accuracy * 100.0),
            format!("{:+.2}", (rep.accuracy - open.accuracy) * 100.0),
        ]);
        csv.push_str(&format!(
            "5000,{},{:.5},{:.4},{:.5},{:.4},{:.8}\n",
            name,
            rep.total_busy_secs,
            rep.latency_per_req * 1e3,
            rep.accuracy,
            rep.admission_rate(),
            rep.energy_kwh
        ));
    }
    print!("\n{}", base.render());

    // ---- weight-policy sweep (§IV-A knobs) -----------------------------
    let mut knobs = Table::new(
        "Weight-policy sweep (alpha, beta, gamma)",
        &["Policy", "alpha", "beta", "gamma", "Admit %", "Busy (s)", "kWh"],
    );
    for policy in [WeightPolicy::Balanced, WeightPolicy::Performance, WeightPolicy::Ecology] {
        let mut c = AdmissionController::new(ControllerConfig {
            weights: policy.weights(),
            schedule: ThresholdSchedule::paper_default(),
            respond_from_cache: true,
        });
        let rep = simulate(&mut c, &reqs, &cfg);
        let w = policy.weights();
        knobs.row(vec![
            format!("{policy:?}"),
            format!("{:.2}", w.alpha),
            format!("{:.2}", w.beta),
            format!("{:.2}", w.gamma),
            format!("{:.0}", rep.admission_rate() * 100.0),
            format!("{:.3}", rep.total_busy_secs),
            format!("{:.6}", rep.energy_kwh),
        ]);
    }
    print!("\n{}", knobs.render());

    // ---- τ-schedule ablation (decay vs static vs step) -----------------
    let mut sched = Table::new(
        "τ-schedule ablation — is the *decay* doing work?",
        &["Schedule", "Admit %", "Busy (s)", "Accuracy %"],
    );
    let schedules: Vec<(&str, ThresholdSchedule)> = vec![
        ("exponential (paper)", ThresholdSchedule::paper_default()),
        ("linear ramp", ThresholdSchedule::Linear { tau0: 0.2, tau_inf: 0.51, duration: 1.5 }),
        ("step @1.5s", ThresholdSchedule::Step { tau0: 0.2, tau_inf: 0.51, at: 1.5 }),
        ("constant strict", ThresholdSchedule::Constant { tau: 0.51 }),
    ];
    for (name, schedule) in schedules {
        let mut c = AdmissionController::new(ControllerConfig {
            weights: WeightPolicy::Balanced.weights(),
            schedule,
            respond_from_cache: true,
        });
        let rep = simulate(&mut c, &reqs, &cfg);
        sched.row(vec![
            name.into(),
            format!("{:.0}", rep.admission_rate() * 100.0),
            format!("{:.3}", rep.total_busy_secs),
            format!("{:.2}", rep.accuracy * 100.0),
        ]);
    }
    print!("\n{}", sched.render());
    common::write_csv("table3_ablation.csv", &csv);
}
