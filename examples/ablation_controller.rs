//! Table III analog — the closed-loop controller ablation, run two ways:
//!
//! 1. **Live**: real PJRT serving with the bio-controller in front
//!    (screener pre-pass, cache skip path), against open-loop serving of
//!    the same trace.
//! 2. **Sim**: the deterministic A100-profile simulation at larger n,
//!    including the static-τ / random-drop / oracle baselines.
//!
//! ```bash
//! cargo run --release --example ablation_controller
//! ```

use greenflow::benchkit::Table;
use greenflow::controller::baselines::{OpenLoop, Oracle, RandomDrop, StaticThreshold};
use greenflow::controller::cost::WeightPolicy;
use greenflow::controller::threshold::ThresholdSchedule;
use greenflow::controller::{AdaptiveTauPolicy, AdmissionController, ControllerConfig};
use greenflow::models;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::router::PathKind;
use greenflow::sim::{simulate, SimConfig};
use greenflow::util::fmt::pct_delta;
use greenflow::util::Rng;
use greenflow::workload::arrival::{arrival_times, ArrivalProcess};
use greenflow::workload::stream::{Request, RequestStream, StreamConfig};

fn bio_config() -> ControllerConfig {
    ControllerConfig {
        weights: WeightPolicy::Balanced.weights(),
        schedule: ThresholdSchedule::Exponential { tau0: 0.2, tau_inf: 0.51, k: 2.0 },
        respond_from_cache: true,
    }
}

fn trace(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut arr = ArrivalProcess::poisson(200.0);
    let times = arrival_times(&mut arr, n, &mut rng);
    RequestStream::new(StreamConfig::default(), seed ^ 1).take(&times)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---------------- live run ----------------------------------------
    let n_live = std::env::var("GF_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
    let repo = std::env::var("GF_REPO").unwrap_or_else(|_| "artifacts".to_string());
    let reqs = trace(n_live, 42);

    let open_sys = ServingSystem::start(SystemConfig::new(repo.clone().into()))?;
    let mut open_busy = 0.0;
    for r in &reqs {
        let res = open_sys.infer_on(r, PathKind::Direct)?;
        open_busy += res.latency_secs;
    }
    let open_kwh = open_sys.meter().total_kwh();

    let bio_sys = ServingSystem::start(
        SystemConfig::new(repo.into()).with_controller(bio_config()),
    )?;
    let mut bio_busy = 0.0;
    for r in &reqs {
        let res = bio_sys.submit(r, PathKind::Direct)?;
        bio_busy += res.latency_secs;
    }
    let bio_kwh = bio_sys.meter().total_kwh();
    let stats = bio_sys.controller_stats().unwrap();

    let mut live = Table::new(
        &format!("Live ablation — DistilBERT, direct path, {n_live} requests (real PJRT)"),
        &["Metric", "Standard", "Bio-Controller", "Delta"],
    );
    live.row(vec![
        "Total Time (s)".into(),
        format!("{open_busy:.3}"),
        format!("{bio_busy:.3}"),
        pct_delta(open_busy, bio_busy),
    ]);
    live.row(vec![
        "Latency/Req (ms)".into(),
        format!("{:.2}", open_busy / n_live as f64 * 1e3),
        format!("{:.2}", bio_busy / n_live as f64 * 1e3),
        pct_delta(open_busy, bio_busy),
    ]);
    live.row(vec![
        "Energy (kWh)".into(),
        format!("{open_kwh:.8}"),
        format!("{bio_kwh:.8}"),
        pct_delta(open_kwh, bio_kwh),
    ]);
    live.row(vec![
        "Admission Rate".into(),
        "100%".into(),
        format!("{:.0}%", stats.admission_rate() * 100.0),
        pct_delta(1.0, stats.admission_rate()),
    ]);
    print!("{}", live.render());

    // ---------------- sim sweep ---------------------------------------
    let reqs = trace(5000, 7);
    let cfg = SimConfig::table3_default();
    let open = simulate(&mut OpenLoop, &reqs, &cfg);
    let mut policies: Vec<(String, greenflow::sim::SimReport)> = vec![];
    let mut bio = AdmissionController::new(bio_config());
    let bio_rep = simulate(&mut bio, &reqs, &cfg);
    let rate = bio_rep.admission_rate();
    policies.push(("bio-controller".into(), bio_rep));
    // Adaptive-τ: the control-plane servo targeting the bio row's realised
    // admission rate — the fixed decay schedule vs its closed-loop twin.
    let mut adaptive = AdaptiveTauPolicy::new(bio_config(), rate, 0.05, 25);
    policies.push((
        format!("adaptive-τ@{:.0}%", rate * 100.0),
        simulate(&mut adaptive, &reqs, &cfg),
    ));
    policies.push(("static-τ".into(), simulate(&mut StaticThreshold::new(0.51), &reqs, &cfg)));
    policies.push((
        format!("random@{:.0}%", rate * 100.0),
        simulate(&mut RandomDrop::new(rate, 3), &reqs, &cfg),
    ));
    policies.push(("oracle".into(), simulate(&mut Oracle::new(0.35), &reqs, &cfg)));

    let mut simt = Table::new(
        "Sim ablation — 5000 requests, A100 profile",
        &["Policy", "Admit %", "Busy (s)", "Δtime", "Accuracy", "Δacc (pp)", "kWh"],
    );
    simt.row(vec![
        "open-loop".into(),
        "100".into(),
        format!("{:.3}", open.total_busy_secs),
        "—".into(),
        format!("{:.2}%", open.accuracy * 100.0),
        "—".into(),
        format!("{:.6}", open.energy_kwh),
    ]);
    for (name, rep) in &policies {
        simt.row(vec![
            name.clone(),
            format!("{:.0}", rep.admission_rate() * 100.0),
            format!("{:.3}", rep.total_busy_secs),
            pct_delta(open.total_busy_secs, rep.total_busy_secs),
            format!("{:.2}%", rep.accuracy * 100.0),
            format!("{:+.2}", (rep.accuracy - open.accuracy) * 100.0),
            format!("{:.6}", rep.energy_kwh),
        ]);
    }
    print!("\n{}", simt.render());

    // ---------------- weight-policy knobs (§IV-A) ----------------------
    let mut knobs = Table::new(
        "Weight policies (α, β, γ) — §IV-A knobs",
        &["Policy", "α", "β", "γ", "Admit %", "Busy (s)", "kWh"],
    );
    for policy in [WeightPolicy::Balanced, WeightPolicy::Performance, WeightPolicy::Ecology] {
        let mut c = AdmissionController::new(ControllerConfig {
            weights: policy.weights(),
            ..bio_config()
        });
        let rep = simulate(&mut c, &reqs, &cfg);
        let w = policy.weights();
        knobs.row(vec![
            format!("{policy:?}"),
            format!("{:.2}", w.alpha),
            format!("{:.2}", w.beta),
            format!("{:.2}", w.gamma),
            format!("{:.0}", rep.admission_rate() * 100.0),
            format!("{:.3}", rep.total_busy_secs),
            format!("{:.6}", rep.energy_kwh),
        ]);
    }
    print!("\n{}", knobs.render());
    Ok(())
}
