//! End-to-end driver (DESIGN.md §5): serve a real workload through both
//! paths with real compiled models, reporting Table II-shaped rows
//! (latency mean/σ, throughput, energy kWh, CO₂) and a concurrency sweep
//! showing where the batched path overtakes the direct one.
//!
//! ```bash
//! make artifacts && cargo run --release --example dualpath_serving
//! # fewer iterations: GF_ITERS=20 cargo run --release --example dualpath_serving
//! ```

use std::sync::Arc;

use greenflow::benchkit::Table;
use greenflow::energy::CarbonAccountant;
use greenflow::models;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::router::PathKind;
use greenflow::stats;
use greenflow::telemetry::Tracker;
use greenflow::workload::stream::{RequestStream, StreamConfig};

fn iters() -> usize {
    std::env::var("GF_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(100)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let repo = std::env::var("GF_REPO").unwrap_or_else(|_| "artifacts".to_string());
    let system = Arc::new(ServingSystem::start(SystemConfig::new(repo.into()))?);
    let tracker = Tracker::new();
    let n = iters();
    let carbon = CarbonAccountant::paper();

    // ---------------- Table II: batch=1 sequential, 100 iterations ----
    let mut table = Table::new(
        "Table II analog — dual-path serving, batch=1 (real PJRT execution)",
        &["Model", "Path", "Avg Lat (ms)", "σ (ms)", "Thru (req/s)", "Energy (kWh)", "CO2 (kg)"],
    );

    for model in [models::DISTILBERT, models::RESNET] {
        for path in [PathKind::Direct, PathKind::Batched] {
            system.meter().reset();
            let run = tracker.start_run(&format!("{model}-{}", path.as_str()));
            run.log_param("model", model);
            run.log_param("path", path.as_str());
            run.log_param("iterations", n);

            let mut stream = RequestStream::new(
                StreamConfig { model: model.to_string(), ..Default::default() },
                7,
            );
            let mut lats = Vec::with_capacity(n);
            for i in 0..n {
                let req = stream.next_request(i as f64);
                let r = system.infer_on(&req, path)?;
                lats.push(r.latency_secs);
                run.log_metric("latency_ms", i as u64, req.arrival, r.latency_secs * 1e3);
            }
            let mean_ms = stats::mean(&lats) * 1e3;
            let std_ms = stats::std_dev(&lats) * 1e3;
            let thru = 1e3 / mean_ms;
            let kwh = system.meter().total_kwh();
            run.log_metric("energy_kwh", n as u64, 0.0, kwh);
            table.row(vec![
                model.to_string(),
                path.as_str().to_string(),
                format!("{mean_ms:.2}"),
                format!("{std_ms:.2}"),
                format!("{thru:.1}"),
                format!("{kwh:.8}"),
                format!("{:.8}", carbon.co2_for_kwh(kwh)),
            ]);
        }
    }
    print!("{}", table.render());

    // ---------------- concurrency sweep (Fig. 3 expectation) ----------
    let mut sweep = Table::new(
        "Concurrency sweep — throughput (req/s) by path",
        &["Model", "Clients", "Direct", "Batched", "Batched/Direct"],
    );
    for model in [models::DISTILBERT, models::RESNET] {
        for clients in [1usize, 4, 8] {
            let mut thru = [0.0f64; 2];
            for (pi, path) in [PathKind::Direct, PathKind::Batched].into_iter().enumerate() {
                let per_client = (n / 4).max(5);
                let t0 = std::time::Instant::now();
                std::thread::scope(|s| {
                    for c in 0..clients {
                        let system = system.clone();
                        let model = model.to_string();
                        s.spawn(move || {
                            let mut stream = RequestStream::new(
                                StreamConfig { model, ..Default::default() },
                                100 + c as u64,
                            );
                            for i in 0..per_client {
                                let req = stream.next_request(i as f64);
                                let _ = system.infer_on(&req, path);
                            }
                        });
                    }
                });
                let total = (clients * per_client) as f64;
                thru[pi] = total / t0.elapsed().as_secs_f64();
            }
            sweep.row(vec![
                model.to_string(),
                clients.to_string(),
                format!("{:.1}", thru[0]),
                format!("{:.1}", thru[1]),
                format!("{:.2}x", thru[1] / thru[0]),
            ]);
        }
    }
    print!("\n{}", sweep.render());

    // ---------------- audit trail (MLflow analog, §X) ------------------
    let snaps: Vec<_> = tracker.runs().iter().map(|r| r.snapshot()).collect();
    let out = std::path::Path::new("bench_data");
    greenflow::telemetry::export::write_file(
        &out.join("dualpath_metrics.csv"),
        &greenflow::telemetry::export::metrics_csv(&snaps),
    )?;
    greenflow::telemetry::export::write_file(
        &out.join("dualpath_runs.json"),
        &greenflow::telemetry::export::runs_json(&snaps),
    )?;
    println!("\naudit trail: bench_data/dualpath_metrics.csv, bench_data/dualpath_runs.json");
    Ok(())
}
