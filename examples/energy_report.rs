//! Energy & carbon report (the CodeCarbon/MLflow §X audit): run the same
//! workload, attribute it on every device profile, convert kWh → CO₂ per
//! grid region, and print an NVML-style power trace summary.
//!
//! ```bash
//! cargo run --release --example energy_report
//! ```

use greenflow::benchkit::Table;
use greenflow::energy::carbon::{CarbonAccountant, REGIONS};
use greenflow::energy::profile::DeviceProfile;
use greenflow::energy::sampler::PowerSampler;

fn main() {
    // Workload: 1000 distilbert_mini requests + 1000 resnet_tiny requests.
    let bert_flops = 4.72e6;
    let resnet_flops = 53.3e6;
    let n = 1000.0;

    let devices = [
        DeviceProfile::rtx4000_ada(),
        DeviceProfile::a100(),
        DeviceProfile::rtx4090(),
        DeviceProfile::cpu_epyc(),
    ];

    let mut t = Table::new(
        "Energy attribution per device profile (1000+1000 requests)",
        &["Device", "Bert J/req", "ResNet J/req", "Total kWh", "kWh @ batch8 (fused)"],
    );
    for d in &devices {
        let bj = d.exec_energy(bert_flops);
        let rj = d.exec_energy(resnet_flops);
        let total_j = n * bj + n * rj;
        // Fused batches keep utilization high for 1/8 the per-item wall
        // time slots; energy is flops-bound, so the win is idle removal:
        let fused_j = total_j; // compute joules identical...
        let idle_saved = d.idle_watts * (n * d.exec_time(bert_flops) * 7.0 / 8.0);
        let _ = fused_j;
        t.row(vec![
            d.name.to_string(),
            format!("{bj:.4}"),
            format!("{rj:.4}"),
            format!("{:.6}", greenflow::energy::joules_to_kwh(total_j)),
            format!("{:.6}", greenflow::energy::joules_to_kwh(total_j - idle_saved).max(0.0)),
        ]);
    }
    print!("{}", t.render());

    let mut c = Table::new(
        "CO₂ by grid region (for the RTX 4000 Ada total)",
        &["Region", "kg CO₂ / kWh", "kg CO₂ for workload"],
    );
    let d = DeviceProfile::rtx4000_ada();
    let kwh = greenflow::energy::joules_to_kwh(n * d.exec_energy(bert_flops) + n * d.exec_energy(resnet_flops));
    for r in REGIONS {
        let acc = CarbonAccountant::new(r.kg_co2_per_kwh);
        c.row(vec![
            r.region.to_string(),
            format!("{:.3}", r.kg_co2_per_kwh),
            format!("{:.8}", acc.co2_for_kwh(kwh)),
        ]);
    }
    print!("\n{}", c.render());

    // NVML-style sampled power trace for a bursty minute.
    let mut sampler = PowerSampler::new(DeviceProfile::rtx4000_ada(), 0.1, 2.0, 42);
    let mut t_now = 0.0;
    for burst in 0..6 {
        let start = burst as f64 * 10.0;
        sampler.report_busy(start, 4.0); // 4 s busy, 6 s idle
        t_now = start + 10.0;
    }
    sampler.advance_to(t_now);
    let samples = sampler.samples();
    let max_w = samples.iter().map(|s| s.watts).fold(0.0, f64::max);
    let min_w = samples.iter().map(|s| s.watts).fold(f64::INFINITY, f64::min);
    println!(
        "\nNVML-style trace: {} samples over {:.0} s, {:.1}–{:.1} W, integral {:.1} J ({:.8} kWh)",
        samples.len(),
        t_now,
        min_w,
        max_w,
        sampler.integrated_joules(),
        greenflow::energy::joules_to_kwh(sampler.integrated_joules()),
    );
}
