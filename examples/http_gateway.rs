//! HTTP gateway demo: boot the closed-loop system behind the FastAPI-analog
//! REST layer, then act as its own client over real TCP.
//!
//! ```bash
//! cargo run --release --example http_gateway
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use greenflow::controller::cost::WeightPolicy;
use greenflow::controller::threshold::ThresholdSchedule;
use greenflow::controller::ControllerConfig;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::server::Gateway;

fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let repo = std::env::var("GF_REPO").unwrap_or_else(|_| "artifacts".to_string());
    let cfg = SystemConfig::new(repo.into()).with_controller(ControllerConfig {
        weights: WeightPolicy::Balanced.weights(),
        schedule: ThresholdSchedule::paper_default(),
        respond_from_cache: true,
    });
    let system = Arc::new(ServingSystem::start(cfg)?);
    let gw = Gateway::start(system, 0, 4)?; // ephemeral port
    let addr = gw.addr();
    println!("gateway up at http://{addr}\n");

    println!("GET /health\n{}\n", get(addr, "/health").lines().last().unwrap_or(""));
    println!("GET /models\n{}\n", get(addr, "/models").lines().last().unwrap_or(""));

    for seed in [1u64, 2, 3, 4] {
        let body = format!("{{\"model\": \"distilbert_mini\", \"seed\": {seed}}}");
        let resp = post(addr, "/infer", &body);
        println!("POST /infer seed={seed}\n{}\n", resp.lines().last().unwrap_or(""));
    }

    println!("GET /metrics\n{}", get(addr, "/metrics").lines().skip(7).collect::<Vec<_>>().join("\n"));
    Ok(())
}
