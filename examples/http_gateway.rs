//! HTTP gateway demo: boot the closed-loop system behind the v2
//! inference protocol, then act as its own client over one real TCP
//! keep-alive connection.
//!
//! ```bash
//! cargo run --release --example http_gateway
//! ```

use std::sync::Arc;

use greenflow::controller::cost::WeightPolicy;
use greenflow::controller::threshold::ThresholdSchedule;
use greenflow::controller::ControllerConfig;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::server::{Gateway, HttpClient};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let repo = std::env::var("GF_REPO").unwrap_or_else(|_| "artifacts".to_string());
    let cfg = SystemConfig::new(repo.into()).with_controller(ControllerConfig {
        weights: WeightPolicy::Balanced.weights(),
        schedule: ThresholdSchedule::paper_default(),
        respond_from_cache: true,
    });
    let system = Arc::new(ServingSystem::start(cfg)?);
    let gw = Gateway::start(system, 0, 4)?; // ephemeral port
    println!("gateway up at http://{}\n", gw.addr());

    // Every request below rides the same keep-alive connection.
    let mut client = HttpClient::connect(gw.addr())?;

    for path in ["/v2/health/ready", "/v2/models", "/v2/models/distilbert_mini"] {
        let resp = client.get(path)?;
        println!("GET {path} -> {}\n{}\n", resp.status, resp.body_str().unwrap_or(""));
    }

    // One batch infer: four items, one response, outputs in order.
    let body = r#"{"inputs": [{"seed": 1}, {"seed": 2}, {"seed": 3}, {"seed": 4}],
                   "parameters": {"path": "auto", "timeout_ms": 5000}}"#;
    let resp = client.post_json("/v2/models/distilbert_mini/infer", body)?;
    println!(
        "POST /v2/models/distilbert_mini/infer -> {}\n{}\n",
        resp.status,
        resp.body_str().unwrap_or("")
    );

    // Legacy shim, still on the same socket.
    let resp =
        client.post_json("/infer", r#"{"model": "distilbert_mini", "seed": 9}"#)?;
    println!(
        "POST /infer (legacy shim) -> {}\n{}\n",
        resp.status,
        resp.body_str().unwrap_or("")
    );

    let resp = client.get("/v2/admission/stats")?;
    println!("GET /v2/admission/stats\n{}\n", resp.body_str().unwrap_or(""));

    let resp = client.get("/metrics")?;
    println!(
        "GET /metrics (gateway lines)\n{}",
        resp.body_str()
            .unwrap_or("")
            .lines()
            .filter(|l| l.contains("gf_http") || l.contains("gf_gateway"))
            .collect::<Vec<_>>()
            .join("\n")
    );
    Ok(())
}
