//! Quickstart: boot the serving system from the AOT repository and run a
//! few requests down both serving paths.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use greenflow::models;
use greenflow::pipeline::system::{ServingSystem, SystemConfig};
use greenflow::router::PathKind;
use greenflow::workload::stream::{RequestStream, StreamConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let repo = std::env::var("GF_REPO").unwrap_or_else(|_| "artifacts".to_string());
    println!("booting greenflow from {repo}/ ...");
    let system = ServingSystem::start(SystemConfig::new(repo.into()))?;
    println!("loaded models: {:?}", system.model_names());

    let mut stream = RequestStream::new(
        StreamConfig { model: models::DISTILBERT.to_string(), ..Default::default() },
        42,
    );

    println!("\n--- Path A (direct, FastAPI+ORT analog) ---");
    for i in 0..3 {
        let req = stream.next_request(i as f64 * 0.1);
        let r = system.infer_on(&req, PathKind::Direct)?;
        println!(
            "req {}: class={} conf={:.3} entropy={:.3} latency={:.2} ms energy={:.4} J",
            r.request_id,
            r.predicted,
            r.confidence,
            r.entropy,
            r.latency_secs * 1e3,
            r.joules
        );
    }

    println!("\n--- Path B (dynamic batching, Triton analog) ---");
    for i in 0..3 {
        let req = stream.next_request(1.0 + i as f64 * 0.1);
        let r = system.infer_on(&req, PathKind::Batched)?;
        println!(
            "req {}: class={} conf={:.3} latency={:.2} ms (bucket {})",
            r.request_id,
            r.predicted,
            r.confidence,
            r.latency_secs * 1e3,
            r.bucket
        );
    }

    println!(
        "\ntotals: {:.4} kWh attributed on {} profile, p95 latency {:.2} ms",
        system.meter().total_kwh(),
        system.meter().profile().name,
        system.p95() * 1e3
    );
    Ok(())
}
