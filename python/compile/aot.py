"""AOT export pipeline: JAX models -> Rust-loadable model repository.

``python -m compile.aot --out-dir ../artifacts`` produces, per model, a
Triton-style repository entry (DESIGN.md §2: this *is* our model-repo
substrate):

    artifacts/<model>/
        manifest.json     parameter table (name/shape/offset), input spec,
                          batch buckets, analytic + XLA-cost-analysis FLOPs
        weights.bin       all parameters, f32 little-endian, manifest order
        config.pbtxt      Triton-style serving config (parsed by rust
                          configsys; max_batch_size / dynamic_batching /
                          instance_group)
        model.b<K>.hlo.txt  HLO text per batch bucket K

Python runs only here — never on the request path.  The Rust runtime
(rust/src/runtime) loads these artifacts, pre-transfers weights to PJRT
device buffers, and serves.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .hlo import lower_to_hlo_text, xla_flops_estimate

SEED = 20260710
BUCKETS = (1, 2, 4, 8)
SCREENER_BUCKETS = (1, 4)


def _write_weights(path: str, params) -> list:
    """Flat f32 LE blob + the manifest parameter table."""
    table = []
    offset = 0
    with open(path, "wb") as f:
        for name, arr in params.items():
            a = np.asarray(arr, dtype=np.float32)
            f.write(a.tobytes())  # C-order, little-endian on all our targets
            table.append(
                {
                    "name": name,
                    "shape": list(a.shape),
                    "offset": offset,
                    "numel": int(a.size),
                }
            )
            offset += a.size * 4
    return table


def _config_pbtxt(name: str, max_batch: int, in_name: str, in_dtype: str,
                  in_dims, classes: int, preferred, delay_us: int) -> str:
    pref = ", ".join(str(p) for p in preferred)
    dims = ", ".join(str(d) for d in in_dims)
    return f"""name: "{name}"
platform: "greenflow_pjrt"
max_batch_size: {max_batch}
input [
  {{
    name: "{in_name}"
    data_type: {in_dtype}
    dims: [ {dims} ]
  }}
]
output [
  {{
    name: "logits"
    data_type: TYPE_FP32
    dims: [ {classes} ]
  }}
  {{
    name: "probs"
    data_type: TYPE_FP32
    dims: [ {classes} ]
  }}
  {{
    name: "entropy"
    data_type: TYPE_FP32
    dims: [ 1 ]
  }}
]
dynamic_batching {{
  preferred_batch_size: [ {pref} ]
  max_queue_delay_microseconds: {delay_us}
}}
instance_group [
  {{
    count: 1
    kind: KIND_CPU
  }}
]
"""


def _export_model(out_dir: str, name: str, params, apply_fn, input_spec_fn,
                  buckets, flops_fn, meta: dict, verbose: bool = True,
                  delay_us: int = 2000):
    mdir = os.path.join(out_dir, name)
    os.makedirs(mdir, exist_ok=True)
    table = _write_weights(os.path.join(mdir, "weights.bin"), params)

    weight_specs = [
        jax.ShapeDtypeStruct(tuple(t["shape"]), jnp.float32) for t in table
    ]
    names = [t["name"] for t in table]

    def fn(*args):
        ws = dict(zip(names, args[:-1]))
        return apply_fn(ws, args[-1])

    hlo_files, flops, flops_xla = {}, {}, {}
    for b in buckets:
        spec = input_spec_fn(b)
        text = lower_to_hlo_text(fn, *weight_specs, spec)
        fname = f"model.b{b}.hlo.txt"
        with open(os.path.join(mdir, fname), "w") as f:
            f.write(text)
        hlo_files[str(b)] = fname
        flops[str(b)] = flops_fn(b)
        flops_xla[str(b)] = xla_flops_estimate(fn, *weight_specs, spec)
        if verbose:
            print(
                f"  {name} b{b}: hlo {len(text) / 1e3:.0f} kB, "
                f"flops {flops[str(b)] / 1e6:.2f} M (xla {flops_xla[str(b)] / 1e6:.2f} M)"
            )

    manifest = {
        "name": name,
        "schema_version": 1,
        "seed": SEED,
        "outputs": ["logits", "probs", "entropy"],
        "batch_buckets": list(buckets),
        "weights_file": "weights.bin",
        "hlo_files": hlo_files,
        "flops_per_batch": flops,
        "flops_xla_per_batch": flops_xla,
        "params": table,
        **meta,
    }
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    cfg = _config_pbtxt(
        name,
        max_batch=max(buckets),
        in_name=meta["input"]["name"],
        in_dtype="TYPE_INT32" if meta["input"]["dtype"] == "i32" else "TYPE_FP32",
        in_dims=meta["input"]["shape_per_item"],
        classes=meta["classes"],
        preferred=[b for b in buckets if b > 1] or [1],
        delay_us=delay_us,
    )
    with open(os.path.join(mdir, "config.pbtxt"), "w") as f:
        f.write(cfg)
    return manifest


def export_all(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    key = jax.random.PRNGKey(SEED)
    kb, kr, ks = jax.random.split(key, 3)

    cfgs = []
    print("exporting distilbert_mini ...") if verbose else None
    cfgs.append(
        _export_model(
            out_dir,
            "distilbert_mini",
            M.init_distilbert(kb),
            M.distilbert_apply,
            lambda b: jax.ShapeDtypeStruct((b, M.BERT.seq), jnp.int32),
            BUCKETS,
            M.flops_distilbert,
            {
                "family": "transformer",
                "classes": M.BERT.classes,
                "input": {
                    "name": "tokens",
                    "kind": "tokens",
                    "shape_per_item": [M.BERT.seq],
                    "dtype": "i32",
                    "vocab": M.BERT.vocab,
                },
            },
            verbose,
        )
    )
    print("exporting resnet_tiny ...") if verbose else None
    cfgs.append(
        _export_model(
            out_dir,
            "resnet_tiny",
            M.init_resnet(kr),
            M.resnet_apply,
            lambda b: jax.ShapeDtypeStruct(
                (b, M.RESNET.image, M.RESNET.image, M.RESNET.in_ch), jnp.float32
            ),
            BUCKETS,
            M.flops_resnet,
            {
                "family": "cnn",
                "classes": M.RESNET.classes,
                "input": {
                    "name": "image",
                    "kind": "image",
                    "shape_per_item": [M.RESNET.image, M.RESNET.image, M.RESNET.in_ch],
                    "dtype": "f32",
                },
            },
            verbose,
            # The paper's §V "dynamic batching windows tuned": Triton's
            # batch=1 latency rows are dominated by the scheduler wait, so
            # the vision model carries a production-sized window (the
            # language model keeps a tight 2 ms window).
            delay_us=120000,
        )
    )
    print("exporting screener ...") if verbose else None
    cfgs.append(
        _export_model(
            out_dir,
            "screener",
            M.init_screener(ks),
            M.screener_apply,
            lambda b: jax.ShapeDtypeStruct((b, M.SCREENER.seq), jnp.int32),
            SCREENER_BUCKETS,
            M.flops_screener,
            {
                "family": "screener",
                "classes": M.SCREENER.classes,
                "input": {
                    "name": "tokens",
                    "kind": "tokens",
                    "shape_per_item": [M.SCREENER.seq],
                    "dtype": "i32",
                    "vocab": M.SCREENER.vocab,
                },
            },
            verbose,
        )
    )

    index = {
        "schema_version": 1,
        "models": [c["name"] for c in cfgs],
        "seed": SEED,
    }
    with open(os.path.join(out_dir, "repository.json"), "w") as f:
        json.dump(index, f, indent=1)
    return index


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    idx = export_all(args.out_dir, verbose=not args.quiet)
    print(f"wrote repository with models: {idx['models']} -> {args.out_dir}")


if __name__ == "__main__":
    main()
