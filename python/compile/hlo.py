"""Lowering helper: jitted jax function -> HLO *text*.

HLO text (not a serialized HloModuleProto) is the interchange format with
the Rust runtime: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, *arg_specs) -> str:
    """Lower ``jax.jit(fn)`` at the given ShapeDtypeStructs to HLO text.

    Lowered with ``return_tuple=True``: the Rust side unwraps the single
    tuple output with ``to_tuple()``.
    """
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def xla_flops_estimate(fn, *arg_specs) -> float:
    """FLOPs from XLA's cost analysis of the compiled module.

    Falls back to -1.0 when the backend does not expose cost analysis;
    callers then use the analytic estimates from ``model.py``.
    """
    try:
        compiled = jax.jit(fn).lower(*arg_specs).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return float(ca.get("flops", -1.0))
    except Exception:
        return -1.0
