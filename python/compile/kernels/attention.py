"""Fused scaled-dot-product attention Pallas kernel.

The paper serves DistilBERT; its hot-spot is multi-head attention.  The GPU
framing (one threadblock per (batch, head), scores staged in shared memory)
maps to TPU as: one grid instance per (batch, head), the (S, Dh) Q/K/V
panels and the (S, S) score tile resident in VMEM, QK^T and PV hitting the
MXU.  For the mini serving model S=32, Dh=16, so one instance holds
3*S*Dh + S*S = 2.5 K floats — far under the VMEM budget; the BlockSpec
schedule is what would scale to real sizes by tiling S.

Softmax inside the kernel reuses the stabilised formulation of
``softmax_entropy`` (max-shift, exp, normalise) without the entropy tap —
attention probabilities are internal and never surface to the controller.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0, 0]  # (S, Dh)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Fused attention over (B, H, S, Dh): softmax(QK^T / sqrt(Dh)) V."""
    b, h, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    spec = pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
