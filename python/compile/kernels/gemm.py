"""Tiled GEMM Pallas kernel — the compute hot-spot of both served models.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's serving
stack keeps GPU SMs busy by fusing requests into batches; on TPU the same
insight becomes "feed the MXU full tiles".  The kernel therefore blocks the
(M, K) x (K, N) product into (bm, bn) output tiles — MXU-shaped multiples of
(8, 128) when the problem is big enough, shrinking to the problem size for
the tiny serving models — and expresses the HBM<->VMEM schedule with
BlockSpecs where a CUDA kernel would use threadblocks + shared memory.

Two variants:

* ``gemm``          — 2-D grid over output tiles; each kernel instance reads a
                      full (bm, K) row-panel and (K, bn) column-panel.  VMEM
                      per instance: bm*K + K*bn + bm*bn floats.
* ``gemm_kblocked`` — 3-D grid that also tiles K and accumulates into the
                      revisited output block (zero-init at k==0).  Lower VMEM
                      footprint (bm*bk + bk*bn + bm*bn) for large K; this is
                      the double-buffer-friendly schedule a real TPU would
                      pipeline.

Both run under ``interpret=True`` (the CPU PJRT client cannot execute Mosaic
custom-calls) and are validated against ``ref.gemm``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_block(dim: int, target: int) -> int:
    """Largest block <= target that keeps the padded grid small.

    Prefers MXU-friendly sizes when dim is large; degrades to the full
    (padded) dimension for the tiny matrices of the mini serving models so
    the grid stays 1 and interpret-mode lowering emits a single body.
    """
    if dim <= target:
        return max(1, dim)
    for cand in (target, target // 2, target // 4):
        if cand and dim % cand == 0:
            return cand
    return target


def _gemm_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128, bn: int = 128
         ) -> jnp.ndarray:
    """Tiled matmul: (M, K) @ (K, N) -> (M, N), f32.

    Pads every dimension up to the tile grid, runs the Pallas kernel over a
    2-D output-tile grid, and slices the result back.  Padding with zeros is
    exact for matmul (zero rows/cols contribute nothing).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), k
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, 0)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    out = pl.pallas_call(
        _gemm_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def _gemm_kblocked_kernel(x_ref, y_ref, o_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_kblocked(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128,
                  bn: int = 128, bk: int = 128) -> jnp.ndarray:
    """K-tiled matmul with output-block accumulation across the k grid dim.

    The output BlockSpec index map ignores the k grid axis, so consecutive k
    steps revisit the same VMEM tile — the canonical TPU accumulation
    schedule (and what a CUDA kernel does with a register-tile + smem loop).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_gemm_kblocked_kernel, nk=nk),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def vmem_footprint_bytes(m: int, n: int, k: int, *, bm: int = 128,
                         bn: int = 128, bk: int | None = None) -> int:
    """Estimated VMEM bytes held live by one kernel instance (f32).

    Used by DESIGN.md §Perf to check the schedule against the ~16 MiB/core
    VMEM budget of a real TPU, since interpret-mode wallclock is not a TPU
    proxy.
    """
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    kk = pick_block(k, bk) if bk is not None else k
    return 4 * (bm * kk + kk * bn + bm * bn)
