"""Fused LayerNorm Pallas kernel (mean/var/normalise/affine in one pass).

Used by the distilbert_mini encoder after attention and FFN sublayers.
One grid instance normalises a (block_rows, D) tile: a single VMEM-resident
read computes both moments and the affine output, where an unfused lowering
would make three passes over HBM (mean, var, normalise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    o_ref[...] = xc * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, *,
              eps: float = 1e-5, block_rows: int = 128) -> jnp.ndarray:
    """Row LayerNorm over the last dim of (R, D) with affine (gamma, beta)."""
    r, d = x.shape
    br = min(block_rows, r)
    rp = (r + br - 1) // br * br
    xp = jnp.pad(x.astype(jnp.float32), ((0, rp - r), (0, 0)))
    g2 = gamma.astype(jnp.float32).reshape(1, d)
    b2 = beta.astype(jnp.float32).reshape(1, d)
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((rp, d), jnp.float32),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        interpret=True,
    )(xp, g2, b2)
    return out[:r]
