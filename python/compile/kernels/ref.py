"""Pure-jnp reference oracles for every Pallas kernel (Layer 1).

These are the ground truth the kernels are validated against (pytest +
hypothesis sweeps in ``python/tests/``). They are also what the kernels
lower to *semantically*: any divergence beyond float tolerance is a bug
in the kernel, never in the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul oracle: (M, K) @ (K, N) -> (M, N) in f32."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def softmax(logits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Numerically-stable softmax."""
    m = jnp.max(logits, axis=axis, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def softmax_entropy(logits: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused oracle: row softmax plus Shannon entropy (nats) of each row.

    Entropy is the paper's L(x) uncertainty proxy (Sec. IV, "Notes on
    proxies"): H(p) = -sum_i p_i log p_i, computed from the same
    numerically-stabilised probabilities the serving path returns.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    e = jnp.exp(z)
    s = jnp.sum(e, axis=-1, keepdims=True)
    p = e / s
    # H = log(sum e) - sum(e*z)/sum(e); avoids log(p) on p ~ 0.
    ent = jnp.log(s[..., 0]) - jnp.sum(e * z, axis=-1) / s[..., 0]
    return p, ent


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    """Row LayerNorm with affine: rows of x are normalised over the last dim."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Scaled dot-product attention oracle over (B, H, S, Dh) tensors."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    p = softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
