"""Fused softmax + Shannon-entropy Pallas kernel.

This kernel is where Layer 1 meets the paper's controller: Sec. IV uses
softmax entropy as the L(x) uncertainty proxy of the admission functional
J(x) = a*L + b*E + g*C.  Fusing the entropy reduction into the same pass
that produces the class probabilities means the serving path gets the
admission signal for free — one HBM read of the logits, one write of the
probabilities, and a (rows,)-shaped entropy vector that the Rust
coordinator feeds straight into the closed loop.

Numerics: the kernel never forms log(p).  With z = logits - max and
s = sum(exp z), entropy is computed as  H = log(s) - sum(exp(z) * z) / s,
which is exact algebra on the stabilised quantities and has no 0*log(0)
hazard for saturated rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_entropy_kernel(logits_ref, probs_ref, ent_ref):
    z = logits_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    z = z - m
    e = jnp.exp(z)
    s = jnp.sum(e, axis=-1, keepdims=True)
    probs_ref[...] = e / s
    ent_ref[...] = jnp.log(s) - jnp.sum(e * z, axis=-1, keepdims=True) / s


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax_entropy(logits: jnp.ndarray, *, block_rows: int = 128
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(R, C) logits -> ((R, C) probs, (R,) entropy in nats).

    Rows are tiled over a 1-D grid; each instance holds one (block_rows, C)
    logits tile plus its outputs in VMEM.  Row padding uses zeros, which
    produce a harmless uniform row that is sliced away.
    """
    r, c = logits.shape
    br = min(block_rows, r)
    rp = (r + br - 1) // br * br
    lp = jnp.pad(logits.astype(jnp.float32), ((0, rp - r), (0, 0)))
    probs, ent = pl.pallas_call(
        _softmax_entropy_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((rp, c), jnp.float32),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ),
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ),
        interpret=True,
    )(lp)
    return probs[:r], ent[:r, 0]
