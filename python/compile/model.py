"""Layer 2 — JAX serving models, built on the Layer-1 Pallas kernels.

Three models mirror the paper's serving zoo, scaled so a CPU PJRT client
can execute hundreds of benchmark iterations (DESIGN.md §2 substitutions):

* ``distilbert_mini`` — transformer encoder classifier (the DistilBERT
  analog): token embedding + learned positions, N encoder layers
  (fused-attention + GEMM FFN + fused LayerNorm), mean-pool head.
* ``resnet_tiny``     — residual CNN (the ResNet-18 analog): conv stem +
  3 stages x 2 basic blocks, every convolution lowered as im2col + the
  Pallas GEMM kernel, global-average-pool head.
* ``screener``        — a ~1%-cost confidence proxy (embedding mean +
  linear head).  The controller needs L(x) *before* paying for the full
  model; the screener is the cheap pre-pass that estimates it (the
  early-exit trick the paper's "respond from cache" line implies).

Every apply function returns ``(logits, probs, entropy)`` — probabilities
and the entropy L(x) proxy come from the fused softmax_entropy kernel, so
the admission signal costs nothing extra at serve time.

Parameters are ordered dicts; ``param_order`` fixes the flattening order
shared with ``weights.bin`` and the Rust runtime (manifest.json contract).
"""

from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.gemm import gemm
from .kernels.layernorm import layernorm
from .kernels.softmax_entropy import softmax_entropy


# --------------------------------------------------------------------------
# configs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BertConfig:
    """distilbert_mini hyper-parameters (DistilBERT scaled for CPU PJRT)."""
    vocab: int = 512
    seq: int = 32
    d_model: int = 64
    heads: int = 4
    d_ff: int = 128
    layers: int = 2
    classes: int = 2

    @property
    def d_head(self) -> int:
        return self.d_model // self.heads


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    """resnet_tiny hyper-parameters (ResNet-18 scaled: 3 stages x 2 blocks)."""
    image: int = 32
    in_ch: int = 3
    widths: tuple = (16, 32, 64)
    blocks_per_stage: int = 2
    classes: int = 10


@dataclasses.dataclass(frozen=True)
class ScreenerConfig:
    vocab: int = 512
    seq: int = 32
    d_embed: int = 16
    classes: int = 2


BERT = BertConfig()
RESNET = ResNetConfig()
SCREENER = ScreenerConfig()


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_distilbert(key, cfg: BertConfig = BERT) -> "OrderedDict[str, jnp.ndarray]":
    p = OrderedDict()
    keys = iter(jax.random.split(key, 64))
    p["embed"] = _dense_init(next(keys), (cfg.vocab, cfg.d_model), 0.02)
    p["pos"] = _dense_init(next(keys), (cfg.seq, cfg.d_model), 0.02)
    for i in range(cfg.layers):
        pre = f"l{i}."
        for nm in ("wq", "wk", "wv", "wo"):
            p[pre + nm] = _dense_init(next(keys), (cfg.d_model, cfg.d_model))
        p[pre + "ln1.g"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[pre + "ln1.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p[pre + "w1"] = _dense_init(next(keys), (cfg.d_model, cfg.d_ff))
        p[pre + "b1"] = jnp.zeros((cfg.d_ff,), jnp.float32)
        p[pre + "w2"] = _dense_init(next(keys), (cfg.d_ff, cfg.d_model))
        p[pre + "b2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p[pre + "ln2.g"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[pre + "ln2.b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["head.w"] = _dense_init(next(keys), (cfg.d_model, cfg.classes))
    p["head.b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return p


def init_resnet(key, cfg: ResNetConfig = RESNET) -> "OrderedDict[str, jnp.ndarray]":
    p = OrderedDict()
    keys = iter(jax.random.split(key, 128))

    def conv_init(k, kh, kw, cin, cout):
        fan_in = kh * kw * cin
        return jax.random.normal(k, (kh, kw, cin, cout), jnp.float32) * math.sqrt(
            2.0 / fan_in
        )

    p["stem.w"] = conv_init(next(keys), 3, 3, cfg.in_ch, cfg.widths[0])
    p["stem.g"] = jnp.ones((cfg.widths[0],), jnp.float32)
    p["stem.b"] = jnp.zeros((cfg.widths[0],), jnp.float32)
    cin = cfg.widths[0]
    for si, w in enumerate(cfg.widths):
        for bi in range(cfg.blocks_per_stage):
            pre = f"s{si}.b{bi}."
            stride_block = si > 0 and bi == 0
            p[pre + "c1.w"] = conv_init(next(keys), 3, 3, cin, w)
            p[pre + "c1.g"] = jnp.ones((w,), jnp.float32)
            p[pre + "c1.b"] = jnp.zeros((w,), jnp.float32)
            p[pre + "c2.w"] = conv_init(next(keys), 3, 3, w, w)
            p[pre + "c2.g"] = jnp.ones((w,), jnp.float32)
            p[pre + "c2.b"] = jnp.zeros((w,), jnp.float32)
            if stride_block or cin != w:
                p[pre + "sc.w"] = conv_init(next(keys), 1, 1, cin, w)
            cin = w
    p["head.w"] = _dense_init(next(keys), (cfg.widths[-1], cfg.classes))
    p["head.b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return p


def init_screener(key, cfg: ScreenerConfig = SCREENER) -> "OrderedDict[str, jnp.ndarray]":
    k1, k2 = jax.random.split(key)
    p = OrderedDict()
    p["embed"] = _dense_init(k1, (cfg.vocab, cfg.d_embed), 0.05)
    p["head.w"] = _dense_init(k2, (cfg.d_embed, cfg.classes))
    p["head.b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return p


def param_order(params: "OrderedDict[str, jnp.ndarray]") -> list:
    """Flattening order shared by aot.py (weights.bin) and the Rust runtime."""
    return list(params.keys())


# --------------------------------------------------------------------------
# distilbert_mini
# --------------------------------------------------------------------------

def _dense(x2d, w, b=None):
    y = gemm(x2d, w)
    return y if b is None else y + b


def distilbert_apply(params, token_ids, cfg: BertConfig = BERT):
    """(B, S) int32 token ids -> (logits (B,C), probs (B,C), entropy (B,))."""
    b, s = token_ids.shape
    x = params["embed"][token_ids] + params["pos"][None, :s, :]
    for i in range(cfg.layers):
        pre = f"l{i}."
        x2 = x.reshape(b * s, cfg.d_model)
        q = _dense(x2, params[pre + "wq"]).reshape(b, s, cfg.heads, cfg.d_head)
        k = _dense(x2, params[pre + "wk"]).reshape(b, s, cfg.heads, cfg.d_head)
        v = _dense(x2, params[pre + "wv"]).reshape(b, s, cfg.heads, cfg.d_head)
        # (B, H, S, Dh) for the fused attention kernel
        q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        o = attention(q, k, v).transpose(0, 2, 1, 3).reshape(b * s, cfg.d_model)
        o = _dense(o, params[pre + "wo"])
        x2 = x2 + o
        x2 = layernorm(x2, params[pre + "ln1.g"], params[pre + "ln1.b"])
        h = jax.nn.gelu(_dense(x2, params[pre + "w1"], params[pre + "b1"]))
        h = _dense(h, params[pre + "w2"], params[pre + "b2"])
        x2 = layernorm(x2 + h, params[pre + "ln2.g"], params[pre + "ln2.b"])
        x = x2.reshape(b, s, cfg.d_model)
    pooled = jnp.mean(x, axis=1)  # mean-pool (CLS-free mini head)
    logits = _dense(pooled, params["head.w"], params["head.b"])
    probs, ent = softmax_entropy(logits)
    return logits, probs, ent


# --------------------------------------------------------------------------
# resnet_tiny
# --------------------------------------------------------------------------

def _conv2d(x, w, stride=1):
    """NHWC conv via im2col + the Pallas GEMM kernel.

    ``conv_general_dilated_patches`` extracts (kh*kw*cin)-patches; the
    contraction then runs through the same MXU-tiled GEMM the transformer
    uses — one kernel to optimise, both models benefit.
    """
    n, h, ww, cin = x.shape
    kh, kw, _, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, Ho, Wo, cin*kh*kw)
    ho, wo = patches.shape[1], patches.shape[2]
    cols = patches.reshape(n * ho * wo, cin * kh * kw)
    # patches order is (cin, kh, kw); reorder the filter to match.
    wmat = w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    # Perf iteration (EXPERIMENTS.md §Perf L1): conv GEMMs have huge M
    # (N*Ho*Wo) and tiny K/N; bm=1024 amortises the per-grid-step overhead
    # of the lowered kernel loop (3.0x on resnet_tiny b8) while the worst
    # tile (bm*K + K*bn + bm*bn at K=576, bn=128) stays ~3.1 MB — well
    # inside the 16 MiB/core VMEM budget.
    y = gemm(cols, wmat, bm=1024)
    return y.reshape(n, ho, wo, cout)


def _scale_bias(x, g, b):
    """Inference-mode 'batchnorm': folded per-channel affine."""
    return x * g + b


def resnet_apply(params, images, cfg: ResNetConfig = RESNET):
    """(B, H, W, C) f32 images -> (logits, probs, entropy)."""
    x = _conv2d(images, params["stem.w"])
    x = jax.nn.relu(_scale_bias(x, params["stem.g"], params["stem.b"]))
    cin = cfg.widths[0]
    for si, w in enumerate(cfg.widths):
        for bi in range(cfg.blocks_per_stage):
            pre = f"s{si}.b{bi}."
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _conv2d(x, params[pre + "c1.w"], stride)
            h = jax.nn.relu(_scale_bias(h, params[pre + "c1.g"], params[pre + "c1.b"]))
            h = _conv2d(h, params[pre + "c2.w"])
            h = _scale_bias(h, params[pre + "c2.g"], params[pre + "c2.b"])
            if pre + "sc.w" in params:
                x = _conv2d(x, params[pre + "sc.w"], stride)
            x = jax.nn.relu(x + h)
            cin = w
    pooled = jnp.mean(x, axis=(1, 2))
    logits = _dense(pooled, params["head.w"], params["head.b"])
    probs, ent = softmax_entropy(logits)
    return logits, probs, ent


# --------------------------------------------------------------------------
# screener
# --------------------------------------------------------------------------

def screener_apply(params, token_ids, cfg: ScreenerConfig = SCREENER):
    """Cheap L(x) estimator: embedding mean + linear head."""
    emb = params["embed"][token_ids]  # (B, S, E)
    pooled = jnp.mean(emb, axis=1)
    logits = _dense(pooled, params["head.w"], params["head.b"])
    probs, ent = softmax_entropy(logits)
    return logits, probs, ent


# --------------------------------------------------------------------------
# analytic FLOPs (drive the energy power model in rust; DESIGN.md §2)
# --------------------------------------------------------------------------

def flops_distilbert(batch: int, cfg: BertConfig = BERT) -> int:
    s, d, f = cfg.seq, cfg.d_model, cfg.d_ff
    per_layer = (
        4 * 2 * s * d * d          # qkv + out projections
        + 2 * 2 * s * s * d        # QK^T and PV
        + 2 * 2 * s * d * f        # FFN
    )
    head = 2 * d * cfg.classes
    return batch * (cfg.layers * per_layer + head)


def flops_resnet(batch: int, cfg: ResNetConfig = RESNET) -> int:
    total = 0
    hw = cfg.image
    cin = cfg.in_ch
    total += 2 * hw * hw * 9 * cin * cfg.widths[0]
    cin = cfg.widths[0]
    for si, w in enumerate(cfg.widths):
        for bi in range(cfg.blocks_per_stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            hw = hw // stride
            total += 2 * hw * hw * 9 * cin * w
            total += 2 * hw * hw * 9 * w * w
            if stride > 1 or cin != w:
                total += 2 * hw * hw * cin * w
            cin = w
    total += 2 * cfg.widths[-1] * cfg.classes
    return batch * total


def flops_screener(batch: int, cfg: ScreenerConfig = SCREENER) -> int:
    return batch * (cfg.seq * cfg.d_embed + 2 * cfg.d_embed * cfg.classes)
