"""AOT export contract tests: manifest/weights/HLO artifacts the Rust
runtime depends on.  Exports the screener (cheap) to a tmpdir and checks
the full contract; the repo-level artifacts are exercised end-to-end by
`cargo test`."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.hlo import lower_to_hlo_text


@pytest.fixture(scope="module")
def screener_export(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    params = M.init_screener(jax.random.PRNGKey(aot.SEED))
    man = aot._export_model(
        str(out),
        "screener",
        params,
        M.screener_apply,
        lambda b: jax.ShapeDtypeStruct((b, M.SCREENER.seq), jnp.int32),
        (1, 4),
        M.flops_screener,
        {
            "family": "screener",
            "classes": M.SCREENER.classes,
            "input": {
                "name": "tokens",
                "kind": "tokens",
                "shape_per_item": [M.SCREENER.seq],
                "dtype": "i32",
                "vocab": M.SCREENER.vocab,
            },
        },
        verbose=False,
    )
    return out / "screener", man, params


def test_manifest_schema(screener_export):
    mdir, man, _ = screener_export
    disk = json.loads((mdir / "manifest.json").read_text())
    assert disk["name"] == "screener"
    assert disk["batch_buckets"] == [1, 4]
    assert disk["outputs"] == ["logits", "probs", "entropy"]
    assert [p["name"] for p in disk["params"]] == ["embed", "head.w", "head.b"]
    for b in ("1", "4"):
        assert disk["hlo_files"][b] == f"model.b{b}.hlo.txt"
        assert (mdir / disk["hlo_files"][b]).exists()


def test_weights_bin_layout(screener_export):
    """weights.bin is the params flattened f32-LE in manifest order."""
    mdir, man, params = screener_export
    blob = (mdir / "weights.bin").read_bytes()
    total = sum(int(np.asarray(p).size) for p in params.values())
    assert len(blob) == total * 4
    off = 0
    for entry, (name, arr) in zip(man["params"], params.items()):
        assert entry["name"] == name
        assert entry["offset"] == off
        n = entry["numel"]
        got = np.frombuffer(blob, np.float32, count=n, offset=off)
        np.testing.assert_array_equal(got, np.asarray(arr, np.float32).ravel())
        off += n * 4


def test_hlo_text_parseable_header(screener_export):
    mdir, man, _ = screener_export
    text = (mdir / "model.b1.hlo.txt").read_text()
    assert text.startswith("HloModule"), "rust loader expects HLO text"
    assert "ENTRY" in text


def test_hlo_entry_arity(screener_export):
    """Entry computation takes len(params)+1 parameters (weights..., input)."""
    mdir, man, _ = screener_export
    text = (mdir / "model.b1.hlo.txt").read_text()
    entry = text[text.index("ENTRY"):]
    first_line = entry.splitlines()[0]
    assert first_line.count("parameter_") == 0 or True  # format varies
    # robust check: parameter count via "parameter(k)" occurrences in entry body
    nparams = sum(
        1 for line in entry.splitlines() if "= f32[" in line and "parameter(" in line
        or "= s32[" in line and "parameter(" in line
    )
    assert nparams == len(man["params"]) + 1


def test_config_pbtxt_contract(screener_export):
    mdir, _, _ = screener_export
    cfg = (mdir / "config.pbtxt").read_text()
    assert 'name: "screener"' in cfg
    assert "max_batch_size: 4" in cfg
    assert "dynamic_batching" in cfg
    assert "max_queue_delay_microseconds" in cfg
    assert "TYPE_INT32" in cfg


def test_lowering_deterministic():
    """Same seed + spec -> byte-identical HLO text (reproducibility note §X)."""
    params = M.init_screener(jax.random.PRNGKey(0))
    names = list(params.keys())
    specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params.values()]

    def fn(*args):
        return M.screener_apply(dict(zip(names, args[:-1])), args[-1])

    spec = jax.ShapeDtypeStruct((1, M.SCREENER.seq), jnp.int32)
    t1 = lower_to_hlo_text(fn, *specs, spec)
    t2 = lower_to_hlo_text(fn, *specs, spec)
    assert t1 == t2


def test_repo_artifacts_exist_if_built():
    """When `make artifacts` has run, the repository index must be complete."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    idx = os.path.join(root, "repository.json")
    if not os.path.exists(idx):
        pytest.skip("artifacts/ not built yet")
    repo = json.loads(open(idx).read())
    assert set(repo["models"]) == {"distilbert_mini", "resnet_tiny", "screener"}
    for m in repo["models"]:
        man = json.loads(open(os.path.join(root, m, "manifest.json")).read())
        for f in man["hlo_files"].values():
            assert os.path.exists(os.path.join(root, m, f))
        wpath = os.path.join(root, m, man["weights_file"])
        want = sum(p["numel"] for p in man["params"]) * 4
        assert os.path.getsize(wpath) == want
