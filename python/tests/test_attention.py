"""Fused attention kernel vs oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention


def _qkv(rng, b, h, s, d):
    return (
        rng.normal(size=(b, h, s, d)).astype(np.float32),
        rng.normal(size=(b, h, s, d)).astype(np.float32),
        rng.normal(size=(b, h, s, d)).astype(np.float32),
    )


@pytest.mark.parametrize(
    "b,h,s,d", [(1, 1, 1, 1), (1, 1, 4, 4), (2, 3, 8, 4), (1, 4, 32, 16), (8, 4, 32, 16)]
)
def test_matches_ref(b, h, s, d):
    rng = np.random.default_rng(b * 17 + h * 13 + s + d)
    q, k, v = _qkv(rng, b, h, s, d)
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention(q, k, v), rtol=1e-4, atol=1e-5
    )


def test_attention_is_convex_combination():
    """Output rows lie in the convex hull of V rows: max|o| <= max|v|."""
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 2, 16, 8)
    o = np.asarray(attention(q, k, v))
    assert np.abs(o).max() <= np.abs(v).max() + 1e-5


def test_uniform_scores_average_v():
    """Identical keys => uniform attention => each output row = mean(V)."""
    rng = np.random.default_rng(1)
    b, h, s, d = 1, 1, 8, 4
    q = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = np.broadcast_to(
        rng.normal(size=(b, h, 1, d)).astype(np.float32), (b, h, s, d)
    ).copy()
    v = rng.normal(size=(b, h, s, d)).astype(np.float32)
    o = np.asarray(attention(q, k, v))
    np.testing.assert_allclose(
        o, np.broadcast_to(v.mean(2, keepdims=True), o.shape), rtol=1e-4, atol=1e-5
    )


def test_batch_independence():
    """Each (batch, head) slice is computed independently."""
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 2, 2, 8, 4)
    full = np.asarray(attention(q, k, v))
    solo = np.asarray(attention(q[:1], k[:1], v[:1]))
    np.testing.assert_allclose(full[:1], solo, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    s=st.integers(1, 16),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_sweep(b, h, s, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, b, h, s, d)
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention(q, k, v), rtol=5e-4, atol=5e-5
    )
