"""Pallas GEMM kernel vs pure-jnp oracle (correctness core, L1)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gemm import gemm, gemm_kblocked, pick_block, vmem_footprint_bytes

RTOL, ATOL = 1e-4, 1e-5


def _rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (2, 3, 4),
        (8, 8, 8),
        (7, 13, 5),
        (32, 64, 16),
        (64, 64, 64),
        (33, 65, 17),  # forces padding on every dim
        (128, 27, 16),  # im2col-conv shaped
        (256, 64, 128),
    ],
)
def test_gemm_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(gemm(x, y), ref.gemm(x, y), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bm,bn", [(1, 1), (4, 4), (8, 16), (128, 128)])
def test_gemm_block_sizes(bm, bn):
    rng = np.random.default_rng(42)
    x, y = _rand(rng, 17, 9), _rand(rng, 9, 11)
    np.testing.assert_allclose(
        gemm(x, y, bm=bm, bn=bn), ref.gemm(x, y), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (8, 8, 8, 4, 4, 4),
        (16, 32, 8, 8, 8, 8),
        (7, 13, 5, 4, 4, 4),
        (32, 64, 32, 16, 16, 16),
    ],
)
def test_gemm_kblocked_matches_ref(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(7)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(
        gemm_kblocked(x, y, bm=bm, bn=bn, bk=bk), ref.gemm(x, y),
        rtol=RTOL, atol=ATOL,
    )


def test_gemm_identity():
    rng = np.random.default_rng(3)
    x = _rand(rng, 12, 12)
    np.testing.assert_allclose(
        gemm(x, np.eye(12, dtype=np.float32)), x, rtol=RTOL, atol=ATOL
    )


def test_gemm_zeros():
    x = np.zeros((5, 6), np.float32)
    y = np.zeros((6, 7), np.float32)
    assert np.all(gemm(x, y) == 0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_property_sweep(m, k, n, seed):
    """Hypothesis shape sweep: kernel == oracle for arbitrary small shapes."""
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(gemm(x, y), ref.gemm(x, y), rtol=5e-4, atol=1e-4)


def test_pick_block_divides_or_caps():
    assert pick_block(4, 128) == 4
    assert pick_block(256, 128) == 128
    assert pick_block(1, 128) == 1
    b = pick_block(96, 128)
    assert 1 <= b <= 128


def test_vmem_footprint_under_tpu_budget():
    """§Perf invariant: one kernel instance must fit the ~16 MiB VMEM/core."""
    # worst-case tile of the served models: im2col GEMM of resnet b8
    assert vmem_footprint_bytes(8 * 32 * 32, 27, 64) < 16 * 2**20
    # a production-shaped GEMM with K-blocking stays in budget too
    assert vmem_footprint_bytes(4096, 4096, 4096, bk=512) < 16 * 2**20
