"""Fused LayerNorm kernel vs oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.layernorm import layernorm


@pytest.mark.parametrize("r,d", [(1, 4), (5, 8), (64, 64), (130, 16)])
def test_matches_ref(r, d):
    rng = np.random.default_rng(r + d)
    x = rng.normal(size=(r, d)).astype(np.float32)
    g = rng.normal(size=d).astype(np.float32)
    b = rng.normal(size=d).astype(np.float32)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm(x, g, b), rtol=1e-4, atol=1e-5
    )


def test_unit_affine_zero_mean_unit_var():
    rng = np.random.default_rng(1)
    d = 32
    x = rng.normal(size=(10, d)).astype(np.float32) * 5 + 3
    y = np.asarray(layernorm(x, np.ones(d, np.float32), np.zeros(d, np.float32)))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.var(-1), 1.0, atol=1e-3)


def test_constant_row_is_finite():
    """A constant row has var=0; eps must keep the output finite."""
    d = 8
    x = np.full((2, d), 7.0, np.float32)
    y = np.asarray(layernorm(x, np.ones(d, np.float32), np.zeros(d, np.float32)))
    assert np.isfinite(y).all()
    np.testing.assert_allclose(y, 0.0, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(r=st.integers(1, 40), d=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
def test_property_sweep(r, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(r, d)).astype(np.float32)
    g = rng.normal(size=d).astype(np.float32)
    b = rng.normal(size=d).astype(np.float32)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm(x, g, b), rtol=5e-4, atol=5e-5
    )
