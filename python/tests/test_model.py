"""Layer-2 model tests: shapes, determinism, numerics, conv-vs-lax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def bert_params():
    return M.init_distilbert(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def resnet_params():
    return M.init_resnet(jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def screener_params():
    return M.init_screener(jax.random.PRNGKey(2))


def _tokens(key, b):
    return jax.random.randint(key, (b, M.BERT.seq), 0, M.BERT.vocab)


@pytest.mark.parametrize("b", [1, 2, 4])
def test_bert_shapes(bert_params, b):
    lo, pr, en = M.distilbert_apply(bert_params, _tokens(jax.random.PRNGKey(3), b))
    assert lo.shape == (b, M.BERT.classes)
    assert pr.shape == (b, M.BERT.classes)
    assert en.shape == (b,)


def test_bert_probs_valid(bert_params):
    _, pr, en = M.distilbert_apply(bert_params, _tokens(jax.random.PRNGKey(4), 4))
    pr = np.asarray(pr)
    np.testing.assert_allclose(pr.sum(-1), 1.0, atol=1e-5)
    assert (pr >= 0).all()
    en = np.asarray(en)
    assert (en >= -1e-6).all() and (en <= np.log(M.BERT.classes) + 1e-5).all()


def test_bert_deterministic(bert_params):
    ids = _tokens(jax.random.PRNGKey(5), 2)
    a = M.distilbert_apply(bert_params, ids)
    b = M.distilbert_apply(bert_params, ids)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_bert_batch_item_independence(bert_params):
    """Row i of a batched call equals the single-item call (padding-safe)."""
    ids = _tokens(jax.random.PRNGKey(6), 4)
    lo4, _, en4 = M.distilbert_apply(bert_params, ids)
    lo1, _, en1 = M.distilbert_apply(bert_params, ids[2:3])
    np.testing.assert_allclose(lo4[2], lo1[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(en4[2], en1[0], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", [1, 2])
def test_resnet_shapes(resnet_params, b):
    img = jax.random.normal(jax.random.PRNGKey(7), (b, 32, 32, 3))
    lo, pr, en = M.resnet_apply(resnet_params, img)
    assert lo.shape == (b, M.RESNET.classes)
    assert pr.shape == (b, M.RESNET.classes)
    assert en.shape == (b,)
    np.testing.assert_allclose(np.asarray(pr).sum(-1), 1.0, atol=1e-5)


def test_resnet_batch_item_independence(resnet_params):
    img = jax.random.normal(jax.random.PRNGKey(8), (2, 32, 32, 3))
    lo2, _, _ = M.resnet_apply(resnet_params, img)
    lo1, _, _ = M.resnet_apply(resnet_params, img[1:])
    np.testing.assert_allclose(lo2[1], lo1[0], rtol=1e-3, atol=1e-4)


def test_conv2d_matches_lax():
    """im2col + Pallas GEMM conv == lax.conv_general_dilated, strides 1 and 2."""
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(10), (3, 3, 3, 5))
    dn = ("NHWC", "HWIO", "NHWC")
    for stride in (1, 2):
        want = jax.lax.conv_general_dilated(x, w, (stride, stride), "SAME",
                                            dimension_numbers=dn)
        got = M._conv2d(x, w, stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_1x1_matches_lax():
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 6, 6, 4))
    w = jax.random.normal(jax.random.PRNGKey(12), (1, 1, 4, 8))
    dn = ("NHWC", "HWIO", "NHWC")
    want = jax.lax.conv_general_dilated(x, w, (2, 2), "SAME", dimension_numbers=dn)
    np.testing.assert_allclose(M._conv2d(x, w, 2), want, rtol=1e-4, atol=1e-4)


def test_screener_shapes(screener_params):
    ids = _tokens(jax.random.PRNGKey(13), 4)
    lo, pr, en = M.screener_apply(screener_params, ids)
    assert lo.shape == (4, 2) and en.shape == (4,)


def test_screener_is_cheap():
    """Screener FLOPs must be <1% of the full model (the early-exit premise)."""
    assert M.flops_screener(1) < 0.01 * M.flops_distilbert(1)


def test_param_order_stable(bert_params):
    order = M.param_order(bert_params)
    assert order[0] == "embed" and order[-1] == "head.b"
    assert len(order) == len(set(order))


def test_flops_scale_linearly_with_batch():
    for fn in (M.flops_distilbert, M.flops_resnet, M.flops_screener):
        assert fn(4) == 4 * fn(1)


def test_bert_flops_magnitude():
    """Sanity: analytic estimate within 2x of XLA's own cost analysis (b=1)."""
    import jax.numpy as jnp
    from compile.hlo import xla_flops_estimate

    params = M.init_distilbert(jax.random.PRNGKey(0))
    names = list(params.keys())
    specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params.values()]

    def fn(*args):
        return M.distilbert_apply(dict(zip(names, args[:-1])), args[-1])

    xf = xla_flops_estimate(fn, *specs, jax.ShapeDtypeStruct((1, M.BERT.seq), jnp.int32))
    if xf > 0:
        ratio = M.flops_distilbert(1) / xf
        assert 0.5 < ratio < 2.0, f"analytic/xla flops ratio {ratio}"
