"""Fused softmax+entropy kernel vs oracle; entropy is the controller's L(x)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.softmax_entropy import softmax_entropy

RTOL, ATOL = 1e-5, 1e-6


@pytest.mark.parametrize("r,c", [(1, 2), (3, 5), (8, 2), (100, 10), (129, 7)])
def test_matches_ref(r, c):
    rng = np.random.default_rng(r * 100 + c)
    logits = rng.normal(size=(r, c)).astype(np.float32) * 3
    p, e = softmax_entropy(logits)
    rp, re = ref.softmax_entropy(logits)
    np.testing.assert_allclose(p, rp, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(e, re, rtol=RTOL, atol=ATOL)


def test_probs_sum_to_one():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(17, 9)).astype(np.float32)
    p, _ = softmax_entropy(logits)
    np.testing.assert_allclose(np.sum(np.asarray(p), -1), 1.0, atol=1e-5)


def test_uniform_logits_max_entropy():
    """H is maximal (= ln C) exactly when logits are uniform."""
    c = 8
    logits = np.zeros((1, c), np.float32)
    _, e = softmax_entropy(logits)
    np.testing.assert_allclose(e[0], np.log(c), rtol=1e-5)


def test_saturated_logits_zero_entropy():
    """A near-one-hot row must not NaN (no 0*log0) and H -> 0."""
    logits = np.array([[50.0, 0.0, 0.0, 0.0]], np.float32)
    p, e = softmax_entropy(logits)
    assert np.isfinite(np.asarray(p)).all() and np.isfinite(np.asarray(e)).all()
    assert float(e[0]) < 1e-6


def test_shift_invariance():
    """softmax/entropy are invariant to additive logit shifts."""
    rng = np.random.default_rng(5)
    logits = rng.normal(size=(4, 6)).astype(np.float32)
    p1, e1 = softmax_entropy(logits)
    p2, e2 = softmax_entropy(logits + 123.0)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(e1, e2, rtol=1e-4, atol=1e-5)


def test_large_magnitude_stability():
    logits = np.array([[1e4, -1e4, 0.0]], np.float32)
    p, e = softmax_entropy(logits)
    assert np.isfinite(np.asarray(p)).all() and np.isfinite(np.asarray(e)).all()


@settings(max_examples=30, deadline=None)
@given(
    r=st.integers(1, 64),
    c=st.integers(2, 16),
    scale=st.floats(0.01, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_entropy_bounds(r, c, scale, seed):
    """0 <= H <= ln(C) for any logits; kernel == oracle."""
    rng = np.random.default_rng(seed)
    logits = (rng.normal(size=(r, c)) * scale).astype(np.float32)
    p, e = softmax_entropy(logits)
    rp, re = ref.softmax_entropy(logits)
    np.testing.assert_allclose(p, rp, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(e, re, rtol=1e-4, atol=1e-5)
    e = np.asarray(e)
    assert (e >= -1e-5).all() and (e <= np.log(c) + 1e-4).all()
