//! The Triton-path substrate: scheduler queues and dynamic batching
//! (§III-B Path B).
//!
//! Triton's dynamic batcher fuses individually-arriving requests into
//! GPU-efficient batches: it fires when a *preferred batch size* is
//! reachable, or when the oldest queued request has waited
//! `max_queue_delay_microseconds`. [`policy::BatchPlan`] implements that
//! decision rule as a pure function (unit-testable without threads);
//! [`queue::PendingQueue`] is the thread-safe queue the batcher thread
//! drains. The batch=1 "orchestration overhead" the paper measures in
//! Table II *is* this machinery: queue hop + delay window + fuse/split.

pub mod policy;
pub mod queue;

pub use policy::{BatchPlan, BatcherPolicy};
pub use queue::PendingQueue;
