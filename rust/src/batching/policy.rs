//! Dynamic-batching decision rule (pure logic, Triton semantics).

use crate::control::Adaptive;

/// Batcher parameters (seeded from `config.pbtxt`). The queue-delay
/// window is an [`Adaptive<u64>`]: clones share the cell, so the control
/// plane's AIMD loop can retune the delay of a live batcher thread (see
/// [`crate::control`]) while `plan` keeps reading it at one atomic load.
#[derive(Debug, Clone)]
pub struct BatcherPolicy {
    pub max_batch_size: usize,
    /// Sorted ascending; empty = fire whenever anything is queued.
    pub preferred_batch_sizes: Vec<usize>,
    /// Window the oldest request may wait before a sub-preferred batch is
    /// released anyway (µs, live-updatable).
    max_queue_delay: Adaptive<u64>,
    /// Multiplicative stretch on the delay window — effective delay is
    /// `base × (1 + stretch)`. The carbon pacer links every version's
    /// policy to its shared pressure-derived cell; unlinked policies
    /// keep a private cell pinned at 0 (no stretch).
    stretch: Adaptive<f64>,
}

impl BatcherPolicy {
    pub fn new(max_batch_size: usize, mut preferred: Vec<usize>, max_queue_delay_us: u64) -> Self {
        assert!(max_batch_size >= 1);
        preferred.retain(|&p| p >= 1 && p <= max_batch_size);
        preferred.sort_unstable();
        preferred.dedup();
        BatcherPolicy {
            max_batch_size,
            preferred_batch_sizes: preferred,
            max_queue_delay: Adaptive::new(max_queue_delay_us),
            stretch: Adaptive::new(0.0f64),
        }
    }

    /// Current queue-delay window (µs), including any carbon stretch.
    /// A zero base window stays zero — carbon pacing lengthens windows
    /// the operator configured, it never introduces delay where none
    /// was asked for.
    pub fn max_queue_delay_us(&self) -> u64 {
        let base = self.max_queue_delay.get();
        let stretch = self.stretch.get();
        if stretch > 0.0 {
            (base as f64 * (1.0 + stretch)).round() as u64
        } else {
            base
        }
    }

    /// Replace the stretch cell with a shared handle (the carbon
    /// pacer's pressure × delay_weight cell). Call before cloning for
    /// replicas so every clone shares it.
    pub fn link_stretch(&mut self, handle: Adaptive<f64>) {
        self.stretch = handle;
    }

    /// Live handle onto the delay window, for the control plane's AIMD
    /// batch-delay loop.
    pub fn delay_handle(&self) -> Adaptive<u64> {
        self.max_queue_delay.handle()
    }

    /// No batching at all: every request is its own batch (the degenerate
    /// config Table II's batch=1 rows exercise when delay = 0).
    pub fn immediate(max_batch_size: usize) -> Self {
        BatcherPolicy::new(max_batch_size, vec![], 0)
    }

    /// From a parsed Triton config.
    pub fn from_config(cfg: &crate::configsys::ModelConfig) -> Self {
        match &cfg.dynamic_batching {
            Some(db) => BatcherPolicy::new(
                cfg.max_batch_size,
                db.preferred_batch_sizes.clone(),
                db.max_queue_delay_us,
            ),
            None => BatcherPolicy::immediate(cfg.max_batch_size),
        }
    }

    /// Largest preferred size that `queued` can fill (None if none fit).
    fn fillable_preferred(&self, queued: usize) -> Option<usize> {
        self.preferred_batch_sizes.iter().copied().filter(|&p| p <= queued).max()
    }

    /// Decide what to do given `queued` waiting requests whose oldest has
    /// waited `oldest_wait_us`.
    pub fn plan(&self, queued: usize, oldest_wait_us: u64) -> BatchPlan {
        if queued == 0 {
            return BatchPlan::Wait;
        }
        // Can we fill the *largest* preferred size? Fire immediately.
        if let Some(&largest) = self.preferred_batch_sizes.last() {
            if queued >= largest {
                return BatchPlan::Fire { size: largest.min(self.max_batch_size) };
            }
            // Window still open: hold for more arrivals.
            if oldest_wait_us < self.max_queue_delay_us() {
                return BatchPlan::Wait;
            }
            // Window expired: release at the best fillable preferred size,
            // or everything queued if below the smallest preferred.
            let size = self.fillable_preferred(queued).unwrap_or(queued);
            return BatchPlan::Fire { size: size.min(self.max_batch_size) };
        }
        // No preferred sizes: fire whatever is there (bounded by max).
        BatchPlan::Fire { size: queued.min(self.max_batch_size) }
    }
}

/// Batcher decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPlan {
    /// Keep queueing (window open, preferred not reachable yet).
    Wait,
    /// Release a batch of exactly `size` requests (FIFO prefix).
    Fire { size: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatcherPolicy {
        BatcherPolicy::new(8, vec![4, 8], 2000)
    }

    #[test]
    fn empty_queue_waits() {
        assert_eq!(policy().plan(0, 999_999), BatchPlan::Wait);
    }

    #[test]
    fn full_preferred_fires_immediately() {
        assert_eq!(policy().plan(8, 0), BatchPlan::Fire { size: 8 });
        assert_eq!(policy().plan(11, 0), BatchPlan::Fire { size: 8 });
    }

    #[test]
    fn window_open_holds_small_batches() {
        assert_eq!(policy().plan(2, 100), BatchPlan::Wait);
        assert_eq!(policy().plan(7, 1999), BatchPlan::Wait);
    }

    #[test]
    fn window_expiry_releases_at_best_fit() {
        // 7 queued, window expired: largest fillable preferred is 4.
        assert_eq!(policy().plan(7, 2000), BatchPlan::Fire { size: 4 });
        // 2 queued (below smallest preferred): release both.
        assert_eq!(policy().plan(2, 2500), BatchPlan::Fire { size: 2 });
        assert_eq!(policy().plan(1, 2000), BatchPlan::Fire { size: 1 });
    }

    #[test]
    fn immediate_policy_never_waits_nonempty() {
        let p = BatcherPolicy::immediate(8);
        assert_eq!(p.plan(1, 0), BatchPlan::Fire { size: 1 });
        assert_eq!(p.plan(20, 0), BatchPlan::Fire { size: 8 });
        assert_eq!(p.plan(0, 0), BatchPlan::Wait);
    }

    #[test]
    fn constructor_sanitises_preferred() {
        let p = BatcherPolicy::new(8, vec![16, 0, 4, 4, 2], 100);
        assert_eq!(p.preferred_batch_sizes, vec![2, 4]);
    }

    #[test]
    fn overfull_queue_with_empty_preferred_caps_at_max() {
        // queued > max_batch_size, no preferred sizes: fire exactly max.
        let p = BatcherPolicy::new(4, vec![], 1000);
        assert_eq!(p.plan(5, 0), BatchPlan::Fire { size: 4 });
        assert_eq!(p.plan(100, 0), BatchPlan::Fire { size: 4 });
        assert_eq!(p.plan(4, 0), BatchPlan::Fire { size: 4 });
    }

    #[test]
    fn zero_delay_window_never_holds() {
        // max_queue_delay_us == 0: the window is born expired, so even a
        // single sub-preferred request releases immediately.
        let p = BatcherPolicy::new(8, vec![4, 8], 0);
        assert_eq!(p.plan(1, 0), BatchPlan::Fire { size: 1 });
        assert_eq!(p.plan(5, 0), BatchPlan::Fire { size: 4 }, "best fillable preferred");
        assert_eq!(p.plan(0, 0), BatchPlan::Wait, "empty queue still waits");
    }

    #[test]
    fn preferred_above_max_batch_size_are_filtered() {
        // Every preferred size exceeds max: behaves like empty preferred
        // (fire whatever is queued, capped at max) instead of waiting for
        // an unreachable size.
        let p = BatcherPolicy::new(4, vec![8, 16], 5_000_000);
        assert!(p.preferred_batch_sizes.is_empty());
        assert_eq!(p.plan(1, 0), BatchPlan::Fire { size: 1 });
        assert_eq!(p.plan(9, 0), BatchPlan::Fire { size: 4 });
    }

    #[test]
    fn adaptive_delay_retunes_a_cloned_policy() {
        // The batcher thread owns a clone; the control plane holds the
        // handle. A retune must be visible through the clone.
        let p = BatcherPolicy::new(8, vec![8], 10_000);
        let on_batcher_thread = p.clone();
        assert_eq!(on_batcher_thread.plan(3, 5_000), BatchPlan::Wait);
        p.delay_handle().set(1_000);
        assert_eq!(on_batcher_thread.max_queue_delay_us(), 1_000);
        assert_eq!(on_batcher_thread.plan(3, 5_000), BatchPlan::Fire { size: 3 });
    }

    #[test]
    fn carbon_stretch_lengthens_the_window() {
        let mut p = BatcherPolicy::new(8, vec![8], 1_000);
        let cell = Adaptive::new(0.0f64);
        p.link_stretch(cell.handle());
        let on_batcher_thread = p.clone(); // replica clone shares the cell
        assert_eq!(on_batcher_thread.max_queue_delay_us(), 1_000);
        cell.set(1.0); // full pressure, delay_weight 1 → 2× window
        assert_eq!(on_batcher_thread.max_queue_delay_us(), 2_000);
        assert_eq!(on_batcher_thread.plan(3, 1_500), BatchPlan::Wait, "stretched window holds");
        cell.set(0.0);
        assert_eq!(on_batcher_thread.plan(3, 1_500), BatchPlan::Fire { size: 3 });
        // A zero base window never acquires delay from stretch.
        let mut z = BatcherPolicy::immediate(4);
        z.link_stretch(cell.handle());
        cell.set(1.0);
        assert_eq!(z.max_queue_delay_us(), 0);
    }

    #[test]
    fn from_triton_config() {
        let cfg = crate::configsys::ModelConfig::from_pbtxt(
            r#"
name: "m"
max_batch_size: 8
input [ { name: "x" data_type: TYPE_INT32 dims: [ 32 ] } ]
output [ { name: "y" data_type: TYPE_FP32 dims: [ 2 ] } ]
dynamic_batching {
  preferred_batch_size: [ 4, 8 ]
  max_queue_delay_microseconds: 2000
}
"#,
        )
        .unwrap();
        let p = BatcherPolicy::from_config(&cfg);
        assert_eq!(p.preferred_batch_sizes, vec![4, 8]);
        assert_eq!(p.max_queue_delay_us(), 2000);
    }

    #[test]
    fn no_batching_config_gives_immediate() {
        let cfg = crate::configsys::ModelConfig::from_pbtxt(
            r#"
name: "m"
max_batch_size: 4
input [ { name: "x" data_type: TYPE_FP32 dims: [ 3 ] } ]
output [ { name: "y" data_type: TYPE_FP32 dims: [ 1 ] } ]
"#,
        )
        .unwrap();
        let p = BatcherPolicy::from_config(&cfg);
        assert!(p.preferred_batch_sizes.is_empty());
        assert_eq!(p.plan(3, 0), BatchPlan::Fire { size: 3 });
    }
}
