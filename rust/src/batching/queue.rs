//! Thread-safe pending-request queue for the dynamic batcher.
//!
//! Mutex + Condvar (no external crates): producers enqueue, the batcher
//! thread blocks until the policy says Fire, then drains a FIFO prefix.
//! Bounded capacity gives the backpressure signal the controller's C(x)
//! reads (queue depth / capacity).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::batching::policy::{BatchPlan, BatcherPolicy};

/// An enqueued item with its arrival instant.
#[derive(Debug)]
struct Pending<T> {
    item: T,
    enqueued: Instant,
}

#[derive(Debug, Default)]
struct State<T> {
    q: VecDeque<Pending<T>>,
    closed: bool,
}

/// Bounded MPSC batch queue.
#[derive(Debug)]
pub struct PendingQueue<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    capacity: usize,
}

/// Why an enqueue failed.
#[derive(Debug, PartialEq, Eq)]
pub enum EnqueueError {
    Full,
    Closed,
}

impl<T> PendingQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        PendingQueue {
            state: Mutex::new(State { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking enqueue; `Err(Full)` is the backpressure signal.
    pub fn push(&self, item: T) -> Result<(), EnqueueError> {
        let mut g = self.state.lock().unwrap();
        if g.closed {
            return Err(EnqueueError::Closed);
        }
        if g.q.len() >= self.capacity {
            return Err(EnqueueError::Full);
        }
        g.q.push_back(Pending { item, enqueued: Instant::now() });
        self.cv.notify_all();
        Ok(())
    }

    /// Current depth (the C(x) congestion input).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Close the queue: pending items still drain; pushes fail; a blocked
    /// `next_batch` returns None once empty.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Block until the policy releases a batch (or the queue is closed and
    /// empty → None). Returns the FIFO prefix of the planned size.
    pub fn next_batch(&self, policy: &BatcherPolicy) -> Option<Vec<T>> {
        let mut g = self.state.lock().unwrap();
        loop {
            if g.q.is_empty() {
                if g.closed {
                    return None;
                }
                g = self.cv.wait(g).unwrap();
                continue;
            }
            let oldest_us = g.q.front().unwrap().enqueued.elapsed().as_micros() as u64;
            match policy.plan(g.q.len(), oldest_us) {
                BatchPlan::Fire { size } => {
                    let n = size.min(g.q.len());
                    let batch: Vec<T> = g.q.drain(..n).map(|p| p.item).collect();
                    return Some(batch);
                }
                BatchPlan::Wait => {
                    if g.closed {
                        // Drain the tail on shutdown.
                        let batch: Vec<T> =
                            g.q.drain(..).map(|p| p.item).collect();
                        return Some(batch);
                    }
                    // Sleep until the window would expire (or new arrivals).
                    // Re-read the adaptive delay each pass: a control-loop
                    // retune takes effect at the next wakeup.
                    let remaining =
                        policy.max_queue_delay_us().saturating_sub(oldest_us).max(1);
                    let (g2, _) = self
                        .cv
                        .wait_timeout(g, Duration::from_micros(remaining))
                        .unwrap();
                    g = g2;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_and_drain() {
        let q = PendingQueue::new(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        let policy = BatcherPolicy::immediate(8);
        assert_eq!(q.next_batch(&policy), Some(vec![1, 2]));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn full_queue_backpressures() {
        let q = PendingQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(EnqueueError::Full));
    }

    #[test]
    fn closed_queue_rejects_push_and_returns_none() {
        let q: PendingQueue<u32> = PendingQueue::new(2);
        q.close();
        assert_eq!(q.push(1), Err(EnqueueError::Closed));
        assert_eq!(q.next_batch(&BatcherPolicy::immediate(4)), None);
    }

    #[test]
    fn close_drains_tail() {
        let q = PendingQueue::new(8);
        q.push(1).unwrap();
        // Policy that would Wait (preferred 4, long window):
        let policy = BatcherPolicy::new(8, vec![4], 10_000_000);
        q.close();
        assert_eq!(q.next_batch(&policy), Some(vec![1]));
        assert_eq!(q.next_batch(&policy), None);
    }

    #[test]
    fn delay_window_releases_sub_preferred_batch() {
        let q = Arc::new(PendingQueue::new(16));
        let policy = BatcherPolicy::new(8, vec![8], 20_000); // 20 ms window
        q.push(1).unwrap();
        q.push(2).unwrap();
        let t0 = Instant::now();
        let batch = q.next_batch(&policy).unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch, vec![1, 2]);
        assert!(waited >= Duration::from_millis(15), "released early: {waited:?}");
    }

    #[test]
    fn preferred_size_fires_without_waiting() {
        let q = Arc::new(PendingQueue::new(16));
        let policy = BatcherPolicy::new(8, vec![2], 5_000_000); // huge window
        q.push(1).unwrap();
        q.push(2).unwrap();
        let t0 = Instant::now();
        let batch = q.next_batch(&policy).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(100), "must not wait the window");
    }

    #[test]
    fn producer_wakes_blocked_batcher() {
        let q = Arc::new(PendingQueue::new(16));
        let policy = BatcherPolicy::immediate(8);
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.push(42).unwrap();
        });
        let batch = q.next_batch(&policy).unwrap();
        assert_eq!(batch, vec![42]);
        producer.join().unwrap();
    }

    #[test]
    fn fifo_order_preserved() {
        let q = PendingQueue::new(64);
        for i in 0..10 {
            q.push(i).unwrap();
        }
        let policy = BatcherPolicy::new(4, vec![4], 0);
        assert_eq!(q.next_batch(&policy), Some(vec![0, 1, 2, 3]));
        assert_eq!(q.next_batch(&policy), Some(vec![4, 5, 6, 7]));
    }
}
