//! Bench harness (criterion is unavailable offline; DESIGN.md §6).
//!
//! Provides warmup + timed iterations with mean/σ/percentile reporting and
//! the table renderer the paper-reproduction benches share. Benches are
//! `harness = false` binaries that call [`bench_fn`] / print [`Table`]s.

use std::time::Instant;

use crate::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wallclock samples (seconds).
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn std_dev(&self) -> f64 {
        stats::std_dev(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        stats::quantile(&self.samples, 0.5)
    }

    pub fn p95(&self) -> f64 {
        stats::quantile(&self.samples, 0.95)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        let m = self.mean();
        if m > 0.0 {
            1.0 / m
        } else {
            0.0
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<32} mean {:>12}  σ {:>12}  p95 {:>12}  ({} iters)",
            self.name,
            crate::util::fmt::duration(self.mean()),
            crate::util::fmt::duration(self.std_dev()),
            crate::util::fmt::duration(self.p95()),
            self.samples.len()
        )
    }
}

/// Run `f` for `warmup` untimed then `iters` timed iterations.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), samples }
}

/// Time a single invocation of `f` (macro-bench building block).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Plain-text table renderer for paper-shaped outputs.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column width fitting.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    /// Render as CSV (export path for EXPERIMENTS.md data).
    pub fn to_csv(&self) -> String {
        use crate::telemetry::export::csv_field;
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| csv_field(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| csv_field(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float cell with fixed decimals.
pub fn cell(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_collects_samples() {
        let mut n = 0u64;
        let r = bench_fn("noop", 2, 10, || n += 1);
        assert_eq!(r.samples.len(), 10);
        assert_eq!(n, 12, "warmup + timed iterations both ran");
        assert!(r.mean() >= 0.0);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn result_stats_consistent() {
        let r = BenchResult { name: "x".into(), samples: vec![1.0, 2.0, 3.0] };
        assert!((r.mean() - 2.0).abs() < 1e-12);
        assert!((r.p50() - 2.0).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert!(r.summary().contains("x"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["model", "ms"]);
        t.row(vec!["distilbert_mini".into(), "125.21".into()]);
        t.row(vec!["resnet".into(), "30.65".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| distilbert_mini |"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len(), "aligned columns");
    }

    #[test]
    fn table_csv() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
