//! Tiny `--key value` / `--flag` argument parser (clap is unavailable
//! offline; DESIGN.md §6).

use std::collections::BTreeMap;

/// Parsed flags: `--key value` pairs and boolean `--flag`s.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse a flag list. A token starting with `--` consumes the next
    /// token as its value unless that token also starts with `--` (then
    /// it is a boolean flag). Positional tokens are rejected.
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            let Some(key) = tok.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {tok:?}"));
            };
            if key.is_empty() {
                return Err("bare -- is not allowed".to_string());
            }
            match argv.get(i + 1) {
                Some(next) if !next.starts_with("--") => {
                    out.values.insert(key.to_string(), next.clone());
                    i += 2;
                }
                _ => {
                    out.flags.push(key.to_string());
                    i += 1;
                }
            }
        }
        Ok(out)
    }

    /// String value of `--key value`.
    pub fn get(&self, key: &str) -> Option<String> {
        self.values.get(key).cloned()
    }

    /// Float value of `--key value`.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.values.get(key).and_then(|v| v.parse().ok())
    }

    /// Whether boolean `--flag` was passed.
    pub fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.values.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_pairs_and_flags() {
        let a = Args::parse(&sv(&["--port", "8080", "--controller", "--k", "1.5"])).unwrap();
        assert_eq!(a.get("port").as_deref(), Some("8080"));
        assert_eq!(a.get_f64("k"), Some(1.5));
        assert!(a.has("controller"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&sv(&["--a", "--b", "x"])).unwrap();
        assert!(a.has("a"));
        assert_eq!(a.get("b").as_deref(), Some("x"));
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["oops"])).is_err());
        assert!(Args::parse(&sv(&["--"])).is_err());
    }

    #[test]
    fn get_f64_rejects_garbage() {
        let a = Args::parse(&sv(&["--n", "abc"])).unwrap();
        assert_eq!(a.get_f64("n"), None);
        assert!(a.has("n"));
    }

    #[test]
    fn empty_ok() {
        let a = Args::parse(&[]).unwrap();
        assert!(!a.has("x"));
    }
}
