//! The `greenflow` command-line launcher.
//!
//! ```text
//! greenflow serve     --repo artifacts --port 8080 [--controller] [--device a100]
//!                     [--model-control explicit|none]
//!                     [--adaptive-tau 0.58] [--adaptive-delay] [--adaptive-router]
//!                     [--energy-budget 60] [--slo 0.25] [--tick-ms 100]
//!                     [--carbon-pacer THRESH] [--carbon-trace trace.csv]
//!                     [--serve-bench N [--model distilbert_mini] [--bench-json out.json]
//!                      [--bench-conns C] [--bench-dup-ratio R]
//!                      [--bench-tenants T] [--bench-hot-tenant-share S]
//!                      [--scenario name|file:trace.csv] [--scenario-seed S]
//!                      [--scenario-out trace.csv]]
//! greenflow repo      <index|load|unload> [--addr 127.0.0.1:8080]
//!                     [--model NAME] [--version N] [--wait]
//! greenflow report    --repo artifacts
//! greenflow ablation  [--requests 1000] [--tau0 0.2] [--tau-inf 0.78] [--k 2.0]
//!                     [--adaptive-tau 0.58] [--duplicate-ratio 0.0]
//! greenflow landscape [--out -]
//! greenflow perfgate  --serve-json serve_bench.json [--micro-json micro.json]
//!                     [--serve-hc-json serve_bench_hc.json]
//!                     [--serve-tenant-json serve_bench_tenant.json]
//!                     [--serve-flash-json serve_bench_flash.json]
//!                     [--serve-diurnal-json serve_bench_diurnal.json]
//!                     [--out BENCH.json] [--baseline benches/baseline.json]
//!                     [--max-regress 0.20] [--label pr6]
//! greenflow version
//! ```
//!
//! `--serve-bench N` boots the gateway on an ephemeral port (unless
//! `--port` pins one), fires `N` v2 infer round-trips over keep-alive
//! connections through [`crate::server::HttpClient`] (`--bench-conns C`
//! spreads them over `C` concurrent connections, default 1), prints
//! the aggregate throughput, and exits — the self-contained
//! load-generator smoke the v2 protocol was rebuilt for.
//! `--bench-dup-ratio R` makes fraction `R` of the requests exact
//! duplicates of one hot request, exercising the singleflight
//! coalescing path; the report then carries the realised
//! `coalesce_hit_rate` and `joules_saved` scraped from
//! `/v2/admission/stats` (see `docs/COALESCE.md`).
//! `--bench-tenants T` tags every request with an `X-Tenant-Id` header
//! spread across `T` synthetic tenants; `--bench-hot-tenant-share S`
//! routes fraction `S` of them to the hot tenant `t0`, the rest
//! round-robin across the cold ones. The report then carries
//! per-tenant admitted-rate fields (`tenant_stats`) — the QoS
//! hot-tenant lane (see `docs/QOS.md`).
//!
//! `--scenario <name|file:trace.csv>` replays a deterministic
//! [`crate::workload::scenario`] request sequence instead of the flat
//! seed ladder: request *i* (global index across connections) carries
//! the scenario's *i*-th seed and its lattice priority in the body's
//! `parameters.priority`, so the live bench and the deterministic sims
//! exercise bit-identical traces (`docs/SCENARIOS.md`).
//! `--scenario-out` saves the resolved trace as a CSV that
//! `--scenario file:<path>` replays exactly — the CI scenario-matrix
//! lane uploads it with the BENCH artifact.
//!
//! The `--adaptive-*` / `--energy-budget` flags boot the control plane
//! ([`crate::control`]): background loops that retune τ, the batcher
//! queue-delay window, and the router QPS threshold from windowed
//! latency/energy/admission signals.
//!
//! `--model-control explicit` starts the server with nothing loaded;
//! `greenflow repo load/unload --model NAME [--version N]` then drives
//! the running server's `/v2/repository` lifecycle API over HTTP
//! (`repo index` prints every model's per-version state). Lifecycle
//! operations are async (202) unless `--wait` is passed.
//!
//! `perfgate` is the CI perf gate: it fuses a `--serve-bench
//! --bench-json` run and the micro-hotpath timings into one
//! `BENCH_*.json` snapshot and fails on regression against a committed
//! baseline — see `docs/BENCH.md`.

pub mod args;

use std::path::PathBuf;
use std::sync::Arc;

use crate::control::ControlPlaneConfig;
use crate::controller::admission::AdaptiveTauPolicy;
use crate::controller::baselines::OpenLoop;
use crate::controller::cost::WeightPolicy;
use crate::controller::threshold::ThresholdSchedule;
use crate::controller::{AdmissionController, ControllerConfig};
use crate::energy::DeviceProfile;
use crate::pipeline::system::{ServingSystem, SystemConfig};
use crate::server::Gateway;
use crate::sim::{simulate, SimConfig};
use crate::workload::arrival::{arrival_times, ArrivalProcess};
use crate::workload::stream::{RequestStream, StreamConfig};

use args::Args;

/// CLI entry point (also used by `main.rs`).
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(run(&argv));
}

/// Run with explicit argv (testable); returns the exit code.
pub fn run(argv: &[String]) -> i32 {
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{}", usage());
        return 2;
    };
    if cmd == "repo" {
        // `repo` takes a positional operation before its flags.
        return cmd_repo(rest);
    }
    let args = match Args::parse(rest) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}\n{}", usage());
            return 2;
        }
    };
    match cmd.as_str() {
        "version" => {
            println!("greenflow {}", crate::VERSION);
            0
        }
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "ablation" => cmd_ablation(&args),
        "landscape" => cmd_landscape(&args),
        "perfgate" => cmd_perfgate(&args),
        other => {
            eprintln!("unknown command {other:?}\n{}", usage());
            2
        }
    }
}

fn usage() -> &'static str {
    "usage: greenflow <serve|repo|report|ablation|landscape|perfgate|version> [--flag value ...]"
}

fn repo_root(args: &Args) -> PathBuf {
    PathBuf::from(args.get("repo").unwrap_or_else(|| crate::DEFAULT_REPOSITORY.to_string()))
}

fn device(args: &Args) -> DeviceProfile {
    let name = args.get("device").unwrap_or_else(|| "rtx4000_ada".to_string());
    DeviceProfile::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown device {name:?}, using rtx4000_ada");
        DeviceProfile::rtx4000_ada()
    })
}

fn cmd_report(args: &Args) -> i32 {
    let root = repo_root(args);
    match crate::runtime::Repository::scan(&root) {
        Ok(repo) => {
            println!("repository at {}:", root.display());
            for (name, e) in &repo.entries {
                println!(
                    "  {name}: family={} classes={} buckets={:?} params={} ({} bytes), delay={}µs",
                    e.manifest.family,
                    e.manifest.classes,
                    e.manifest.batch_buckets,
                    e.manifest.params.len(),
                    e.manifest.weights_bytes(),
                    repo.queue_delay_us(name),
                );
            }
            if let Err(e) = repo.validate() {
                eprintln!("VALIDATION FAILED: {e}");
                return 1;
            }
            println!("validation: ok");
            0
        }
        Err(e) => {
            eprintln!("cannot scan repository: {e} (run `make artifacts` first)");
            1
        }
    }
}

fn controller_config(args: &Args) -> ControllerConfig {
    let policy = args
        .get("policy")
        .and_then(|p| WeightPolicy::by_name(&p))
        .unwrap_or(WeightPolicy::Balanced);
    ControllerConfig {
        weights: policy.weights(),
        schedule: ThresholdSchedule::Exponential {
            tau0: args.get_f64("tau0").unwrap_or(0.2),
            tau_inf: args.get_f64("tau-inf").unwrap_or(0.51),
            k: args.get_f64("k").unwrap_or(2.0),
        },
        respond_from_cache: true,
    }
}

/// Assemble the control-plane config from the `--adaptive-*` /
/// `--energy-budget` flags; None when no loop was requested.
fn control_config(args: &Args, slo: f64) -> Option<ControlPlaneConfig> {
    let mut cfg = ControlPlaneConfig {
        tick_secs: args.get_f64("tick-ms").unwrap_or(100.0).max(1.0) / 1e3,
        ..ControlPlaneConfig::default()
    };
    if args.has("adaptive-tau") {
        // Admission rate is a fraction: clamp so e.g. "--adaptive-tau 58"
        // saturates at admit-all instead of wiring an unreachable setpoint.
        cfg = cfg
            .with_adaptive_tau(args.get_f64("adaptive-tau").unwrap_or(0.58).clamp(0.0, 1.0));
    }
    if args.has("adaptive-delay") {
        cfg = cfg.with_adaptive_batch_delay(slo);
    }
    if args.has("adaptive-router") {
        cfg = cfg.with_adaptive_router(slo);
    }
    if let Some(w) = args.get_f64("energy-budget") {
        cfg = cfg.with_energy_budget(w);
    }
    if args.has("carbon-pacer") || args.has("carbon-trace") {
        // `--carbon-pacer [THRESH]` enables the pacer at the given clean
        // threshold (kg CO₂/kWh); `--carbon-trace` alone implies it at
        // the default threshold so a trace is never silently ignored.
        let threshold = args
            .get_f64("carbon-pacer")
            .filter(|v| *v > 0.0)
            .unwrap_or(crate::control::CarbonPacerConfig::default().threshold_kg_per_kwh);
        cfg = cfg.with_carbon_pacer(threshold);
    }
    cfg.any_enabled().then_some(cfg)
}

/// `greenflow repo <index|load|unload>`: drive a running server's
/// `/v2/repository` lifecycle API over one HTTP round-trip. Load and
/// unload are asynchronous by default (202 + pollable state via
/// `repo index`); `--wait` blocks until the server reports the
/// terminal outcome.
fn cmd_repo(rest: &[String]) -> i32 {
    const REPO_USAGE: &str = "usage: greenflow repo <index|load|unload> \
                              [--addr 127.0.0.1:8080] [--model NAME] [--version N] [--wait]";
    let Some((op, flags)) = rest.split_first() else {
        eprintln!("{REPO_USAGE}");
        return 2;
    };
    let args = match Args::parse(flags) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}\n{REPO_USAGE}");
            return 2;
        }
    };
    let addr_str = args.get("addr").unwrap_or_else(|| "127.0.0.1:8080".to_string());
    let addr: std::net::SocketAddr = match addr_str.parse() {
        Ok(a) => a,
        Err(_) => {
            eprintln!("invalid --addr {addr_str:?} (want host:port)");
            return 2;
        }
    };
    let (path, body) = match op.as_str() {
        "index" => ("/v2/repository/index".to_string(), "{}".to_string()),
        "load" | "unload" => {
            let Some(model) = args.get("model") else {
                eprintln!("repo {op} needs --model\n{REPO_USAGE}");
                return 2;
            };
            let body = match args.get_f64("version") {
                Some(v) if v >= 1.0 && v.fract() == 0.0 => {
                    format!("{{\"parameters\": {{\"version\": {}}}}}", v as u64)
                }
                Some(_) => {
                    eprintln!("--version must be a positive integer");
                    return 2;
                }
                None => "{}".to_string(),
            };
            let wait = if args.has("wait") { "?wait=true" } else { "" };
            (format!("/v2/repository/models/{model}/{op}{wait}"), body)
        }
        other => {
            eprintln!("unknown repo operation {other:?}\n{REPO_USAGE}");
            return 2;
        }
    };
    let mut client = match crate::server::HttpClient::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot connect to {addr}: {e} (is `greenflow serve` running?)");
            return 1;
        }
    };
    match client.post_json(&path, &body) {
        Ok(resp) => {
            println!("{}", resp.body_str().unwrap_or_default());
            // 200 = done, 202 = accepted (async lifecycle) — both wins.
            if (200..300).contains(&resp.status) {
                0
            } else {
                eprintln!("HTTP {}", resp.status);
                1
            }
        }
        Err(e) => {
            eprintln!("transport error: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let root = repo_root(args);
    let mut cfg = SystemConfig::new(root);
    cfg.device = device(args);
    if let Some(slo) = args.get_f64("slo") {
        cfg.slo_latency = slo;
    }
    if let Some(mc) = args.get("model-control") {
        match crate::pipeline::system::ModelControl::parse(&mc) {
            Some(m) => cfg.model_control = m,
            None => {
                eprintln!("unknown --model-control {mc:?} (want explicit|none)");
                return 2;
            }
        }
    }
    let control = control_config(args, cfg.slo_latency);
    // τ-side loops need the admission controller in front (the carbon
    // pacer biases τ on deferrable work, so it counts).
    let needs_controller = args.has("controller")
        || control
            .as_ref()
            .map(|c| {
                c.adaptive_tau.is_some() || c.energy_budget.is_some() || c.carbon_pacer.is_some()
            })
            .unwrap_or(false);
    if needs_controller {
        cfg = cfg.with_controller(controller_config(args));
    }
    if let Some(c) = control {
        cfg = cfg.with_control(c);
    }
    if let Some(path) = args.get("carbon-trace") {
        match crate::energy::CarbonIntensityTrace::load(std::path::Path::new(&path)) {
            Ok(trace) => cfg = cfg.with_carbon_trace(trace),
            Err(e) => {
                eprintln!("cannot load --carbon-trace {path}: {e}");
                return 2;
            }
        }
    }
    let bench_n = args.get_f64("serve-bench").map(|n| n.max(1.0) as usize);
    // Fail fast on a bad --scenario spec before booting anything (file
    // traces are validated at resolve time — the path may appear later).
    if let Some(spec) = args.get("scenario") {
        if bench_n.is_none() {
            eprintln!("--scenario requires --serve-bench N");
            return 2;
        }
        if !spec.starts_with("file:")
            && crate::workload::scenario::Scenario::named(&spec).is_none()
        {
            eprintln!(
                "unknown scenario {spec:?}; built-ins: {}, or file:<trace.csv>",
                crate::workload::scenario::Scenario::builtin_names().join(", ")
            );
            return 2;
        }
    }
    // Bench mode defaults to an ephemeral port so it never collides.
    let default_port = if bench_n.is_some() { 0.0 } else { 8080.0 };
    let port = args.get_f64("port").unwrap_or(default_port) as u16;
    let system = match ServingSystem::start(cfg) {
        Ok(s) => Arc::new(s),
        Err(e) => {
            eprintln!("cannot start serving system: {e}");
            return 1;
        }
    };
    match Gateway::start(system.clone(), port, 8) {
        Ok(mut gw) => {
            println!("greenflow gateway listening on http://{}", gw.addr());
            println!(
                "v2: GET /v2/health/live|ready  GET /v2/models[/{{name}}[/versions/{{v}}]]  \
                 POST /v2/models/{{name}}[/versions/{{v}}]/infer  GET /v2/control/loops  \
                 GET /v2/admission/stats"
            );
            println!(
                "repository: POST /v2/repository/index  \
                 POST /v2/repository/models/{{name}}/load|unload"
            );
            println!("legacy: POST /infer  GET /metrics  GET /models  GET /health");
            println!(
                "models: {} of {} registered loaded (load more with `greenflow repo`)",
                system.ready_models(),
                system.model_names().len(),
            );
            if system.control_plane_running() {
                println!("control plane: {}", system.control_loop_names().join(", "));
            }
            if let Some(n) = bench_n {
                let model = args
                    .get("model")
                    .unwrap_or_else(|| crate::models::DISTILBERT.to_string());
                let conns = args.get_f64("bench-conns").map(|c| c.max(1.0) as usize).unwrap_or(1);
                let dup_ratio = args.get_f64("bench-dup-ratio").unwrap_or(0.0).clamp(0.0, 1.0);
                let tenants = args.get_f64("bench-tenants").map(|t| t as usize).unwrap_or(0);
                let hot_tenant_share = args.get_f64("bench-hot-tenant-share").unwrap_or(0.0);
                let scenario_seed = args
                    .get_f64("scenario-seed")
                    .map(|s| s as u64)
                    .unwrap_or(crate::workload::scenario::DEFAULT_SEED);
                let opts = BenchOpts {
                    n,
                    model,
                    conns,
                    dup_ratio,
                    tenants,
                    hot_tenant_share,
                    scenario: args.get("scenario"),
                    scenario_seed,
                    scenario_out: args.get("scenario-out"),
                    json_out: args.get("bench-json"),
                };
                let code = serve_bench(gw.addr(), &opts);
                gw.shutdown();
                return code;
            }
            // Serve until killed.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("cannot bind: {e}");
            1
        }
    }
}

/// Round-trip bench: N requests spread over `conns` concurrent
/// keep-alive connections (default 1, the historical shape; CI also
/// runs 256 to exercise the reactor's connection scaling). When the
/// target model has a ready version the round-trips are real v2
/// infers; otherwise (hermetic CI — the stub backend loads nothing) it
/// degrades to `GET /v2/health/live`, which still measures the whole
/// HTTP hot path (accept loop, parse, route, serialise). `--bench-json`
/// writes the measurements for the CI perf gate (`greenflow perfgate`).
///
/// `dup_ratio` ∈ [0, 1] sends that fraction of requests with one
/// shared hot seed (exact duplicates, Bresenham-spread so the mix is
/// even); the rest get globally unique seeds. Duplicates that overlap
/// in flight coalesce onto one execution — the report's
/// `coalesce_hit_rate`/`joules_saved` (scraped from
/// `/v2/admission/stats` after the run) quantify the saving. In the
/// health fallback both are reported as 0.
///
/// Latencies are pooled across connections; throughput is aggregate
/// wall-clock (N ÷ elapsed across all workers), i.e. what the server
/// actually sustained, not a per-connection mean.
///
/// `tenants > 0` switches on the QoS lane: every request carries an
/// `X-Tenant-Id` header, fraction `hot_tenant_share` lands on the hot
/// tenant `t0` (Bresenham-spread like the duplicate mix, so the
/// interleave is deterministic), the rest round-robin across the cold
/// tenants, and the report gains per-tenant admitted-rate fields.
///
/// `scenario` replaces the flat seed ladder with a resolved
/// [`crate::workload::scenario`] run: request *i* (global index
/// `worker + conns·i`) replays the scenario's *i*-th seed and carries
/// its lattice priority in `parameters.priority`, making the live bench
/// and the deterministic sims consume bit-identical traces. The report
/// then gains `scenario`/`scenario_seed`/`joules_per_answer` plus the
/// gateway's carbon accounting when the pacer is wired.
struct BenchOpts {
    n: usize,
    model: String,
    conns: usize,
    dup_ratio: f64,
    tenants: usize,
    hot_tenant_share: f64,
    scenario: Option<String>,
    scenario_seed: u64,
    scenario_out: Option<String>,
    json_out: Option<String>,
}

fn serve_bench(addr: std::net::SocketAddr, opts: &BenchOpts) -> i32 {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    let (model, dup_ratio) = (opts.model.as_str(), opts.dup_ratio);
    let (tenants, hot_share) = (opts.tenants, opts.hot_tenant_share.clamp(0.0, 1.0));
    let json_out = opts.json_out.as_deref();
    // Scenario replay: resolve the spec once; the workers then index the
    // shared run by global request index, so the wire order per worker is
    // exactly the scenario order modulo `conns`-striding.
    let scenario_run = match opts.scenario.as_deref() {
        Some(spec) => {
            match crate::workload::scenario::resolve(spec, opts.n, opts.scenario_seed) {
                Ok(run) if run.requests.is_empty() => {
                    eprintln!("serve-bench: scenario {spec:?} resolved to zero requests");
                    return 2;
                }
                Ok(run) => Some(run),
                Err(e) => {
                    eprintln!("serve-bench: {e}");
                    return 2;
                }
            }
        }
        None => None,
    };
    // File traces may carry fewer requests than asked for.
    let n = scenario_run.as_ref().map(|r| r.requests.len()).unwrap_or(opts.n);
    if let (Some(run), Some(path)) = (&scenario_run, opts.scenario_out.as_deref()) {
        if let Err(e) = crate::workload::trace::save(std::path::Path::new(path), &run.requests) {
            eprintln!("serve-bench: cannot write scenario trace {path}: {e}");
            return 1;
        }
        println!(
            "serve-bench: wrote scenario trace {path} (replay with --scenario file:{path})"
        );
    }
    let scenario = scenario_run.as_ref();
    let conns = opts.conns.clamp(1, n.max(1));
    // Readiness probe on its own connection, dropped before timing.
    let ready = match crate::server::HttpClient::connect(addr) {
        Ok(mut probe) => probe
            .get(&format!("/v2/models/{model}"))
            .ok()
            .and_then(|r| r.json().ok())
            .map(|v| v.get("ready").ok().cloned() == Some(crate::json::Value::Bool(true)))
            .unwrap_or(false),
        Err(e) => {
            eprintln!("serve-bench: cannot connect: {e}");
            return 1;
        }
    };
    let target = if ready { "infer" } else { "health" };
    if !ready {
        eprintln!(
            "serve-bench: model {model:?} has no ready version — measuring \
             /v2/health/live round-trips instead"
        );
    }
    let infer_path = format!("/v2/models/{model}/infer");
    let latencies = std::sync::Mutex::new(Vec::with_capacity(n));
    let ok = AtomicUsize::new(0);
    let err = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    // Per-tenant tallies for the QoS lane (empty when tenants == 0).
    // Index 0 is the hot tenant; sheds are any non-200 answer (429
    // rate-limit / retry-budget / backpressure in practice).
    let tenant_names: Vec<String> = (0..tenants).map(|i| format!("t{i}")).collect();
    let tenant_sent: Vec<AtomicUsize> = (0..tenants).map(|_| AtomicUsize::new(0)).collect();
    let tenant_ok: Vec<AtomicUsize> = (0..tenants).map(|_| AtomicUsize::new(0)).collect();
    let tenant_shed: Vec<AtomicUsize> = (0..tenants).map(|_| AtomicUsize::new(0)).collect();
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for worker in 0..conns {
            // Spread the total across workers (earlier workers absorb
            // the remainder) so exactly `n` requests hit the wire.
            let quota = n / conns + usize::from(worker < n % conns);
            let (latencies, ok, err, failed) = (&latencies, &ok, &err, &failed);
            let (tenant_names, tenant_sent, tenant_ok, tenant_shed) =
                (&tenant_names, &tenant_sent, &tenant_ok, &tenant_shed);
            let infer_path = infer_path.as_str();
            scope.spawn(move || {
                let mut client = match crate::server::HttpClient::connect(addr) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("serve-bench: connection {worker} failed: {e}");
                        failed.store(true, Ordering::SeqCst);
                        return;
                    }
                };
                let mut local = Vec::with_capacity(quota);
                // Bresenham accumulator: exactly ⌊quota·R⌋±1 requests
                // reuse the hot seed, evenly interleaved — no RNG, so
                // runs are reproducible.
                let mut dup_acc = 0.0f64;
                // Same Bresenham idea for the hot-tenant share: exactly
                // ⌊quota·S⌋±1 requests land on t0, evenly interleaved.
                let mut hot_acc = 0.0f64;
                for i in 0..quota {
                    // Global index: worker w sends scenario requests
                    // w, w+conns, w+2·conns, … — together the workers
                    // cover exactly [0, n).
                    let global = worker + conns * i;
                    let body = match scenario {
                        Some(run) => {
                            // Replay the scenario's seed and tag its
                            // lattice priority so the gateway's carbon
                            // pacer sees the deferrable share.
                            let r = &run.requests[global];
                            format!(
                                "{{\"seed\": {}, \"parameters\": {{\"priority\": \"{}\"}}}}",
                                r.seed,
                                run.priority_for(global).as_str(),
                            )
                        }
                        None => {
                            dup_acc += dup_ratio;
                            let seed = if dup_acc >= 1.0 {
                                dup_acc -= 1.0;
                                0 // the shared hot request every duplicate collapses onto
                            } else {
                                // Globally unique across workers.
                                1 + global as u64
                            };
                            format!("{{\"seed\": {seed}}}")
                        }
                    };
                    let tenant = if tenants == 0 {
                        None
                    } else {
                        hot_acc += hot_share;
                        if hot_acc >= 1.0 {
                            hot_acc -= 1.0;
                            Some(0)
                        } else if tenants == 1 {
                            Some(0)
                        } else {
                            Some(1 + global % (tenants - 1))
                        }
                    };
                    let t_req = std::time::Instant::now();
                    let result = match tenant {
                        Some(ti) => {
                            tenant_sent[ti].fetch_add(1, Ordering::Relaxed);
                            let id = (crate::qos::TENANT_HEADER, tenant_names[ti].as_str());
                            if ready {
                                client.request(
                                    "POST",
                                    infer_path,
                                    &[("Content-Type", "application/json"), id],
                                    Some(body.as_bytes()),
                                )
                            } else {
                                client.request("GET", "/v2/health/live", &[id], None)
                            }
                        }
                        None if ready => client.post_json(infer_path, &body),
                        None => client.get("/v2/health/live"),
                    };
                    match result {
                        Ok(resp) => {
                            local.push(t_req.elapsed().as_secs_f64());
                            if resp.status == 200 {
                                ok.fetch_add(1, Ordering::Relaxed);
                            } else {
                                err.fetch_add(1, Ordering::Relaxed);
                            }
                            if let Some(ti) = tenant {
                                if resp.status == 200 {
                                    tenant_ok[ti].fetch_add(1, Ordering::Relaxed);
                                } else {
                                    tenant_shed[ti].fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            // The server rotates connections after 100k
                            // requests (Connection: close); reconnect
                            // instead of dying on the next write.
                            if !resp.keep_alive() && i + 1 < quota {
                                client = match crate::server::HttpClient::connect(addr) {
                                    Ok(c) => c,
                                    Err(e) => {
                                        eprintln!("serve-bench: reconnect failed: {e}");
                                        failed.store(true, Ordering::SeqCst);
                                        return;
                                    }
                                };
                            }
                        }
                        Err(e) => {
                            eprintln!(
                                "serve-bench: transport error on connection {worker}: {e}"
                            );
                            failed.store(true, Ordering::SeqCst);
                            return;
                        }
                    }
                }
                latencies.lock().unwrap().extend(local);
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    if failed.load(Ordering::SeqCst) {
        return 1;
    }
    let latencies = latencies.into_inner().unwrap();
    let (ok, err) = (ok.load(Ordering::Relaxed), err.load(Ordering::Relaxed));
    let p50 = crate::stats::quantile(&latencies, 0.5);
    let p95 = crate::stats::quantile(&latencies, 0.95);
    // Post-run gains, scraped from the server's own stats endpoint
    // (coalescing zero in the health fallback — no executions to save;
    // the carbon block present whenever the pacer is wired).
    let stats = crate::server::HttpClient::connect(addr)
        .ok()
        .and_then(|mut c| c.get("/v2/admission/stats").ok())
        .and_then(|r| r.json().ok());
    let stats_num = |block: &str, key: &str| {
        stats
            .as_ref()
            .and_then(|v| v.get(block).ok())
            .and_then(|b| b.get(key).ok())
            .and_then(|x| x.as_f64().ok())
    };
    let coalesce_hit_rate = stats_num("coalesce", "hit_rate").unwrap_or(0.0);
    let joules_saved = stats_num("coalesce", "joules_saved").unwrap_or(0.0);
    let executions = stats_num("coalesce", "executions").unwrap_or(0.0);
    let carbon = stats.as_ref().and_then(|v| v.get("carbon").ok()).cloned();
    let energy_joules = stats_num("carbon", "energy_joules").unwrap_or(0.0);
    println!(
        "serve-bench[{target}]: {n} round-trips across {conns} keep-alive connection(s) \
         in {:.3} s ({:.0} req/s, p50 {:.1} µs, p95 {:.1} µs), {ok} ok / {err} error responses",
        secs,
        n as f64 / secs,
        p50 * 1e6,
        p95 * 1e6,
    );
    if dup_ratio > 0.0 {
        println!(
            "serve-bench[coalesce]: dup-ratio {dup_ratio:.2}, {executions:.0} executions \
             ({:.0} exec/s), coalesce hit rate {:.1}%, {joules_saved:.3} J saved",
            executions / secs,
            coalesce_hit_rate * 100.0,
        );
    }
    if let Some(run) = scenario {
        println!(
            "serve-bench[scenario {}]: seed {}, {n} requests, {:.4} J/answer, \
             {:.3} g CO₂ total ({:.3} g deferred)",
            run.name,
            run.seed,
            energy_joules / n.max(1) as f64,
            stats_num("carbon", "co2_total_grams").unwrap_or(0.0),
            stats_num("carbon", "co2_deferred_grams").unwrap_or(0.0),
        );
    }
    // Per-tenant admitted rates for the QoS lane (hot tenant first).
    let tenant_rows: Vec<crate::json::Value> = (0..tenants)
        .map(|i| {
            let sent = tenant_sent[i].load(Ordering::Relaxed);
            let okc = tenant_ok[i].load(Ordering::Relaxed);
            let shed = tenant_shed[i].load(Ordering::Relaxed);
            println!(
                "serve-bench[tenant {}]: {sent} sent, {okc} ok ({:.0} admitted/s), {shed} shed",
                tenant_names[i],
                okc as f64 / secs,
            );
            crate::json::obj(vec![
                ("name", crate::json::s(&tenant_names[i])),
                ("requests", crate::json::num(sent as f64)),
                ("ok", crate::json::num(okc as f64)),
                ("shed", crate::json::num(shed as f64)),
                ("admitted_rps", crate::json::num(okc as f64 / secs)),
            ])
        })
        .collect();
    if let Some(path) = json_out {
        let mut fields = vec![
            ("schema", crate::json::s("greenflow.serve-bench/1")),
            ("target", crate::json::s(target)),
            ("model", crate::json::s(model)),
            ("requests", crate::json::num(n as f64)),
            ("connections", crate::json::num(conns as f64)),
            ("dup_ratio", crate::json::num(dup_ratio)),
            ("seconds", crate::json::num(secs)),
            ("throughput_rps", crate::json::num(n as f64 / secs)),
            ("p50_latency_us", crate::json::num(p50 * 1e6)),
            ("p95_latency_us", crate::json::num(p95 * 1e6)),
            ("executions", crate::json::num(executions)),
            ("executions_per_sec", crate::json::num(executions / secs)),
            ("coalesce_hit_rate", crate::json::num(coalesce_hit_rate)),
            ("joules_saved", crate::json::num(joules_saved)),
            ("ok", crate::json::num(ok as f64)),
            ("errors", crate::json::num(err as f64)),
        ];
        if tenants > 0 {
            fields.push(("tenants", crate::json::num(tenants as f64)));
            fields.push(("hot_tenant_share", crate::json::num(hot_share)));
            fields.push(("tenant_stats", crate::json::Value::Arr(tenant_rows)));
        }
        if let Some(run) = scenario {
            fields.push(("scenario", crate::json::s(&run.name)));
            fields.push(("scenario_seed", crate::json::num(run.seed as f64)));
            // Joules per answered request over the whole run — the
            // per-scenario energy figure the CI matrix records (0 in the
            // health fallback, real when a backend executes).
            fields.push((
                "joules_per_answer",
                crate::json::num(energy_joules / n.max(1) as f64),
            ));
        }
        if let Some(c) = carbon {
            fields.push(("carbon", c));
        }
        let report = crate::json::obj(fields);
        if let Err(e) = std::fs::write(path, report.to_json()) {
            eprintln!("serve-bench: cannot write {path}: {e}");
            return 1;
        }
        println!("serve-bench: wrote {path}");
    }
    0
}

fn cmd_ablation(args: &Args) -> i32 {
    let n = args.get_f64("requests").unwrap_or(1000.0) as usize;
    let seed = args.get_f64("seed").unwrap_or(20260710.0) as u64;
    let mut rng = crate::util::Rng::new(seed);
    let mut arr = ArrivalProcess::poisson(args.get_f64("rate").unwrap_or(200.0));
    let times = arrival_times(&mut arr, n, &mut rng);
    let reqs = RequestStream::new(StreamConfig::default(), seed ^ 1).take(&times);

    // `--duplicate-ratio R`: fraction of requests that are exact
    // duplicates of an in-flight one, answered by singleflight
    // coalescing instead of execution (docs/COALESCE.md).
    let dup = args.get_f64("duplicate-ratio").unwrap_or(0.0).clamp(0.0, 1.0);
    let cfg = SimConfig { seed, duplicate_ratio: dup, ..SimConfig::table3_default() };
    let std_report = simulate(&mut OpenLoop, &reqs, &cfg);
    let mut bio = AdmissionController::new(controller_config(args));
    let bio_report = simulate(&mut bio, &reqs, &cfg);
    // Adaptive-τ comparator: servo the admission rate to --adaptive-tau
    // (default: the bio row's realised rate, so the rows stay comparable).
    let target =
        args.get_f64("adaptive-tau").unwrap_or(bio_report.admission_rate()).clamp(0.0, 1.0);
    let mut adaptive = AdaptiveTauPolicy::new(controller_config(args), target, 0.05, 25);
    let adaptive_report = simulate(&mut adaptive, &reqs, &cfg);

    let mut t = crate::benchkit::Table::new(
        "Ablation: controller impact (sim, A100 profile)",
        &["Metric", "Standard", "Bio-Controller", "Delta", "Adaptive-τ"],
    );
    let pct = crate::util::fmt::pct_delta;
    t.row(vec![
        "Total Time (s)".into(),
        format!("{:.3}", std_report.total_busy_secs),
        format!("{:.3}", bio_report.total_busy_secs),
        pct(std_report.total_busy_secs, bio_report.total_busy_secs),
        format!("{:.3}", adaptive_report.total_busy_secs),
    ]);
    t.row(vec![
        "Latency/Req (ms)".into(),
        format!("{:.2}", std_report.latency_per_req * 1e3),
        format!("{:.2}", bio_report.latency_per_req * 1e3),
        pct(std_report.latency_per_req, bio_report.latency_per_req),
        format!("{:.2}", adaptive_report.latency_per_req * 1e3),
    ]);
    t.row(vec![
        "Accuracy".into(),
        format!("{:.1}%", std_report.accuracy * 100.0),
        format!("{:.1}%", bio_report.accuracy * 100.0),
        format!("{:+.1} pp", (bio_report.accuracy - std_report.accuracy) * 100.0),
        format!("{:.1}%", adaptive_report.accuracy * 100.0),
    ]);
    t.row(vec![
        "Admission Rate".into(),
        "100%".into(),
        format!("{:.0}%", bio_report.admission_rate() * 100.0),
        pct(1.0, bio_report.admission_rate()),
        format!("{:.0}% (target {:.0}%)", adaptive_report.admission_rate() * 100.0, target * 100.0),
    ]);
    t.row(vec![
        "Energy (kWh)".into(),
        format!("{:.6}", std_report.energy_kwh),
        format!("{:.6}", bio_report.energy_kwh),
        pct(std_report.energy_kwh, bio_report.energy_kwh),
        format!("{:.6}", adaptive_report.energy_kwh),
    ]);
    if dup > 0.0 {
        t.row(vec![
            "Coalesced".into(),
            format!("{}", std_report.coalesced),
            format!("{}", bio_report.coalesced),
            format!("{:+}", bio_report.coalesced as i64 - std_report.coalesced as i64),
            format!("{}", adaptive_report.coalesced),
        ]);
        t.row(vec![
            "Energy/Answer (J)".into(),
            format!("{:.4}", std_report.energy_per_answer()),
            format!("{:.4}", bio_report.energy_per_answer()),
            pct(std_report.energy_per_answer(), bio_report.energy_per_answer()),
            format!("{:.4}", adaptive_report.energy_per_answer()),
        ]);
    }
    print!("{}", t.render());
    0
}

/// Read a whole JSON file (perfgate inputs).
fn read_json_file(path: &str) -> Result<crate::json::Value, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    crate::json::parse(&text).map_err(|e| format!("{path}: {e}"))
}

/// A baseline field: a number to gate against, or null/absent = not
/// pinned yet (the check is skipped and the measured value printed so
/// the operator can pin it).
fn baseline_field(v: &crate::json::Value, key: &str) -> Option<f64> {
    v.get(key).ok().and_then(|x| x.as_f64().ok())
}

/// `greenflow perfgate`: assemble the `BENCH_*.json` perf snapshot and
/// gate it against a committed baseline (the CI perf gate — see
/// `docs/BENCH.md`).
///
/// ```text
/// greenflow perfgate --serve-json serve_bench.json [--micro-json micro.json]
///                    [--serve-hc-json serve_bench_hc.json]
///                    [--serve-dup-json serve_bench_dup.json]
///                    [--serve-tenant-json serve_bench_tenant.json]
///                    [--serve-flash-json serve_bench_flash.json]
///                    [--serve-diurnal-json serve_bench_diurnal.json]
///                    --out BENCH_6.json [--label pr6]
///                    [--baseline benches/baseline.json] [--max-regress 0.20]
///                    [--requests 2000]
/// ```
///
/// Inputs: the `--bench-json` output of `greenflow serve --serve-bench`
/// (HTTP round-trip throughput + latency percentiles), optionally a
/// second high-concurrency run (`--bench-conns 256 --bench-json
/// serve_bench_hc.json`, passed as `--serve-hc-json`) gated as
/// `hc_throughput_rps`, optionally a duplicate-heavy run
/// (`--bench-dup-ratio 0.8`, passed as `--serve-dup-json`) embedded as
/// `serve_bench_dup`, optionally a tenant-tagged run (`--bench-tenants`,
/// passed as `--serve-tenant-json`) embedded as `serve_bench_tenant`
/// with its per-tenant admitted-rate fields, and optionally
/// the `--json` output of `cargo bench --bench micro_hotpath`
/// (per-component timings, embedded verbatim). Six gated numbers are
/// measured in-process so the gate has no backend dependency: the
/// `Adaptive<T>` hot-path read (ns), the replica-scheduler
/// power-of-two-choices pick (`sched_read_ns`), the sharded
/// response-cache probe (`cache_read_ns` — the per-request cost the
/// coalescing subsystem added to every submit), the per-tenant QoS
/// admission decide (`qos_decide_ns` — the gate every infer pays in
/// front of the admission controller), the cold-start
/// lifecycle-executor round-trip (`cold_start_ms`, engine compile
/// excluded), and the deterministic admission-sim admit rate. When a
/// serve-bench input carries coalescing gains (the `--serve-dup-json`
/// report preferred, else the main one), `coalesce_hit_rate` and
/// `joules_saved` are recorded in the
/// snapshot (never gated — they depend on the duplicate mix).
///
/// The scenario-matrix lanes (`--scenario flash-crowd` / `--scenario
/// diurnal` serve-bench runs, passed as `--serve-flash-json` /
/// `--serve-diurnal-json`) are embedded as `serve_bench_flash_crowd` /
/// `serve_bench_diurnal`; their p95s surface as `flash_crowd_p95_ms`
/// (the pinned tail-latency gate) and `diurnal_p95_ms`, and each run's
/// `joules_per_answer` is recorded per scenario. Exits 1
/// when any pinned baseline regresses by more than `--max-regress`
/// (direction-aware: throughput may not drop, latency and read/dispatch
/// costs may not grow, admit rate may not drift either way). When CI
/// exposes `GITHUB_STEP_SUMMARY`, the per-metric delta table is also
/// appended there as markdown.
fn cmd_perfgate(args: &Args) -> i32 {
    use crate::json::{self, Value};

    let Some(serve_path) = args.get("serve-json") else {
        eprintln!("perfgate needs --serve-json <serve_bench.json>");
        return 2;
    };
    let serve = match read_json_file(&serve_path) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("perfgate: {e}");
            return 1;
        }
    };
    let serve_num = |key: &str| serve.get(key).ok().and_then(|v| v.as_f64().ok());
    let (Some(throughput), Some(p50_us), Some(p95_us)) = (
        serve_num("throughput_rps"),
        serve_num("p50_latency_us"),
        serve_num("p95_latency_us"),
    ) else {
        eprintln!("perfgate: {serve_path} is missing throughput/latency fields");
        return 1;
    };
    // Optional high-concurrency serve-bench (`--bench-conns 256` run):
    // gates aggregate connection-scaling throughput as a Floor. Absent
    // = not gated (keeps single-connection invocations working).
    let serve_hc = match args.get("serve-hc-json") {
        Some(p) => match read_json_file(&p) {
            Ok(v) => Some(v),
            Err(e) => {
                eprintln!("perfgate: {e}");
                return 1;
            }
        },
        None => None,
    };
    let hc_throughput = serve_hc
        .as_ref()
        .and_then(|v| v.get("throughput_rps").ok().and_then(|x| x.as_f64().ok()));
    if serve_hc.is_some() && hc_throughput.is_none() {
        eprintln!("perfgate: --serve-hc-json input is missing throughput_rps");
        return 1;
    }
    // Optional duplicate-heavy serve-bench (`--bench-dup-ratio` run):
    // embedded verbatim, and preferred as the source of the recorded
    // coalescing gains (the plain run has no duplicates to coalesce).
    let serve_dup = match args.get("serve-dup-json") {
        Some(p) => match read_json_file(&p) {
            Ok(v) => Some(v),
            Err(e) => {
                eprintln!("perfgate: {e}");
                return 1;
            }
        },
        None => None,
    };
    // Optional tenant-tagged serve-bench (`--bench-tenants` run): the
    // QoS hot-tenant lane, embedded verbatim with its per-tenant
    // admitted-rate fields (never gated — they depend on the share
    // knob and the configured quotas).
    let serve_tenant = match args.get("serve-tenant-json") {
        Some(p) => match read_json_file(&p) {
            Ok(v) => Some(v),
            Err(e) => {
                eprintln!("perfgate: {e}");
                return 1;
            }
        },
        None => None,
    };
    // Optional scenario-matrix serve-benches (`--scenario flash-crowd`
    // / `--scenario diurnal` runs): flash-crowd p95 is the pinned
    // tail-latency gate, diurnal p95 and both joules-per-answer figures
    // are recorded.
    let read_optional = |flag: &str| -> Result<Option<Value>, i32> {
        match args.get(flag) {
            Some(p) => match read_json_file(&p) {
                Ok(v) => Ok(Some(v)),
                Err(e) => {
                    eprintln!("perfgate: {e}");
                    Err(1)
                }
            },
            None => Ok(None),
        }
    };
    let serve_flash = match read_optional("serve-flash-json") {
        Ok(v) => v,
        Err(code) => return code,
    };
    let serve_diurnal = match read_optional("serve-diurnal-json") {
        Ok(v) => v,
        Err(code) => return code,
    };
    let scen_num = |v: &Option<Value>, key: &str| {
        v.as_ref().and_then(|x| x.get(key).ok()).and_then(|x| x.as_f64().ok())
    };
    let flash_p95_ms = scen_num(&serve_flash, "p95_latency_us").map(|us| us / 1e3);
    if serve_flash.is_some() && flash_p95_ms.is_none() {
        eprintln!("perfgate: --serve-flash-json input is missing p95_latency_us");
        return 1;
    }
    let diurnal_p95_ms = scen_num(&serve_diurnal, "p95_latency_us").map(|us| us / 1e3);
    if serve_diurnal.is_some() && diurnal_p95_ms.is_none() {
        eprintln!("perfgate: --serve-diurnal-json input is missing p95_latency_us");
        return 1;
    }
    let flash_jpa = scen_num(&serve_flash, "joules_per_answer");
    let diurnal_jpa = scen_num(&serve_diurnal, "joules_per_answer");
    let components = match args.get("micro-json") {
        Some(p) => match read_json_file(&p) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("perfgate: {e}");
                return 1;
            }
        },
        None => Value::Null,
    };

    // Adaptive<T> hot-path read, measured right here: the control
    // plane's promise is that adaptive knobs cost ~a plain load on the
    // request path (includes ~Instant::now() timer overhead, same as
    // micro_hotpath).
    let adaptive = crate::control::Adaptive::new(0.51f64);
    let mut acc = 0.0f64;
    let r = crate::benchkit::bench_fn("adaptive_f64.get", 1000, 200_000, || {
        acc += std::hint::black_box(&adaptive).get();
    });
    std::hint::black_box(acc);
    let adaptive_read_ns = r.mean() * 1e9;

    // Replica-scheduler read, measured in-process like the adaptive
    // read: one power-of-two-choices ticket hash plus two per-replica
    // load probes — the cost the replica-set redesign added to every
    // request. No engines involved, so the number is hermetic.
    let sched_read_ns = {
        use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
        let loads: Vec<AtomicUsize> = (0..4usize).map(AtomicUsize::new).collect();
        let ticket = AtomicU64::new(0);
        let mut acc_s = 0usize;
        let r = crate::benchkit::bench_fn("sched.p2c_pick", 1000, 200_000, || {
            let t = ticket.fetch_add(1, Ordering::Relaxed);
            let (i, j) = crate::pipeline::p2c_indices(t, loads.len());
            let a = loads[i].load(Ordering::Relaxed);
            let b = loads[j].load(Ordering::Relaxed);
            acc_s += if b < a { b } else { a };
        });
        std::hint::black_box(acc_s);
        r.mean() * 1e9
    };

    // Sharded response-cache probe, measured in-process: signature
    // hash + shard pick + one shard-lock get — the cost the coalescing
    // subsystem's cache consult adds to every submit. Populated so the
    // probe exercises real hits, like the serving steady state.
    let cache_read_ns = {
        use crate::controller::cache::{CachedResponse, ResponseCache};
        let cache = crate::pipeline::ShardedResponseCache::new(4096);
        for seed in 0..1024u64 {
            cache.put(
                ResponseCache::signature("perfgate", 1, seed, 1024),
                CachedResponse { label: seed as u32, confidence: 0.9 },
            );
        }
        let mut next = 0u64;
        let mut acc_c = 0u64;
        let r = crate::benchkit::bench_fn("cache.sharded_get", 1000, 200_000, || {
            let sig = ResponseCache::signature("perfgate", 1, next, 1024);
            next = (next + 1) & 1023;
            if let Some(hit) = std::hint::black_box(&cache).get(sig) {
                acc_c += hit.label as u64;
            }
        });
        std::hint::black_box(acc_c);
        r.mean() * 1e9
    };

    // Per-tenant QoS admission decide, measured in-process: one shard
    // read-lock, one tenant-mutex GCRA step, and the counter bumps —
    // the gate every infer request pays before the admission
    // controller. The quota sits far above the bench rate so every
    // decide admits; sheds leave the hot path by definition.
    let qos_decide_ns = {
        use crate::qos::{QosConfig, QosLayer};
        let layer = QosLayer::new(QosConfig {
            default_rate_rps: 1_000_000_000,
            default_burst: 1_000_000,
            ..QosConfig::default()
        });
        let mut t_q = 0.0f64;
        let r = crate::benchkit::bench_fn("qos.decide", 1000, 200_000, || {
            t_q += 1e-6;
            std::hint::black_box(layer.decide("perfgate", 1, 0, t_q));
        });
        r.mean() * 1e9
    };

    // Cold-start orchestration overhead: the lifecycle-executor
    // round-trip a wake-up from zero replicas pays *before* any engine
    // work (submit → worker pickup → completion). Engine compile time
    // is deliberately excluded — it belongs to the backend, not to the
    // scale-to-zero machinery this gate guards.
    let cold_start_ms = {
        use crate::runtime::lifecycle::{JobKind, LifecycleExecutor};
        let exec = LifecycleExecutor::start(1, 16);
        let iters = 200usize;
        let t0 = std::time::Instant::now();
        for i in 0..iters {
            let (tx, rx) = std::sync::mpsc::channel();
            exec.submit(
                "perfgate",
                i as u64,
                JobKind::Scale,
                Box::new(move || {
                    let _ = tx.send(());
                }),
                Box::new(|| {}),
            )
            .expect("scale jobs bypass the queue bound");
            let _ = rx.recv();
        }
        t0.elapsed().as_secs_f64() * 1e3 / iters as f64
    };

    // Deterministic admission-rate sim (fixed seed + default controller
    // schedule): catches regressions in the J(x)/τ(t) decision logic
    // itself, independent of machine speed.
    let n = args.get_f64("requests").unwrap_or(2000.0).max(1.0) as usize;
    let seed = 20260710u64;
    let mut rng = crate::util::Rng::new(seed);
    let mut arr = ArrivalProcess::poisson(200.0);
    let times = arrival_times(&mut arr, n, &mut rng);
    let reqs = RequestStream::new(StreamConfig::default(), seed ^ 1).take(&times);
    let sim_cfg = SimConfig { seed, ..SimConfig::table3_default() };
    let mut bio = AdmissionController::new(controller_config(args));
    let admit_rate = simulate(&mut bio, &reqs, &sim_cfg).admission_rate();

    let label = args.get("label").unwrap_or_else(|| "bench".to_string());
    // Coalescing gains: from the duplicate-heavy run when one was
    // passed, else from the main serve-bench run (present when it was
    // a `--bench-dup-ratio` run; recorded, never gated).
    let dup_num = |key: &str| {
        serve_dup
            .as_ref()
            .and_then(|v| v.get(key).ok().and_then(|x| x.as_f64().ok()))
    };
    let coalesce_hit_rate = dup_num("coalesce_hit_rate").or_else(|| serve_num("coalesce_hit_rate"));
    let joules_saved = dup_num("joules_saved").or_else(|| serve_num("joules_saved"));
    let mut fields = vec![
        ("schema", json::s("greenflow.bench/1")),
        ("label", json::s(&label)),
        ("throughput_rps", json::num(throughput)),
        ("p50_latency_us", json::num(p50_us)),
        ("p95_latency_us", json::num(p95_us)),
        ("admit_rate", json::num(admit_rate)),
        ("adaptive_read_ns", json::num(adaptive_read_ns)),
        ("sched_read_ns", json::num(sched_read_ns)),
        ("cache_read_ns", json::num(cache_read_ns)),
        ("qos_decide_ns", json::num(qos_decide_ns)),
        ("cold_start_ms", json::num(cold_start_ms)),
    ];
    if let Some(v) = coalesce_hit_rate {
        fields.push(("coalesce_hit_rate", json::num(v)));
    }
    if let Some(v) = joules_saved {
        fields.push(("joules_saved", json::num(v)));
    }
    if let Some(hc) = hc_throughput {
        fields.push(("hc_throughput_rps", json::num(hc)));
    }
    if let Some(v) = flash_p95_ms {
        fields.push(("flash_crowd_p95_ms", json::num(v)));
    }
    if let Some(v) = flash_jpa {
        fields.push(("flash_crowd_joules_per_answer", json::num(v)));
    }
    if let Some(v) = diurnal_p95_ms {
        fields.push(("diurnal_p95_ms", json::num(v)));
    }
    if let Some(v) = diurnal_jpa {
        fields.push(("diurnal_joules_per_answer", json::num(v)));
    }
    fields.push(("serve_bench", serve));
    if let Some(hc) = serve_hc {
        fields.push(("serve_bench_hc", hc));
    }
    if let Some(dup) = serve_dup {
        fields.push(("serve_bench_dup", dup));
    }
    if let Some(tenant) = serve_tenant {
        fields.push(("serve_bench_tenant", tenant));
    }
    if let Some(flash) = serve_flash {
        fields.push(("serve_bench_flash_crowd", flash));
    }
    if let Some(diurnal) = serve_diurnal {
        fields.push(("serve_bench_diurnal", diurnal));
    }
    fields.push(("components", components));
    let bench = json::obj(fields);
    let out = args.get("out").unwrap_or_else(|| "BENCH.json".to_string());
    if let Err(e) = std::fs::write(&out, bench.to_json()) {
        eprintln!("perfgate: cannot write {out}: {e}");
        return 1;
    }
    println!("perfgate: wrote {out}");

    let Some(baseline_path) = args.get("baseline") else {
        println!("perfgate: no --baseline, nothing gated");
        return 0;
    };
    let baseline = match read_json_file(&baseline_path) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("perfgate: {e}");
            return 1;
        }
    };
    let r = args.get_f64("max-regress").unwrap_or(0.20).clamp(0.0, 10.0);

    // (metric, measured, pinned baseline, check kind)
    enum Gate {
        /// Regression = dropping below baseline × (1 − r).
        Floor,
        /// Regression = rising above baseline × (1 + r).
        Ceiling,
        /// Regression = drifting from baseline by more than r either way.
        Drift,
    }
    let mut checks = vec![
        ("throughput_rps", throughput, Gate::Floor),
        ("p50_latency_us", p50_us, Gate::Ceiling),
        ("p95_latency_us", p95_us, Gate::Ceiling),
        ("admit_rate", admit_rate, Gate::Drift),
        ("adaptive_read_ns", adaptive_read_ns, Gate::Ceiling),
        ("sched_read_ns", sched_read_ns, Gate::Ceiling),
        ("cache_read_ns", cache_read_ns, Gate::Ceiling),
        ("qos_decide_ns", qos_decide_ns, Gate::Ceiling),
        ("cold_start_ms", cold_start_ms, Gate::Ceiling),
    ];
    if let Some(hc) = hc_throughput {
        checks.push(("hc_throughput_rps", hc, Gate::Floor));
    }
    if let Some(v) = flash_p95_ms {
        checks.push(("flash_crowd_p95_ms", v, Gate::Ceiling));
    }
    if let Some(v) = diurnal_p95_ms {
        checks.push(("diurnal_p95_ms", v, Gate::Ceiling));
    }
    // (metric, measured, Some((baseline, delta %, ok)) when pinned).
    let mut rows: Vec<(&str, f64, Option<(f64, f64, bool)>)> = Vec::new();
    let mut failed = false;
    for (name, measured, gate) in checks {
        let Some(base) = baseline_field(&baseline, name) else {
            println!("  {name:<20} {measured:>12.3}  (baseline unpinned — recorded only)");
            rows.push((name, measured, None));
            continue;
        };
        let ok = match gate {
            Gate::Floor => measured >= base * (1.0 - r),
            Gate::Ceiling => measured <= base * (1.0 + r),
            Gate::Drift => (measured - base).abs() <= r * base.abs().max(1e-9),
        };
        let delta_pct = if base.abs() > 1e-12 { (measured - base) / base * 100.0 } else { 0.0 };
        println!(
            "  {name:<20} {measured:>12.3}  vs baseline {base:>12.3}  ({delta_pct:>+7.1}%)  [{}]",
            if ok { "ok" } else { "REGRESSION" }
        );
        rows.push((name, measured, Some((base, delta_pct, ok))));
        if !ok {
            failed = true;
        }
    }
    // Mirror the per-metric delta table into the GitHub job summary when
    // CI exposes the well-known file (no-op locally).
    if let Ok(summary_path) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        let mut md = format!("### perfgate — {label} (budget ±{:.0}%)\n\n", r * 100.0);
        md.push_str("| metric | measured | baseline | Δ | status |\n");
        md.push_str("|---|---:|---:|---:|---|\n");
        for (name, measured, pinned) in &rows {
            match pinned {
                Some((base, delta, ok)) => md.push_str(&format!(
                    "| {name} | {measured:.3} | {base:.3} | {delta:+.1}% | {} |\n",
                    if *ok { "ok" } else { "**REGRESSION**" }
                )),
                None => {
                    md.push_str(&format!("| {name} | {measured:.3} | — | — | recorded |\n"))
                }
            }
        }
        md.push('\n');
        let appended = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&summary_path)
            .and_then(|mut f| f.write_all(md.as_bytes()));
        if let Err(e) = appended {
            eprintln!("perfgate: cannot append job summary {summary_path}: {e}");
        }
    }
    if failed {
        eprintln!(
            "perfgate: regression past the {:.0}% budget against {baseline_path}",
            r * 100.0
        );
        1
    } else {
        println!("perfgate: within the {:.0}% budget of {baseline_path}", r * 100.0);
        0
    }
}

fn cmd_landscape(args: &Args) -> i32 {
    let pts = crate::sim::landscape::sample_surface(
        args.get_f64("samples").unwrap_or(200.0) as usize
    );
    println!("s,j");
    for p in &pts {
        println!("{:.4},{:.5}", p.s, p.j);
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn version_runs() {
        assert_eq!(run(&sv(&["version"])), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(&sv(&["frobnicate"])), 2);
        assert_eq!(run(&[]), 2);
    }

    #[test]
    fn repo_subcommand_validates_arguments() {
        // Missing operation / unknown operation / missing --model are
        // usage errors before any connection is attempted.
        assert_eq!(run(&sv(&["repo"])), 2);
        assert_eq!(run(&sv(&["repo", "frobnicate"])), 2);
        assert_eq!(run(&sv(&["repo", "load"])), 2);
        assert_eq!(run(&sv(&["repo", "load", "--model", "m", "--version", "0"])), 2);
        assert_eq!(run(&sv(&["repo", "index", "--addr", "not-an-addr"])), 2);
    }

    #[test]
    fn serve_rejects_unknown_model_control() {
        assert_eq!(run(&sv(&["serve", "--model-control", "frobnicate"])), 2);
    }

    #[test]
    fn serve_rejects_bad_scenario_flags() {
        // Unknown built-in, scenario without bench mode, and a missing
        // carbon trace are all usage errors before anything binds.
        assert_eq!(
            run(&sv(&["serve", "--serve-bench", "10", "--scenario", "no-such-scenario"])),
            2
        );
        assert_eq!(run(&sv(&["serve", "--scenario", "diurnal"])), 2);
        assert_eq!(
            run(&sv(&["serve", "--carbon-trace", "/nonexistent/trace.csv"])),
            2
        );
    }

    #[test]
    fn ablation_runs_in_sim() {
        assert_eq!(run(&sv(&["ablation", "--requests", "200"])), 0);
    }

    #[test]
    fn ablation_with_explicit_adaptive_target() {
        assert_eq!(
            run(&sv(&["ablation", "--requests", "300", "--adaptive-tau", "0.7"])),
            0
        );
    }

    #[test]
    fn ablation_with_duplicate_ratio() {
        assert_eq!(
            run(&sv(&["ablation", "--requests", "300", "--duplicate-ratio", "0.5"])),
            0
        );
    }

    #[test]
    fn control_config_from_flags() {
        let a = Args::parse(&sv(&[
            "--adaptive-tau",
            "0.6",
            "--adaptive-delay",
            "--energy-budget",
            "75",
            "--tick-ms",
            "50",
        ]))
        .unwrap();
        let c = control_config(&a, 0.1).expect("loops requested");
        assert_eq!(c.tick_secs, 0.05);
        assert_eq!(c.adaptive_tau.as_ref().unwrap().target_admit_rate, 0.6);
        assert_eq!(c.adaptive_batch_delay.as_ref().unwrap().slo_p95_secs, 0.1);
        assert!(c.adaptive_router.is_none());
        assert_eq!(c.energy_budget.as_ref().unwrap().budget_watts, 75.0);
        assert!(control_config(&Args::parse(&[]).unwrap(), 0.1).is_none());
    }

    #[test]
    fn control_config_carbon_flags() {
        // Explicit threshold.
        let a = Args::parse(&sv(&["--carbon-pacer", "0.2"])).unwrap();
        let c = control_config(&a, 0.1).expect("pacer requested");
        assert_eq!(c.carbon_pacer.as_ref().unwrap().threshold_kg_per_kwh, 0.2);
        // A trace alone implies the pacer at the default threshold.
        let a = Args::parse(&sv(&["--carbon-trace", "grid.csv"])).unwrap();
        let c = control_config(&a, 0.1).expect("trace implies pacer");
        assert_eq!(
            c.carbon_pacer.as_ref().unwrap().threshold_kg_per_kwh,
            crate::control::CarbonPacerConfig::default().threshold_kg_per_kwh
        );
    }

    #[test]
    fn landscape_emits_csv() {
        assert_eq!(run(&sv(&["landscape", "--samples", "50"])), 0);
    }

    #[test]
    fn perfgate_assembles_and_gates() {
        let dir = std::env::temp_dir().join(format!(
            "gf-perfgate-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let serve = dir.join("serve_bench.json");
        std::fs::write(
            &serve,
            r#"{"schema": "greenflow.serve-bench/1", "target": "health",
                "throughput_rps": 5000.0, "p50_latency_us": 100.0,
                "p95_latency_us": 400.0, "dup_ratio": 0.8,
                "coalesce_hit_rate": 0.75, "joules_saved": 12.5,
                "ok": 100, "errors": 0}"#,
        )
        .unwrap();
        let out = dir.join("BENCH_test.json");

        // Missing input is a usage error; bad path a runtime error.
        assert_eq!(run(&sv(&["perfgate"])), 2);
        assert_eq!(run(&sv(&["perfgate", "--serve-json", "/nonexistent.json"])), 1);

        // No baseline: snapshot written, nothing gated.
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            0
        );
        let bench = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(
            bench.get("schema").unwrap().as_str().unwrap(),
            "greenflow.bench/1"
        );
        assert_eq!(bench.get("throughput_rps").unwrap().as_f64().unwrap(), 5000.0);
        let admit = bench.get("admit_rate").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&admit), "{admit}");
        assert!(bench.get("adaptive_read_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(bench.get("sched_read_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(bench.get("cache_read_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(bench.get("qos_decide_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(bench.get("cold_start_ms").unwrap().as_f64().unwrap() > 0.0);
        // Coalescing gains pass through from the serve-bench input.
        assert_eq!(bench.get("coalesce_hit_rate").unwrap().as_f64().unwrap(), 0.75);
        assert_eq!(bench.get("joules_saved").unwrap().as_f64().unwrap(), 12.5);

        // Generous baseline passes; an impossible throughput floor fails;
        // unpinned (null) fields are recorded but never gated.
        let good = dir.join("baseline_good.json");
        std::fs::write(
            &good,
            r#"{"throughput_rps": 4500.0, "p50_latency_us": 120.0,
                "p95_latency_us": 480.0, "admit_rate": null,
                "adaptive_read_ns": null}"#,
        )
        .unwrap();
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--baseline",
                good.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            0
        );
        let bad = dir.join("baseline_bad.json");
        std::fs::write(&bad, r#"{"throughput_rps": 1e9}"#).unwrap();
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--baseline",
                bad.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            1
        );

        // High-concurrency input: recorded as hc_throughput_rps and
        // gated as a Floor when the baseline pins it.
        let serve_hc = dir.join("serve_bench_hc.json");
        std::fs::write(
            &serve_hc,
            r#"{"schema": "greenflow.serve-bench/1", "target": "health",
                "connections": 256, "throughput_rps": 9000.0,
                "p50_latency_us": 900.0, "p95_latency_us": 3000.0}"#,
        )
        .unwrap();
        let good_hc = dir.join("baseline_good_hc.json");
        std::fs::write(
            &good_hc,
            r#"{"throughput_rps": 4500.0, "hc_throughput_rps": 8000.0}"#,
        )
        .unwrap();
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--serve-hc-json",
                serve_hc.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--baseline",
                good_hc.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            0
        );
        let bench = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(bench.get("hc_throughput_rps").unwrap().as_f64().unwrap(), 9000.0);
        assert!(bench.get("serve_bench_hc").is_ok());
        let bad_hc = dir.join("baseline_bad_hc.json");
        std::fs::write(&bad_hc, r#"{"hc_throughput_rps": 1e9}"#).unwrap();
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--serve-hc-json",
                serve_hc.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--baseline",
                bad_hc.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            1
        );

        // Duplicate-heavy input: embedded as serve_bench_dup, and its
        // coalescing numbers take precedence over the main report's.
        let serve_dup = dir.join("serve_bench_dup.json");
        std::fs::write(
            &serve_dup,
            r#"{"schema": "greenflow.serve-bench/1", "target": "health",
                "connections": 64, "throughput_rps": 7000.0,
                "p50_latency_us": 150.0, "p95_latency_us": 600.0,
                "dup_ratio": 0.8, "coalesce_hit_rate": 0.6,
                "joules_saved": 33.0}"#,
        )
        .unwrap();
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--serve-dup-json",
                serve_dup.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            0
        );
        let bench = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(bench.get("coalesce_hit_rate").unwrap().as_f64().unwrap(), 0.6);
        assert_eq!(bench.get("joules_saved").unwrap().as_f64().unwrap(), 33.0);
        assert!(bench.get("serve_bench_dup").is_ok());

        // Tenant-tagged input: embedded verbatim as serve_bench_tenant
        // (the hot-tenant lane's per-tenant fields ride along ungated).
        let serve_tenant = dir.join("serve_bench_tenant.json");
        std::fs::write(
            &serve_tenant,
            r#"{"schema": "greenflow.serve-bench/1", "target": "health",
                "tenants": 4, "hot_tenant_share": 0.7,
                "throughput_rps": 6000.0,
                "tenant_stats": [{"name": "t0", "requests": 140.0,
                                  "ok": 140.0, "shed": 0.0,
                                  "admitted_rps": 4200.0}]}"#,
        )
        .unwrap();
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--serve-tenant-json",
                serve_tenant.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            0
        );
        let bench = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let embedded = bench.get("serve_bench_tenant").unwrap();
        assert_eq!(embedded.get("tenants").unwrap().as_f64().unwrap(), 4.0);
        let rows = embedded.get("tenant_stats").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("name").unwrap().as_str().unwrap(), "t0");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn perfgate_scenario_lanes_gate_flash_p95() {
        let dir = std::env::temp_dir().join(format!(
            "gf-perfgate-scen-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let serve = dir.join("serve_bench.json");
        std::fs::write(
            &serve,
            r#"{"schema": "greenflow.serve-bench/1", "target": "health",
                "throughput_rps": 5000.0, "p50_latency_us": 100.0,
                "p95_latency_us": 400.0}"#,
        )
        .unwrap();
        let flash = dir.join("serve_bench_flash.json");
        std::fs::write(
            &flash,
            r#"{"schema": "greenflow.serve-bench/1", "target": "health",
                "scenario": "flash-crowd", "scenario_seed": 539232264,
                "throughput_rps": 4000.0, "p50_latency_us": 200.0,
                "p95_latency_us": 2500.0, "joules_per_answer": 0.012}"#,
        )
        .unwrap();
        let diurnal = dir.join("serve_bench_diurnal.json");
        std::fs::write(
            &diurnal,
            r#"{"schema": "greenflow.serve-bench/1", "target": "health",
                "scenario": "diurnal", "scenario_seed": 539232264,
                "throughput_rps": 4500.0, "p50_latency_us": 150.0,
                "p95_latency_us": 1200.0, "joules_per_answer": 0.010}"#,
        )
        .unwrap();
        let out = dir.join("BENCH_test.json");
        let base_args = [
            "perfgate",
            "--serve-json",
            serve.to_str().unwrap(),
            "--serve-flash-json",
            flash.to_str().unwrap(),
            "--serve-diurnal-json",
            diurnal.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
            "--requests",
            "200",
        ];

        // No baseline: per-scenario metrics recorded, reports embedded.
        assert_eq!(run(&sv(&base_args)), 0);
        let bench = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(bench.get("flash_crowd_p95_ms").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(bench.get("diurnal_p95_ms").unwrap().as_f64().unwrap(), 1.2);
        assert_eq!(
            bench.get("flash_crowd_joules_per_answer").unwrap().as_f64().unwrap(),
            0.012
        );
        assert_eq!(
            bench.get("diurnal_joules_per_answer").unwrap().as_f64().unwrap(),
            0.010
        );
        assert_eq!(
            bench
                .get("serve_bench_flash_crowd")
                .unwrap()
                .get("scenario")
                .unwrap()
                .as_str()
                .unwrap(),
            "flash-crowd"
        );
        assert!(bench.get("serve_bench_diurnal").is_ok());

        // Generous flash pin passes (diurnal left unpinned = recorded);
        // a tight pin fails the gate.
        let good = dir.join("baseline_good.json");
        std::fs::write(&good, r#"{"flash_crowd_p95_ms": 3.0, "diurnal_p95_ms": null}"#)
            .unwrap();
        let with_baseline = |b: &std::path::Path| {
            let mut v = sv(&base_args);
            v.push("--baseline".to_string());
            v.push(b.to_str().unwrap().to_string());
            v
        };
        assert_eq!(run(&with_baseline(&good)), 0);
        let bad = dir.join("baseline_bad.json");
        std::fs::write(&bad, r#"{"flash_crowd_p95_ms": 1.0}"#).unwrap();
        assert_eq!(run(&with_baseline(&bad)), 1);

        // A scenario input without latency fields is a runtime error.
        let broken = dir.join("broken.json");
        std::fs::write(&broken, r#"{"schema": "greenflow.serve-bench/1"}"#).unwrap();
        assert_eq!(
            run(&sv(&[
                "perfgate",
                "--serve-json",
                serve.to_str().unwrap(),
                "--serve-flash-json",
                broken.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
                "--requests",
                "200",
            ])),
            1
        );
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn serve_bench_scenario_replay_round_trips() {
        // Hermetic: `--model-control explicit` loads nothing, so the
        // bench degrades to health round-trips — but the scenario
        // resolution, trace save, file replay, and the carbon block on
        // the report are all exercised end-to-end (the CI lane's shape).
        let dir = std::env::temp_dir().join(format!(
            "gf-scenario-bench-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("benches/fixtures/bench_repo");
        let trace_csv = dir.join("grid.csv");
        std::fs::write(&trace_csv, "t_secs,kg_co2_per_kwh\n0,0.475\n30,0.056\n").unwrap();
        let scenario_out = dir.join("flash_trace.csv");
        let report_path = dir.join("bench.json");
        assert_eq!(
            run(&sv(&[
                "serve",
                "--repo",
                repo.to_str().unwrap(),
                "--model-control",
                "explicit",
                "--serve-bench",
                "30",
                "--bench-conns",
                "3",
                "--scenario",
                "flash-crowd",
                "--carbon-pacer",
                "0.35",
                "--carbon-trace",
                trace_csv.to_str().unwrap(),
                "--scenario-out",
                scenario_out.to_str().unwrap(),
                "--bench-json",
                report_path.to_str().unwrap(),
            ])),
            0
        );
        let report =
            crate::json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
        assert_eq!(report.get("scenario").unwrap().as_str().unwrap(), "flash-crowd");
        assert_eq!(
            report.get("scenario_seed").unwrap().as_f64().unwrap(),
            crate::workload::scenario::DEFAULT_SEED as f64
        );
        assert!(report.get("joules_per_answer").unwrap().as_f64().is_ok());
        // The pacer was wired, so the gateway's carbon accounting rides
        // along; the trace opens at the world-average intensity.
        let carbon = report.get("carbon").unwrap();
        assert_eq!(carbon.get("intensity_kg_per_kwh").unwrap().as_f64().unwrap(), 0.475);
        // The saved trace is exactly the resolved scenario prefix…
        let saved = crate::workload::trace::load(&scenario_out).unwrap();
        let resolved = crate::workload::scenario::resolve(
            "flash-crowd",
            30,
            crate::workload::scenario::DEFAULT_SEED,
        )
        .unwrap();
        assert_eq!(saved.len(), 30);
        for (a, b) in saved.iter().zip(&resolved.requests) {
            assert_eq!(a.seed, b.seed);
        }
        // …and replays through the file: spec.
        assert_eq!(
            run(&sv(&[
                "serve",
                "--repo",
                repo.to_str().unwrap(),
                "--model-control",
                "explicit",
                "--serve-bench",
                "30",
                "--scenario",
                &format!("file:{}", scenario_out.display()),
            ])),
            0
        );
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn report_fails_gracefully_without_repo() {
        assert_eq!(run(&sv(&["report", "--repo", "/nonexistent"])), 1);
    }

    #[test]
    fn report_ok_with_artifacts() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if root.join("repository.json").exists() {
            assert_eq!(run(&sv(&["report", "--repo", root.to_str().unwrap()])), 0);
        }
    }

    #[test]
    fn serve_bench_round_trips_with_artifacts() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("repository.json").exists() {
            return;
        }
        // Ephemeral port, 10 round-trips over one keep-alive connection.
        assert_eq!(
            run(&sv(&[
                "serve",
                "--repo",
                root.to_str().unwrap(),
                "--serve-bench",
                "10",
            ])),
            0
        );
        // And spread over 4 concurrent connections.
        assert_eq!(
            run(&sv(&[
                "serve",
                "--repo",
                root.to_str().unwrap(),
                "--serve-bench",
                "40",
                "--bench-conns",
                "4",
            ])),
            0
        );
        // Duplicate-heavy mix: exercises the singleflight coalescing
        // path end-to-end (hot seed shared across connections).
        assert_eq!(
            run(&sv(&[
                "serve",
                "--repo",
                root.to_str().unwrap(),
                "--serve-bench",
                "40",
                "--bench-conns",
                "4",
                "--bench-dup-ratio",
                "0.8",
            ])),
            0
        );
        // Tenant-tagged mix: the QoS hot-tenant lane (X-Tenant-Id
        // spread over 3 tenants, 70% of requests on the hot one).
        assert_eq!(
            run(&sv(&[
                "serve",
                "--repo",
                root.to_str().unwrap(),
                "--serve-bench",
                "30",
                "--bench-conns",
                "3",
                "--bench-tenants",
                "3",
                "--bench-hot-tenant-share",
                "0.7",
            ])),
            0
        );
    }
}
