//! Serving configuration: a Triton-style `config.pbtxt` parser plus typed
//! model/server config structs.
//!
//! The paper's reproducibility notes (§X) require "Triton config.pbtxt
//! under version control with explicit max_batch_size, input dtypes, and
//! dynamic batching windows" — this module is that contract on our side.
//! `aot.py` emits one `config.pbtxt` per model; the coordinator parses it
//! to configure the dynamic batcher and instance groups.

pub mod pbtxt;

pub use pbtxt::{parse_pbtxt, PbNode, PbValue};

// Hand-written error impls (no `thiserror`) keep the dependency graph
// path-only — see `runtime::RuntimeError`.
#[derive(Debug)]
pub enum ConfigError {
    Syntax(String),
    Missing(&'static str),
    Invalid(&'static str, String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Syntax(m) => write!(f, "pbtxt syntax error: {m}"),
            ConfigError::Missing(field) => write!(f, "missing field {field}"),
            ConfigError::Invalid(field, v) => write!(f, "invalid value for {field}: {v}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Tensor dtype as declared in config.pbtxt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataType {
    F32,
    I32,
}

impl DataType {
    fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "TYPE_FP32" => Ok(DataType::F32),
            "TYPE_INT32" => Ok(DataType::I32),
            other => Err(ConfigError::Invalid("data_type", other.to_string())),
        }
    }
}

/// One declared input/output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DataType,
    /// Per-item dims (batch dim excluded, Triton convention).
    pub dims: Vec<usize>,
}

/// `dynamic_batching { ... }` block.
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicBatching {
    pub preferred_batch_sizes: Vec<usize>,
    pub max_queue_delay_us: u64,
}

/// `instance_group [ ... ]` entry.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceGroup {
    pub count: usize,
    pub kind: String,
}

/// `version_policy { ... }` block (Triton semantics): which numbered
/// version directories serve when the model loads without an explicit
/// version. Absent from the config, the registry defaults to
/// `Latest { num: 1 }` — serve only the newest version.
#[derive(Debug, Clone, PartialEq)]
pub enum VersionPolicy {
    /// The `num` highest version numbers present on disk.
    Latest { num: usize },
    /// Every version present on disk.
    All,
    /// Exactly these versions (loading errors if one is missing).
    Specific { versions: Vec<u64> },
}

impl Default for VersionPolicy {
    fn default() -> Self {
        VersionPolicy::Latest { num: 1 }
    }
}

impl VersionPolicy {
    /// Select the serving set from the available version numbers
    /// (sorted ascending). `Specific` returns its configured list
    /// verbatim — the caller validates existence so a missing version
    /// is a load error, not a silent no-op.
    pub fn select(&self, available: &[u64]) -> Vec<u64> {
        match self {
            VersionPolicy::Latest { num } => {
                let n = (*num).min(available.len());
                available[available.len() - n..].to_vec()
            }
            VersionPolicy::All => available.to_vec(),
            VersionPolicy::Specific { versions } => versions.clone(),
        }
    }

    fn parse(n: &PbNode) -> Result<VersionPolicy, ConfigError> {
        if let Some(l) = n.get_msg("latest") {
            let num = l.get_int("num_versions").unwrap_or(1);
            if num < 1 {
                return Err(ConfigError::Invalid(
                    "version_policy.latest.num_versions",
                    num.to_string(),
                ));
            }
            return Ok(VersionPolicy::Latest { num: num as usize });
        }
        if n.get_msg("all").is_some() {
            return Ok(VersionPolicy::All);
        }
        if let Some(s) = n.get_msg("specific") {
            let raw = s.get_int_list("versions").unwrap_or_default();
            if raw.is_empty() || raw.iter().any(|&v| v < 1) {
                return Err(ConfigError::Invalid(
                    "version_policy.specific.versions",
                    format!("{raw:?}"),
                ));
            }
            return Ok(VersionPolicy::Specific {
                versions: raw.iter().map(|&v| v as u64).collect(),
            });
        }
        Err(ConfigError::Invalid(
            "version_policy",
            "expected latest { num_versions: N } / all {} / specific { versions: [..] }".into(),
        ))
    }
}

/// Fully-parsed model serving config.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub platform: String,
    pub max_batch_size: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub dynamic_batching: Option<DynamicBatching>,
    pub instance_groups: Vec<InstanceGroup>,
    /// None = registry default (`latest { num_versions: 1 }`).
    pub version_policy: Option<VersionPolicy>,
}

impl ModelConfig {
    /// Parse from `config.pbtxt` text.
    pub fn from_pbtxt(text: &str) -> Result<Self, ConfigError> {
        let root = parse_pbtxt(text).map_err(ConfigError::Syntax)?;

        let name = root.get_str("name").ok_or(ConfigError::Missing("name"))?.to_string();
        let platform = root.get_str("platform").unwrap_or("greenflow_pjrt").to_string();
        let max_batch_size = root
            .get_int("max_batch_size")
            .ok_or(ConfigError::Missing("max_batch_size"))? as usize;

        let tensor = |n: &PbNode| -> Result<TensorSpec, ConfigError> {
            Ok(TensorSpec {
                name: n.get_str("name").ok_or(ConfigError::Missing("input.name"))?.to_string(),
                dtype: DataType::parse(
                    n.get_ident("data_type").ok_or(ConfigError::Missing("data_type"))?,
                )?,
                dims: n
                    .get_int_list("dims")
                    .ok_or(ConfigError::Missing("dims"))?
                    .iter()
                    .map(|&d| d as usize)
                    .collect(),
            })
        };

        let inputs = root.get_msg_list("input").iter().map(|n| tensor(n)).collect::<Result<_, _>>()?;
        let outputs =
            root.get_msg_list("output").iter().map(|n| tensor(n)).collect::<Result<_, _>>()?;

        let dynamic_batching = root.get_msg("dynamic_batching").map(|n| DynamicBatching {
            preferred_batch_sizes: n
                .get_int_list("preferred_batch_size")
                .unwrap_or_default()
                .iter()
                .map(|&x| x as usize)
                .collect(),
            max_queue_delay_us: n.get_int("max_queue_delay_microseconds").unwrap_or(0) as u64,
        });

        let instance_groups = root
            .get_msg_list("instance_group")
            .iter()
            .map(|n| InstanceGroup {
                count: n.get_int("count").unwrap_or(1) as usize,
                kind: n.get_ident("kind").unwrap_or("KIND_CPU").to_string(),
            })
            .collect();

        let version_policy = match root.get_msg("version_policy") {
            Some(n) => Some(VersionPolicy::parse(n)?),
            None => None,
        };

        Ok(ModelConfig {
            name,
            platform,
            max_batch_size,
            inputs,
            outputs,
            dynamic_batching,
            instance_groups,
            version_policy,
        })
    }

    /// Total instance count across groups (>=1).
    pub fn total_instances(&self) -> usize {
        self.instance_groups.iter().map(|g| g.count).sum::<usize>().max(1)
    }

    /// Validate internal consistency (batch sizes, dims).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_batch_size == 0 {
            return Err(ConfigError::Invalid("max_batch_size", "0".into()));
        }
        if self.inputs.is_empty() {
            return Err(ConfigError::Missing("input"));
        }
        if let Some(db) = &self.dynamic_batching {
            for &p in &db.preferred_batch_sizes {
                if p == 0 || p > self.max_batch_size {
                    return Err(ConfigError::Invalid(
                        "preferred_batch_size",
                        format!("{p} (max_batch_size {})", self.max_batch_size),
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name: "distilbert_mini"
platform: "greenflow_pjrt"
max_batch_size: 8
input [
  {
    name: "tokens"
    data_type: TYPE_INT32
    dims: [ 32 ]
  }
]
output [
  {
    name: "logits"
    data_type: TYPE_FP32
    dims: [ 2 ]
  }
  {
    name: "entropy"
    data_type: TYPE_FP32
    dims: [ 1 ]
  }
]
dynamic_batching {
  preferred_batch_size: [ 4, 8 ]
  max_queue_delay_microseconds: 2000
}
instance_group [
  {
    count: 2
    kind: KIND_CPU
  }
]
"#;

    #[test]
    fn parses_full_config() {
        let c = ModelConfig::from_pbtxt(SAMPLE).unwrap();
        assert_eq!(c.name, "distilbert_mini");
        assert_eq!(c.max_batch_size, 8);
        assert_eq!(c.inputs.len(), 1);
        assert_eq!(c.inputs[0].dtype, DataType::I32);
        assert_eq!(c.inputs[0].dims, vec![32]);
        assert_eq!(c.outputs.len(), 2);
        let db = c.dynamic_batching.as_ref().unwrap();
        assert_eq!(db.preferred_batch_sizes, vec![4, 8]);
        assert_eq!(db.max_queue_delay_us, 2000);
        assert_eq!(c.total_instances(), 2);
        c.validate().unwrap();
    }

    #[test]
    fn missing_name_fails() {
        assert!(ModelConfig::from_pbtxt("max_batch_size: 4").is_err());
    }

    #[test]
    fn validation_rejects_bad_preferred() {
        let mut c = ModelConfig::from_pbtxt(SAMPLE).unwrap();
        c.dynamic_batching.as_mut().unwrap().preferred_batch_sizes = vec![16];
        assert!(c.validate().is_err());
    }

    #[test]
    fn no_dynamic_batching_is_ok() {
        let txt = r#"
name: "m"
max_batch_size: 1
input [ { name: "x" data_type: TYPE_FP32 dims: [ 3 ] } ]
output [ { name: "y" data_type: TYPE_FP32 dims: [ 1 ] } ]
"#;
        let c = ModelConfig::from_pbtxt(txt).unwrap();
        assert!(c.dynamic_batching.is_none());
        assert_eq!(c.total_instances(), 1);
        c.validate().unwrap();
    }

    #[test]
    fn version_policy_parses_all_forms() {
        let base = "name: \"m\"\nmax_batch_size: 1\n\
                    input [ { name: \"x\" data_type: TYPE_FP32 dims: [ 3 ] } ]\n";

        let c = ModelConfig::from_pbtxt(base).unwrap();
        assert_eq!(c.version_policy, None);

        let c = ModelConfig::from_pbtxt(
            &format!("{base}version_policy {{ latest {{ num_versions: 2 }} }}"),
        )
        .unwrap();
        assert_eq!(c.version_policy, Some(VersionPolicy::Latest { num: 2 }));

        let c = ModelConfig::from_pbtxt(&format!("{base}version_policy {{ all {{ }} }}"))
            .unwrap();
        assert_eq!(c.version_policy, Some(VersionPolicy::All));

        let c = ModelConfig::from_pbtxt(
            &format!("{base}version_policy {{ specific {{ versions: [ 1, 3 ] }} }}"),
        )
        .unwrap();
        assert_eq!(
            c.version_policy,
            Some(VersionPolicy::Specific { versions: vec![1, 3] })
        );

        // Malformed policies are config errors, never silent defaults.
        assert!(ModelConfig::from_pbtxt(&format!("{base}version_policy {{ }}")).is_err());
        assert!(ModelConfig::from_pbtxt(
            &format!("{base}version_policy {{ latest {{ num_versions: 0 }} }}")
        )
        .is_err());
        assert!(ModelConfig::from_pbtxt(
            &format!("{base}version_policy {{ specific {{ versions: [ 0 ] }} }}")
        )
        .is_err());
    }

    #[test]
    fn version_policy_selection() {
        let avail = [1u64, 2, 5];
        assert_eq!(VersionPolicy::default().select(&avail), vec![5]);
        assert_eq!(VersionPolicy::Latest { num: 2 }.select(&avail), vec![2, 5]);
        assert_eq!(VersionPolicy::Latest { num: 9 }.select(&avail), vec![1, 2, 5]);
        assert_eq!(VersionPolicy::All.select(&avail), vec![1, 2, 5]);
        assert_eq!(
            VersionPolicy::Specific { versions: vec![2, 7] }.select(&avail),
            vec![2, 7],
            "existence is validated by the caller"
        );
        assert!(VersionPolicy::default().select(&[]).is_empty());
    }

    #[test]
    fn real_artifact_config_parses_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/distilbert_mini/config.pbtxt");
        if let Ok(text) = std::fs::read_to_string(path) {
            let c = ModelConfig::from_pbtxt(&text).unwrap();
            assert_eq!(c.name, "distilbert_mini");
            c.validate().unwrap();
        }
    }
}
