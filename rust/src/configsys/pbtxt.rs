//! Tokenizer + recursive-descent parser for the protobuf text-format subset
//! Triton uses in `config.pbtxt`:
//!
//! ```text
//! name: "model"                     // scalar field (string)
//! max_batch_size: 8                 // scalar field (int)
//! data_type: TYPE_FP32              // scalar field (enum identifier)
//! dims: [ 1, 2 ]                    // scalar list
//! dynamic_batching { ... }          // nested message
//! input [ { ... } { ... } ]         // repeated message (list form)
//! ```
//!
//! Field-name/colon forms both with and without `:` before `{`/`[` are
//! accepted, matching protobuf text-format.

use std::collections::BTreeMap;

/// A parsed field value.
#[derive(Debug, Clone, PartialEq)]
pub enum PbValue {
    Int(i64),
    Float(f64),
    Str(String),
    /// Bare identifier (enum constant like `TYPE_FP32` / `KIND_CPU`).
    Ident(String),
    IntList(Vec<i64>),
    Msg(PbNode),
    MsgList(Vec<PbNode>),
}

/// A message node: multimap of field name -> values (repeated fields keep
/// every occurrence, in order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PbNode {
    fields: BTreeMap<String, Vec<PbValue>>,
}

impl PbNode {
    fn push(&mut self, key: String, v: PbValue) {
        self.fields.entry(key).or_default().push(v);
    }

    fn first(&self, key: &str) -> Option<&PbValue> {
        self.fields.get(key).and_then(|v| v.first())
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.first(key)? {
            PbValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get_ident(&self, key: &str) -> Option<&str> {
        match self.first(key)? {
            PbValue::Ident(s) => Some(s),
            PbValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.first(key)? {
            PbValue::Int(i) => Some(*i),
            PbValue::Float(f) => Some(*f as i64),
            _ => None,
        }
    }

    pub fn get_int_list(&self, key: &str) -> Option<Vec<i64>> {
        match self.first(key)? {
            PbValue::IntList(v) => Some(v.clone()),
            PbValue::Int(i) => Some(vec![*i]),
            _ => None,
        }
    }

    pub fn get_msg(&self, key: &str) -> Option<&PbNode> {
        match self.first(key)? {
            PbValue::Msg(n) => Some(n),
            PbValue::MsgList(ns) => ns.first(),
            _ => None,
        }
    }

    /// All message values of a repeated field (both `f { } f { }` and
    /// `f [ { } { } ]` forms).
    pub fn get_msg_list(&self, key: &str) -> Vec<&PbNode> {
        let mut out = Vec::new();
        if let Some(vals) = self.fields.get(key) {
            for v in vals {
                match v {
                    PbValue::Msg(n) => out.push(n),
                    PbValue::MsgList(ns) => out.extend(ns.iter()),
                    _ => {}
                }
            }
        }
        out
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Str(String),
    Num(String),
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Colon,
    Comma,
}

fn tokenize(src: &str) -> Result<Vec<Tok>, String> {
    let b = src.as_bytes();
    let mut i = 0;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'#' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'{' => {
                out.push(Tok::LBrace);
                i += 1;
            }
            b'}' => {
                out.push(Tok::RBrace);
                i += 1;
            }
            b'[' => {
                out.push(Tok::LBracket);
                i += 1;
            }
            b']' => {
                out.push(Tok::RBracket);
                i += 1;
            }
            b':' => {
                out.push(Tok::Colon);
                i += 1;
            }
            b',' => {
                out.push(Tok::Comma);
                i += 1;
            }
            b'"' => {
                i += 1;
                let start = i;
                while i < b.len() && b[i] != b'"' {
                    if b[i] == b'\\' {
                        i += 1;
                    }
                    i += 1;
                }
                if i >= b.len() {
                    return Err("unterminated string".into());
                }
                let raw = std::str::from_utf8(&b[start..i]).map_err(|e| e.to_string())?;
                out.push(Tok::Str(raw.replace("\\\"", "\"").replace("\\\\", "\\")));
                i += 1;
            }
            b'-' | b'0'..=b'9' => {
                let start = i;
                i += 1;
                while i < b.len() && matches!(b[i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                {
                    i += 1;
                }
                out.push(Tok::Num(
                    std::str::from_utf8(&b[start..i]).map_err(|e| e.to_string())?.to_string(),
                ));
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.push(Tok::Ident(
                    std::str::from_utf8(&b[start..i]).map_err(|e| e.to_string())?.to_string(),
                ));
            }
            c => return Err(format!("unexpected byte {:?} at {}", c as char, i)),
        }
    }
    Ok(out)
}

/// Parse pbtxt source into the root message node.
pub fn parse_pbtxt(src: &str) -> Result<PbNode, String> {
    let toks = tokenize(src)?;
    let mut p = P { t: &toks, i: 0 };
    let node = p.message_body(true)?;
    if p.i != toks.len() {
        return Err(format!("trailing tokens at {}", p.i));
    }
    Ok(node)
}

struct P<'a> {
    t: &'a [Tok],
    i: usize,
}

impl<'a> P<'a> {
    fn peek(&self) -> Option<&Tok> {
        self.t.get(self.i)
    }

    fn next(&mut self) -> Result<&'a Tok, String> {
        let t = self.t.get(self.i).ok_or("unexpected end of input")?;
        self.i += 1;
        Ok(t)
    }

    /// Parse fields until `}` (or EOF when `top` is true).
    fn message_body(&mut self, top: bool) -> Result<PbNode, String> {
        let mut node = PbNode::default();
        loop {
            match self.peek() {
                None if top => return Ok(node),
                None => return Err("unterminated message".into()),
                Some(Tok::RBrace) if !top => {
                    self.i += 1;
                    return Ok(node);
                }
                Some(Tok::Ident(_)) => {
                    let name = match self.next()? {
                        Tok::Ident(s) => s.clone(),
                        _ => unreachable!(),
                    };
                    let v = self.field_value()?;
                    node.push(name, v);
                }
                Some(t) => return Err(format!("unexpected token {t:?}")),
            }
        }
    }

    fn field_value(&mut self) -> Result<PbValue, String> {
        // optional colon
        if matches!(self.peek(), Some(Tok::Colon)) {
            self.i += 1;
        }
        match self.next()? {
            Tok::Str(s) => Ok(PbValue::Str(s.clone())),
            Tok::Ident(s) => Ok(PbValue::Ident(s.clone())),
            Tok::Num(n) => Ok(parse_num(n)),
            Tok::LBrace => Ok(PbValue::Msg(self.message_body(false)?)),
            Tok::LBracket => self.list_value(),
            t => Err(format!("unexpected token {t:?} as field value")),
        }
    }

    fn list_value(&mut self) -> Result<PbValue, String> {
        // Distinguish int lists from message lists by the first element.
        let mut ints = Vec::new();
        let mut msgs = Vec::new();
        loop {
            match self.peek().cloned() {
                Some(Tok::RBracket) => {
                    self.i += 1;
                    break;
                }
                Some(Tok::Comma) => {
                    self.i += 1;
                }
                Some(Tok::Num(n)) => {
                    self.i += 1;
                    match parse_num(&n) {
                        PbValue::Int(v) => ints.push(v),
                        PbValue::Float(f) => ints.push(f as i64),
                        _ => unreachable!(),
                    }
                }
                Some(Tok::LBrace) => {
                    self.i += 1;
                    msgs.push(self.message_body(false)?);
                }
                Some(t) => return Err(format!("unexpected token {t:?} in list")),
                None => return Err("unterminated list".into()),
            }
        }
        if !msgs.is_empty() {
            Ok(PbValue::MsgList(msgs))
        } else {
            Ok(PbValue::IntList(ints))
        }
    }
}

fn parse_num(n: &str) -> PbValue {
    if let Ok(i) = n.parse::<i64>() {
        PbValue::Int(i)
    } else {
        PbValue::Float(n.parse::<f64>().unwrap_or(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_comments() {
        let n = parse_pbtxt("# hello\nname: \"m\"\ncount: 3\nrate: 1.5\nkind: KIND_CPU").unwrap();
        assert_eq!(n.get_str("name"), Some("m"));
        assert_eq!(n.get_int("count"), Some(3));
        assert_eq!(n.get_ident("kind"), Some("KIND_CPU"));
    }

    #[test]
    fn int_lists() {
        let n = parse_pbtxt("dims: [ 1, 2, 3 ]").unwrap();
        assert_eq!(n.get_int_list("dims"), Some(vec![1, 2, 3]));
    }

    #[test]
    fn nested_message_with_and_without_colon() {
        let n = parse_pbtxt("a { x: 1 }\nb: { y: 2 }").unwrap();
        assert_eq!(n.get_msg("a").unwrap().get_int("x"), Some(1));
        assert_eq!(n.get_msg("b").unwrap().get_int("y"), Some(2));
    }

    #[test]
    fn repeated_message_list_form() {
        let n = parse_pbtxt("input [ { name: \"a\" } { name: \"b\" } ]").unwrap();
        let list = n.get_msg_list("input");
        assert_eq!(list.len(), 2);
        assert_eq!(list[1].get_str("name"), Some("b"));
    }

    #[test]
    fn repeated_field_form() {
        let n = parse_pbtxt("g { c: 1 }\ng { c: 2 }").unwrap();
        let list = n.get_msg_list("g");
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].get_int("c"), Some(1));
    }

    #[test]
    fn errors_on_garbage() {
        assert!(parse_pbtxt("a: {").is_err());
        assert!(parse_pbtxt("[").is_err());
        assert!(parse_pbtxt("a: \"unterminated").is_err());
    }

    #[test]
    fn escaped_strings() {
        let n = parse_pbtxt(r#"name: "a\"b""#).unwrap();
        assert_eq!(n.get_str("name"), Some("a\"b"));
    }
}
