//! Act layer: `Adaptive<T>` — a shared scalar that control loops write and
//! hot paths read at the cost of one relaxed atomic load.
//!
//! The handle is arc-swap-style (no external crates offline): the value is
//! bit-packed into an `Arc<AtomicU64>`, so clones share state and a store
//! in the control plane is immediately visible to every reader. Only
//! `Copy` scalars that round-trip through 64 bits are supported — exactly
//! the knobs the plane drives (τ corrections, delay µs, QPS thresholds).

use std::fmt;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Scalars that can live inside an `AtomicU64`.
pub trait AtomicBits: Copy {
    fn to_bits64(self) -> u64;
    fn from_bits64(bits: u64) -> Self;
}

impl AtomicBits for f64 {
    fn to_bits64(self) -> u64 {
        self.to_bits()
    }

    fn from_bits64(bits: u64) -> Self {
        f64::from_bits(bits)
    }
}

impl AtomicBits for u64 {
    fn to_bits64(self) -> u64 {
        self
    }

    fn from_bits64(bits: u64) -> Self {
        bits
    }
}

impl AtomicBits for u32 {
    fn to_bits64(self) -> u64 {
        self as u64
    }

    fn from_bits64(bits: u64) -> Self {
        bits as u32
    }
}

impl AtomicBits for usize {
    fn to_bits64(self) -> u64 {
        self as u64
    }

    fn from_bits64(bits: u64) -> Self {
        bits as usize
    }
}

/// A live-updatable scalar: cheap lock-free reads, controlled updates.
///
/// `Clone` shares the underlying cell — hand a clone to the control plane
/// and keep one on the hot path; `set` on either side is visible to both.
pub struct Adaptive<T: AtomicBits> {
    bits: Arc<AtomicU64>,
    _marker: PhantomData<fn() -> T>,
}

impl<T: AtomicBits> Adaptive<T> {
    pub fn new(value: T) -> Self {
        Adaptive { bits: Arc::new(AtomicU64::new(value.to_bits64())), _marker: PhantomData }
    }

    /// Hot-path read: a single relaxed atomic load.
    #[inline]
    pub fn get(&self) -> T {
        T::from_bits64(self.bits.load(Ordering::Relaxed))
    }

    /// Publish a new value (control-plane side).
    #[inline]
    pub fn set(&self, value: T) {
        self.bits.store(value.to_bits64(), Ordering::Relaxed);
    }

    /// A second handle onto the same cell (alias for `clone`, reads as
    /// intent at wiring sites).
    pub fn handle(&self) -> Self {
        self.clone()
    }

    /// Whether two handles share the same underlying cell.
    pub fn shares_cell_with(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.bits, &other.bits)
    }
}

impl<T: AtomicBits> Clone for Adaptive<T> {
    fn clone(&self) -> Self {
        Adaptive { bits: self.bits.clone(), _marker: PhantomData }
    }
}

impl<T: AtomicBits + fmt::Debug> fmt::Debug for Adaptive<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Adaptive({:?})", self.get())
    }
}

impl<T: AtomicBits + Default> Default for Adaptive<T> {
    fn default() -> Self {
        Adaptive::new(T::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_roundtrip() {
        let a = Adaptive::new(0.51f64);
        assert_eq!(a.get(), 0.51);
        a.set(-3.25);
        assert_eq!(a.get(), -3.25);

        let d = Adaptive::new(2000u64);
        assert_eq!(d.get(), 2000);
        d.set(0);
        assert_eq!(d.get(), 0);
    }

    #[test]
    fn clones_share_the_cell() {
        let a = Adaptive::new(1.0f64);
        let b = a.clone();
        assert!(a.shares_cell_with(&b));
        b.set(7.5);
        assert_eq!(a.get(), 7.5);
        let c = Adaptive::new(1.0f64);
        assert!(!a.shares_cell_with(&c));
    }

    #[test]
    fn usize_and_u32_pack() {
        let a = Adaptive::new(usize::MAX >> 1);
        assert_eq!(a.get(), usize::MAX >> 1);
        let b = Adaptive::new(u32::MAX);
        assert_eq!(b.get(), u32::MAX);
    }

    #[test]
    fn debug_prints_value() {
        let a = Adaptive::new(42u64);
        assert_eq!(format!("{a:?}"), "Adaptive(42)");
    }

    #[test]
    fn read_under_concurrent_update_never_tears() {
        // A writer cycles through a known value set; readers must only
        // ever observe members of that set (a torn 64-bit store would
        // produce a value outside it).
        let values = [0.125f64, -7.5, 1e300, 0.0, 42.0];
        let a = Adaptive::new(values[0]);
        let writer = {
            let a = a.clone();
            std::thread::spawn(move || {
                for i in 0..50_000 {
                    a.set(values[i % values.len()]);
                }
            })
        };
        for _ in 0..50_000 {
            let v = a.get();
            assert!(values.contains(&v), "torn read: {v}");
        }
        writer.join().unwrap();
    }
}
