//! Decide layer: pluggable control laws.
//!
//! A law maps a windowed signal (Observe) onto the next value of a knob
//! (published through an `Adaptive<T>` handle, Act). Laws are stepped on
//! the control tick — never on the request hot path — so they can afford
//! branches and floating point without budget anxiety.

/// One feedback law. `step` consumes the latest windowed signal and the
/// elapsed tick interval `dt` (seconds) and returns the new output; the
/// caller publishes it. `dt` lets time-based laws (budget pacing)
/// integrate correctly under irregular ticks; per-decision laws may
/// ignore it.
pub trait ControlLaw: Send {
    fn step(&mut self, signal: f64, dt: f64) -> f64;

    /// Current output without stepping.
    fn output(&self) -> f64;

    /// Law name for telemetry gauges and reports.
    fn name(&self) -> &'static str;
}

/// Additive-increase / multiplicative-decrease.
///
/// While `signal <= setpoint` (healthy — e.g. windowed p95 under the SLO)
/// the output creeps up by `increase` per step, probing for headroom;
/// on violation it is cut by the factor `decrease`, backing off fast.
/// The classic TCP-style sawtooth: used here to drive the batcher's
/// `max_queue_delay_us` (more delay = better amortisation) subject to
/// the latency SLO.
#[derive(Debug, Clone)]
pub struct Aimd {
    pub setpoint: f64,
    pub increase: f64,
    pub decrease: f64,
    pub min: f64,
    pub max: f64,
    value: f64,
}

impl Aimd {
    pub fn new(
        initial: f64,
        setpoint: f64,
        increase: f64,
        decrease: f64,
        min: f64,
        max: f64,
    ) -> Self {
        assert!(increase >= 0.0, "AIMD additive step must be >= 0");
        assert!(decrease > 0.0 && decrease < 1.0, "AIMD decrease must be in (0,1)");
        assert!(min <= max && (min..=max).contains(&initial));
        Aimd { setpoint, increase, decrease, min, max, value: initial }
    }
}

impl ControlLaw for Aimd {
    fn step(&mut self, signal: f64, _dt: f64) -> f64 {
        self.value = if signal <= self.setpoint {
            (self.value + self.increase).min(self.max)
        } else {
            (self.value * self.decrease).max(self.min)
        };
        self.value
    }

    fn output(&self) -> f64 {
        self.value
    }

    fn name(&self) -> &'static str {
        "aimd"
    }
}

/// Integral setpoint tracker: `value += gain * (signal - setpoint)`,
/// clamped to `[min, max]`.
///
/// The admission-rate → τ servo: admitting more than the target rate
/// raises the τ correction (stricter), under-admitting lowers it. The
/// per-step (not per-second) form matches the windowed-rate cadence the
/// admission controller observes at.
#[derive(Debug, Clone)]
pub struct SetpointTracker {
    pub setpoint: f64,
    pub gain: f64,
    pub min: f64,
    pub max: f64,
    value: f64,
}

impl SetpointTracker {
    pub fn new(initial: f64, setpoint: f64, gain: f64, min: f64, max: f64) -> Self {
        assert!(gain > 0.0);
        assert!(min <= max && (min..=max).contains(&initial));
        SetpointTracker { setpoint, gain, min, max, value: initial }
    }
}

impl ControlLaw for SetpointTracker {
    fn step(&mut self, signal: f64, _dt: f64) -> f64 {
        self.value = (self.value + self.gain * (signal - self.setpoint)).clamp(self.min, self.max);
        self.value
    }

    fn output(&self) -> f64 {
        self.value
    }

    fn name(&self) -> &'static str {
        "setpoint"
    }
}

/// Energy-budget pacer: integrates (spend − budget) over wall time.
///
/// `signal` is the windowed power draw (W); while it exceeds `budget` the
/// output grows toward `max` at `gain` per joule of overspend, and decays
/// back toward `min` under budget. Wired as a *positive* τ correction:
/// sustained overspend tightens admission until the draw returns under
/// budget (the paper's §IV-A-B energy-spike response, held over a window
/// instead of a single EWMA spike).
#[derive(Debug, Clone)]
pub struct BudgetPacer {
    pub budget: f64,
    pub gain: f64,
    pub min: f64,
    pub max: f64,
    value: f64,
}

impl BudgetPacer {
    pub fn new(budget: f64, gain: f64, min: f64, max: f64) -> Self {
        assert!(budget >= 0.0 && gain > 0.0 && min <= max);
        BudgetPacer { budget, gain, min, max, value: min }
    }
}

impl ControlLaw for BudgetPacer {
    fn step(&mut self, signal: f64, dt: f64) -> f64 {
        let dt = dt.max(0.0);
        self.value =
            (self.value + self.gain * (signal - self.budget) * dt).clamp(self.min, self.max);
        self.value
    }

    fn output(&self) -> f64 {
        self.value
    }

    fn name(&self) -> &'static str {
        "budget"
    }
}

/// Full PID controller on the error `signal - setpoint`, with clamped
/// output and integral anti-windup.
///
/// Same sign convention as [`SetpointTracker`] (its pure-I special
/// case): a signal above the setpoint drives the output up. The
/// proportional term reacts to the current error immediately — where
/// the integral tracker needs many ticks to accumulate the same
/// correction — and the derivative term damps the overshoot that a
/// hard proportional gain would otherwise ring with, so τ converges in
/// fewer control ticks (the `tests/integration_control.rs` convergence
/// contrast).
///
/// Anti-windup: the integral state is clamped so that `ki * integral`
/// alone can never exceed the output band — a long saturated excursion
/// (burst far above the setpoint) unwinds immediately once the signal
/// returns, instead of replaying the accumulated windup as overshoot.
#[derive(Debug, Clone)]
pub struct Pid {
    pub setpoint: f64,
    pub kp: f64,
    pub ki: f64,
    pub kd: f64,
    pub min: f64,
    pub max: f64,
    integral: f64,
    prev_error: Option<f64>,
    value: f64,
}

impl Pid {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        initial: f64,
        setpoint: f64,
        kp: f64,
        ki: f64,
        kd: f64,
        min: f64,
        max: f64,
    ) -> Self {
        assert!(kp >= 0.0 && ki >= 0.0 && kd >= 0.0, "PID gains must be >= 0");
        assert!(kp > 0.0 || ki > 0.0, "a PID with no P and no I never moves");
        assert!(min <= max && (min..=max).contains(&initial));
        Pid { setpoint, kp, ki, kd, min, max, integral: 0.0, prev_error: None, value: initial }
    }
}

impl ControlLaw for Pid {
    fn step(&mut self, signal: f64, dt: f64) -> f64 {
        let dt = dt.max(0.0);
        let error = signal - self.setpoint;
        if self.ki > 0.0 {
            // Clamp the *integral contribution* into the output band.
            self.integral =
                (self.integral + error * dt).clamp(self.min / self.ki, self.max / self.ki);
        }
        let derivative = match self.prev_error {
            Some(prev) if dt > 0.0 => (error - prev) / dt,
            _ => 0.0,
        };
        self.prev_error = Some(error);
        self.value = (self.kp * error + self.ki * self.integral + self.kd * derivative)
            .clamp(self.min, self.max);
        self.value
    }

    fn output(&self) -> f64 {
        self.value
    }

    fn name(&self) -> &'static str {
        "pid"
    }
}

/// Replica-count governor with hysteresis and idle scale-to-zero.
///
/// `signal` is *demand in replica-units*: the concurrent work a version
/// is carrying (in-flight + queued, scaled by latency pressure and the
/// energy-budget throttle — composed by the loop wiring, see
/// `SystemShared::attach_loops`). The law moves its target one replica
/// per tick — never a jump — with a hysteresis band so demand noise
/// around a boundary cannot flap spawn/retire cycles:
///
/// * scale **up** when demand exceeds `up_threshold` of what the
///   current set absorbs (`signal > target * up_threshold`);
/// * scale **down** when the set one smaller would still run under
///   `down_threshold` (`signal < (target - 1) * down_threshold`);
/// * scale **to zero** only after `idle_secs` of continuous zero
///   demand — the cold-model branch of arXiv:2402.07585's dynamic
///   model management. A cold version that sees demand again comes
///   back to one replica on the next tick.
///
/// The output is a fractional target; the actor rounds it and applies
/// the delta through the `LifecycleExecutor`.
#[derive(Debug, Clone)]
pub struct ReplicaScaler {
    pub max_replicas: f64,
    pub up_threshold: f64,
    pub down_threshold: f64,
    pub idle_secs: f64,
    idle_accum: f64,
    value: f64,
}

impl ReplicaScaler {
    pub fn new(
        initial: f64,
        max_replicas: f64,
        up_threshold: f64,
        down_threshold: f64,
        idle_secs: f64,
    ) -> Self {
        assert!(max_replicas >= 1.0, "a scaler that can never run a replica is useless");
        assert!(
            0.0 < down_threshold && down_threshold < up_threshold && up_threshold <= 1.0,
            "hysteresis needs 0 < down < up <= 1"
        );
        assert!(idle_secs > 0.0, "idle window must be positive");
        assert!((0.0..=max_replicas).contains(&initial));
        ReplicaScaler {
            max_replicas,
            up_threshold,
            down_threshold,
            idle_secs,
            idle_accum: 0.0,
            value: initial,
        }
    }

    /// Seconds of continuous zero demand observed so far.
    pub fn idle_for(&self) -> f64 {
        self.idle_accum
    }
}

impl ControlLaw for ReplicaScaler {
    fn step(&mut self, signal: f64, dt: f64) -> f64 {
        let dt = dt.max(0.0);
        let signal = signal.max(0.0);
        if signal > 0.0 {
            self.idle_accum = 0.0;
        } else {
            self.idle_accum += dt;
        }
        let cur = self.value;
        if self.idle_accum >= self.idle_secs {
            self.value = 0.0;
        } else if cur < 1.0 {
            if signal > 0.0 {
                // cold version saw traffic: bring the first replica up
                self.value = 1.0;
            }
        } else if signal > cur * self.up_threshold {
            self.value = (cur + 1.0).min(self.max_replicas);
        } else if cur > 1.0 && signal < (cur - 1.0) * self.down_threshold {
            self.value = cur - 1.0;
        }
        self.value
    }

    fn output(&self) -> f64 {
        self.value
    }

    fn name(&self) -> &'static str {
        "replica"
    }
}

/// Tenant quota governor: maps global pressure (watts over budget) to
/// a multiplicative scale on every tenant's GCRA rate.
///
/// `signal` is the windowed power draw; `setpoint` the power budget.
/// The scale shrinks in proportion to the *relative* overshoot
/// (`gain × (signal − setpoint)/setpoint` per second) and recovers at
/// the same gain when the draw falls back under budget, clamped to
/// `[min_scale, 1]`. Relative error makes one gain work across
/// deployments whose budgets differ by orders of magnitude, and the
/// `min_scale` floor guarantees no tenant is ever throttled to zero —
/// pressure degrades quotas, it never revokes them.
///
/// The actor side writes the output through
/// `crate::qos::QosLayer::set_quota_scale`, which rescales each
/// tenant's `Adaptive<u32>` rate cell (effective rate =
/// `base_rate × scale`).
#[derive(Debug, Clone)]
pub struct QuotaScaler {
    pub setpoint: f64,
    pub gain: f64,
    pub min_scale: f64,
    value: f64,
}

impl QuotaScaler {
    pub fn new(setpoint: f64, gain: f64, min_scale: f64) -> Self {
        assert!(setpoint > 0.0, "pressure setpoint must be positive");
        assert!(gain > 0.0, "a gainless scaler never moves");
        assert!(
            min_scale > 0.0 && min_scale < 1.0,
            "min_scale must lie in (0, 1): quotas degrade, never vanish"
        );
        QuotaScaler { setpoint, gain, min_scale, value: 1.0 }
    }
}

impl ControlLaw for QuotaScaler {
    fn step(&mut self, signal: f64, dt: f64) -> f64 {
        let dt = dt.max(0.0);
        let err = (signal - self.setpoint) / self.setpoint;
        self.value = (self.value - self.gain * err * dt).clamp(self.min_scale, 1.0);
        self.value
    }

    fn output(&self) -> f64 {
        self.value
    }

    fn name(&self) -> &'static str {
        "quota"
    }
}

/// Carbon-aware pacer: maps grid carbon intensity (kg CO₂/kWh, the
/// [`crate::energy::CarbonIntensityTrace`] sample fed through
/// `WindowedMetrics`) to a deferral *pressure* in `[0, 1]`.
///
/// Like [`QuotaScaler`], the law integrates the **relative** overshoot
/// of intensity above `threshold` (`gain × (signal − threshold)/threshold`
/// per second), so one gain works from France (0.056) to the world
/// average (0.475). Pressure rises while the grid is dirty and unwinds
/// symmetrically once intensity drops below the threshold — a clean
/// window actively drains the deferral bias instead of merely freezing
/// it. The actor side applies the pressure as a positive admission-τ
/// bias and a batch-delay stretch on *deferrable* (low-priority) work
/// only; high-priority traffic never sees it (docs/SCENARIOS.md).
#[derive(Debug, Clone)]
pub struct CarbonPacer {
    /// Intensity above which work should start deferring (kg CO₂/kWh).
    pub threshold: f64,
    /// Pressure change per second per unit of relative overshoot.
    pub gain: f64,
    value: f64,
}

impl CarbonPacer {
    pub fn new(threshold: f64, gain: f64) -> Self {
        assert!(threshold > 0.0, "carbon threshold must be positive");
        assert!(gain > 0.0, "a gainless pacer never moves");
        CarbonPacer { threshold, gain, value: 0.0 }
    }
}

impl ControlLaw for CarbonPacer {
    fn step(&mut self, signal: f64, dt: f64) -> f64 {
        let dt = dt.max(0.0);
        let err = (signal - self.threshold) / self.threshold;
        self.value = (self.value + self.gain * err * dt).clamp(0.0, 1.0);
        self.value
    }

    fn output(&self) -> f64 {
        self.value
    }

    fn name(&self) -> &'static str {
        "carbon"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aimd_increases_additively_while_healthy() {
        let mut a = Aimd::new(10.0, 1.0, 2.0, 0.5, 0.0, 100.0);
        // signal under setpoint: +2 per step
        assert_eq!(a.step(0.5, 1.0), 12.0);
        assert_eq!(a.step(0.9, 1.0), 14.0);
        assert_eq!(a.step(1.0, 1.0), 16.0, "setpoint itself is healthy");
        assert_eq!(a.output(), 16.0);
    }

    #[test]
    fn aimd_decreases_multiplicatively_on_violation() {
        let mut a = Aimd::new(64.0, 1.0, 2.0, 0.5, 1.0, 100.0);
        assert_eq!(a.step(2.0, 1.0), 32.0);
        assert_eq!(a.step(2.0, 1.0), 16.0);
        assert_eq!(a.step(2.0, 1.0), 8.0);
        // recovery is additive, not a jump back
        assert_eq!(a.step(0.0, 1.0), 10.0);
    }

    #[test]
    fn aimd_respects_bounds() {
        let mut a = Aimd::new(9.0, 1.0, 5.0, 0.1, 2.0, 10.0);
        assert_eq!(a.step(0.0, 1.0), 10.0, "clamped at max");
        assert_eq!(a.step(0.0, 1.0), 10.0);
        for _ in 0..10 {
            a.step(9.9, 1.0);
        }
        assert_eq!(a.output(), 2.0, "clamped at min");
    }

    #[test]
    fn aimd_sawtooth_stays_in_band() {
        // Alternate healthy/violating: the sawtooth must not diverge.
        let mut a = Aimd::new(50.0, 1.0, 1.0, 0.5, 0.0, 1000.0);
        for i in 0..1000 {
            a.step(if i % 4 == 0 { 2.0 } else { 0.5 }, 1.0);
        }
        assert!(a.output() < 20.0, "diverged: {}", a.output());
        assert!(a.output() > 0.0);
    }

    #[test]
    #[should_panic]
    fn aimd_rejects_bad_decrease() {
        Aimd::new(1.0, 1.0, 1.0, 1.5, 0.0, 10.0);
    }

    #[test]
    fn setpoint_tracker_servos_toward_target() {
        // Plant: admission rate falls linearly as τ correction rises.
        let plant = |corr: f64| (0.9 - corr).clamp(0.0, 1.0);
        let mut law = SetpointTracker::new(0.0, 0.6, 0.4, -1.0, 1.0);
        let mut corr = 0.0;
        for _ in 0..200 {
            corr = law.step(plant(corr), 1.0);
        }
        assert!((plant(corr) - 0.6).abs() < 0.02, "rate {}", plant(corr));
    }

    #[test]
    fn setpoint_tracker_sign_convention() {
        let mut law = SetpointTracker::new(0.0, 0.5, 0.1, -1.0, 1.0);
        // over-admission raises the correction (stricter τ)
        assert!(law.step(0.9, 1.0) > 0.0);
        // sustained under-admission drives it negative (permissive τ)
        for _ in 0..20 {
            law.step(0.1, 1.0);
        }
        assert!(law.output() < 0.0);
    }

    #[test]
    fn setpoint_tracker_clamps() {
        let mut law = SetpointTracker::new(0.0, 0.0, 10.0, -0.25, 0.25);
        for _ in 0..100 {
            law.step(1.0, 1.0);
        }
        assert_eq!(law.output(), 0.25);
        for _ in 0..100 {
            law.step(-1.0, 1.0);
        }
        assert_eq!(law.output(), -0.25);
    }

    #[test]
    fn budget_pacer_rises_on_overspend_and_recovers() {
        let mut p = BudgetPacer::new(100.0, 0.001, 0.0, 0.5);
        assert_eq!(p.output(), 0.0, "starts at min");
        // 150 W against a 100 W budget: +0.05/s of correction
        for _ in 0..10 {
            p.step(150.0, 1.0);
        }
        assert!((p.output() - 0.5).abs() < 1e-9, "saturates at max");
        // back under budget: decays toward min
        for _ in 0..5 {
            p.step(50.0, 1.0);
        }
        assert!((p.output() - 0.25).abs() < 1e-9);
        for _ in 0..100 {
            p.step(50.0, 1.0);
        }
        assert_eq!(p.output(), 0.0);
    }

    #[test]
    fn budget_pacer_scales_with_dt() {
        let mut a = BudgetPacer::new(0.0, 1.0, 0.0, 100.0);
        let mut b = BudgetPacer::new(0.0, 1.0, 0.0, 100.0);
        a.step(10.0, 1.0);
        for _ in 0..10 {
            b.step(10.0, 0.1);
        }
        assert!((a.output() - b.output()).abs() < 1e-9);
    }

    #[test]
    fn pid_matches_setpoint_tracker_when_pure_integral() {
        // With kp = kd = 0 and per-step dt = 1, the PID must reduce to
        // the integral tracker it generalises.
        let mut pid = Pid::new(0.0, 0.6, 0.0, 0.4, 0.0, -1.0, 1.0);
        let mut tracker = SetpointTracker::new(0.0, 0.6, 0.4, -1.0, 1.0);
        for signal in [0.9, 0.1, 0.7, 0.6, 0.2, 0.95] {
            assert!((pid.step(signal, 1.0) - tracker.step(signal, 1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn pid_sign_convention_matches_setpoint_tracker() {
        let mut law = Pid::new(0.0, 0.5, 0.3, 0.1, 0.0, -1.0, 1.0);
        // over the setpoint: correction rises (stricter τ)
        assert!(law.step(0.9, 1.0) > 0.0);
        // sustained under-shoot drives it negative (permissive τ)
        for _ in 0..20 {
            law.step(0.1, 1.0);
        }
        assert!(law.output() < 0.0);
    }

    #[test]
    fn pid_proportional_term_reacts_immediately() {
        // One step, same error: the P term moves the output at once,
        // where the pure-I tracker takes gain*error per step.
        let mut pid = Pid::new(0.0, 0.5, 1.0, 0.1, 0.0, -1.0, 1.0);
        let out = pid.step(0.9, 1.0);
        assert!(out > 0.4, "P term should dominate the first step, got {out}");
    }

    #[test]
    fn pid_derivative_damps_a_rising_error() {
        // The derivative acts on the error's motion: while the error is
        // falling (the loop converging), D subtracts from the output —
        // the damping that lets PR-6's convergence test run hotter P/I
        // gains without overshoot.
        let mut with_d = Pid::new(0.0, 0.0, 0.5, 0.1, 0.2, -10.0, 10.0);
        let mut without_d = Pid::new(0.0, 0.0, 0.5, 0.1, 0.0, -10.0, 10.0);
        with_d.step(1.0, 1.0);
        without_d.step(1.0, 1.0);
        // error falls 1.0 → 0.2: D sees -0.8/s and pulls the output
        // below the P+I-only controller.
        let damped = with_d.step(0.2, 1.0);
        let undamped = without_d.step(0.2, 1.0);
        assert!(damped < undamped, "D failed to damp: {damped} vs {undamped}");
    }

    #[test]
    fn pid_anti_windup_bounds_the_integral() {
        // Long saturated excursion, then the error reverses: an
        // unclamped integral (200 × 10 accumulated) would hold the
        // output pinned at max for ~2000 more steps; the clamped one
        // lets the controller move off the rail on the very next step.
        let mut law = Pid::new(0.0, 0.0, 0.2, 0.5, 0.0, -1.0, 1.0);
        for _ in 0..200 {
            law.step(10.0, 1.0);
        }
        assert_eq!(law.output(), 1.0, "saturated at max during the excursion");
        let out = law.step(-1.0, 1.0);
        assert!(out < 0.5, "integral windup pinned the output: {out}");
    }

    #[test]
    fn pid_clamps_output() {
        let mut law = Pid::new(0.0, 0.0, 100.0, 0.0, 0.0, -0.25, 0.25);
        assert_eq!(law.step(1.0, 1.0), 0.25);
        assert_eq!(law.step(-1.0, 1.0), -0.25);
    }

    #[test]
    #[should_panic]
    fn pid_rejects_a_controller_that_cannot_move() {
        Pid::new(0.0, 0.5, 0.0, 0.0, 1.0, -1.0, 1.0);
    }

    #[test]
    fn replica_scaler_steps_up_one_at_a_time_under_load() {
        let mut s = ReplicaScaler::new(1.0, 8.0, 0.8, 0.4, 30.0);
        // demand of 4 replicas-worth: grows 1 → 2 → 3 → 4 → 5, then the
        // hysteresis band holds (4.0 <= 5 * 0.8).
        for expect in [2.0, 3.0, 4.0, 5.0, 5.0, 5.0] {
            assert_eq!(s.step(4.0, 1.0), expect);
        }
    }

    #[test]
    fn replica_scaler_scales_down_with_hysteresis() {
        let mut s = ReplicaScaler::new(4.0, 8.0, 0.8, 0.4, 30.0);
        // demand 1.5: one fewer replica (3) would run at 0.5 each —
        // above the 0.4 down-threshold only through (cur-1)*0.4:
        // 1.5 > 3*0.4 = 1.2 holds at cur=4, so no shrink yet.
        assert_eq!(s.step(1.5, 1.0), 4.0);
        // demand 1.0 < 3*0.4: shrink one per tick until the band holds
        assert_eq!(s.step(1.0, 1.0), 3.0);
        assert_eq!(s.step(1.0, 1.0), 3.0, "1.0 > 2*0.4 holds at 3");
        assert_eq!(s.step(0.7, 1.0), 2.0);
        // never through zero on load alone
        for _ in 0..10 {
            s.step(0.1, 1.0);
        }
        assert_eq!(s.output(), 1.0);
    }

    #[test]
    fn replica_scaler_reaches_zero_only_after_the_idle_window() {
        let mut s = ReplicaScaler::new(1.0, 8.0, 0.8, 0.4, 10.0);
        for _ in 0..9 {
            assert_eq!(s.step(0.0, 1.0), 1.0, "still inside the idle window");
        }
        assert_eq!(s.step(0.0, 1.0), 0.0, "idle window elapsed");
        // traffic on a cold version brings one replica back
        assert_eq!(s.step(0.5, 1.0), 1.0);
        assert_eq!(s.idle_for(), 0.0, "demand resets the idle clock");
    }

    #[test]
    fn replica_scaler_demand_resets_idle_accumulation() {
        let mut s = ReplicaScaler::new(1.0, 4.0, 0.8, 0.4, 10.0);
        for _ in 0..9 {
            s.step(0.0, 1.0);
        }
        s.step(1.0, 2.0); // traffic just before the window elapses
        for _ in 0..9 {
            assert!(s.step(0.0, 1.0) >= 1.0);
        }
        assert_eq!(s.step(0.0, 1.0), 0.0);
    }

    #[test]
    fn replica_scaler_clamps_at_max() {
        let mut s = ReplicaScaler::new(1.0, 3.0, 0.8, 0.4, 30.0);
        for _ in 0..10 {
            s.step(100.0, 1.0);
        }
        assert_eq!(s.output(), 3.0);
    }

    #[test]
    #[should_panic]
    fn replica_scaler_rejects_inverted_hysteresis() {
        ReplicaScaler::new(1.0, 4.0, 0.4, 0.8, 10.0);
    }

    #[test]
    fn quota_scaler_shrinks_under_pressure_and_recovers() {
        let mut q = QuotaScaler::new(100.0, 0.5, 0.05);
        assert_eq!(q.output(), 1.0, "starts with full quotas");
        // 200 W against a 100 W budget: relative error 1.0 → −0.5/s.
        assert!((q.step(200.0, 1.0) - 0.5).abs() < 1e-9);
        for _ in 0..10 {
            q.step(200.0, 1.0);
        }
        assert_eq!(q.output(), 0.05, "clamps at min_scale, never zero");
        // Back under budget: recovers toward 1 and clamps there.
        for _ in 0..100 {
            q.step(50.0, 1.0);
        }
        assert_eq!(q.output(), 1.0);
    }

    #[test]
    fn quota_scaler_scales_with_dt() {
        let mut a = QuotaScaler::new(10.0, 0.2, 0.05);
        let mut b = QuotaScaler::new(10.0, 0.2, 0.05);
        a.step(15.0, 1.0);
        for _ in 0..10 {
            b.step(15.0, 0.1);
        }
        assert!((a.output() - b.output()).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn quota_scaler_rejects_zero_floor() {
        QuotaScaler::new(10.0, 0.1, 0.0);
    }

    #[test]
    fn carbon_pacer_builds_and_drains_pressure() {
        let mut c = CarbonPacer::new(0.35, 0.5);
        assert_eq!(c.output(), 0.0, "starts with no deferral pressure");
        // 0.70 kg/kWh against a 0.35 threshold: relative error 1.0 → +0.5/s.
        assert!((c.step(0.70, 1.0) - 0.5).abs() < 1e-9);
        for _ in 0..10 {
            c.step(0.70, 1.0);
        }
        assert_eq!(c.output(), 1.0, "clamps at full pressure");
        // Clean window (France-like grid): pressure actively drains to 0.
        for _ in 0..10 {
            c.step(0.056, 1.0);
        }
        assert_eq!(c.output(), 0.0);
    }

    #[test]
    fn carbon_pacer_scales_with_dt() {
        let mut a = CarbonPacer::new(0.35, 0.2);
        let mut b = CarbonPacer::new(0.35, 0.2);
        a.step(0.5, 1.0);
        for _ in 0..10 {
            b.step(0.5, 0.1);
        }
        assert!((a.output() - b.output()).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn carbon_pacer_rejects_zero_threshold() {
        CarbonPacer::new(0.0, 0.5);
    }

    #[test]
    fn laws_are_object_safe() {
        let mut laws: Vec<Box<dyn ControlLaw>> = vec![
            Box::new(Aimd::new(1.0, 1.0, 1.0, 0.5, 0.0, 10.0)),
            Box::new(SetpointTracker::new(0.0, 0.5, 0.1, -1.0, 1.0)),
            Box::new(BudgetPacer::new(10.0, 0.1, 0.0, 1.0)),
            Box::new(Pid::new(0.0, 0.5, 0.5, 0.1, 0.05, -1.0, 1.0)),
            Box::new(ReplicaScaler::new(1.0, 4.0, 0.8, 0.4, 30.0)),
            Box::new(QuotaScaler::new(40.0, 0.5, 0.05)),
            Box::new(CarbonPacer::new(0.35, 0.5)),
        ];
        for law in &mut laws {
            let out = law.step(0.7, 0.1);
            assert!(out.is_finite());
            assert_eq!(out, law.output());
            assert!(!law.name().is_empty());
        }
    }
}
