//! The control plane: one reusable feedback substrate for every knob that
//! should adapt to live load and energy signals.
//!
//! The paper's closed loop (energy EWMA → next admission decision) is one
//! instance of a general pattern. This module factors that pattern into
//! three layers so that "make X adaptive" becomes a one-law addition
//! instead of a cross-cutting rewrite:
//!
//! ```text
//!            ┌───────────────────────────────────────────────┐
//!            │                 ControlPlane                  │
//!            │            (background tick thread)           │
//!            └───────────────────────────────────────────────┘
//!   OBSERVE                  DECIDE                    ACT
//! ┌───────────────┐   ┌───────────────────┐   ┌──────────────────────┐
//! │ RateWindow    │   │ trait ControlLaw  │   │ Adaptive<T>          │
//! │ LatencyWindow │ → │  · Aimd           │ → │  (atomic handle read │
//! │ EnergyWindow  │   │  · SetpointTracker│   │   on the hot path)   │
//! │ WindowedMetrics│  │  · Pid            │   │                      │
//! │               │   │  · BudgetPacer    │   │                      │
//! └───────────────┘   └───────────────────┘   └──────────────────────┘
//!   request events      windowed signal          τ correction,
//!   (arrival, latency,   vs. setpoint            batcher delay µs,
//!    joules)                                     router QPS threshold
//! ```
//!
//! * **Observe** ([`window`]) — windowed metric primitives: arrival-rate
//!   ring ([`RateWindow`]), rolling latency quantiles ([`LatencyWindow`]),
//!   windowed power ([`EnergyWindow`]), and the lock-light
//!   [`WindowedMetrics`] aggregator the serving pipeline feeds from its
//!   existing telemetry/energy events.
//! * **Decide** ([`law`]) — pluggable control laws behind the
//!   [`ControlLaw`] trait: AIMD ([`Aimd`]), additive setpoint tracking
//!   ([`SetpointTracker`], the admission-rate → τ servo), full PID with
//!   anti-windup ([`Pid`]), and energy-budget pacing ([`BudgetPacer`]).
//! * **Act** ([`adaptive`]) — the generic [`Adaptive<T>`] handle: an
//!   atomic cell consumers read on the hot path at the cost of one
//!   relaxed load (see `benches/micro_hotpath.rs` for the measurement
//!   against a plain field load).
//!
//! [`plane::ControlPlane`] glues the layers together: each
//! [`plane::ControlLoop`] pairs a signal closure (Observe), a law
//! (Decide), and an apply closure writing an `Adaptive` handle (Act),
//! stepped either by a background tick thread (live serving) or manually
//! (deterministic sim and tests).
//!
//! Consumers wired in this crate:
//!
//! * [`crate::controller`] — adaptive-τ mode: a [`SetpointTracker`] servos
//!   the τ correction toward a target admission rate; an optional
//!   [`BudgetPacer`] adds a positive τ correction when the windowed power
//!   draw exceeds an energy budget.
//! * [`crate::batching`] — `BatcherPolicy::max_queue_delay_us` is an
//!   `Adaptive<u64>` driven by AIMD on observed p95 vs the latency SLO.
//! * [`crate::router`] — the arrival estimator is a shared [`RateWindow`]
//!   and the QPS threshold an `Adaptive<f64>`.
//! * [`crate::pipeline::system`] — boots the loops from a
//!   [`ControlPlaneConfig`] and runs them on the background tick.

pub mod adaptive;
pub mod law;
pub mod plane;
pub mod window;

pub use adaptive::{Adaptive, AtomicBits};
pub use law::{
    Aimd, BudgetPacer, CarbonPacer, ControlLaw, Pid, QuotaScaler, ReplicaScaler, SetpointTracker,
};
pub use plane::{
    AdaptiveDelayConfig, AdaptiveRouterConfig, AdaptiveTauConfig, CarbonPacerConfig, ControlLoop,
    ControlPlane, ControlPlaneConfig, EnergyBudgetConfig, LoopState, QuotaScalerConfig,
    ReplicaScalerConfig,
};
pub use window::{EnergyWindow, LatencyWindow, MetricsSnapshot, RateWindow, WindowedMetrics};
