//! The control plane: loops = (signal, law, apply), run on a background
//! tick or stepped manually by the deterministic sim.
//!
//! Each [`ControlLoop`] closes one feedback circuit:
//!
//! ```text
//! signal() ──▶ law.step(signal, dt) ──▶ apply(output)   [+ telemetry gauge]
//! (Observe)        (Decide)                (Act)
//! ```
//!
//! A signal closure returning a non-finite value (NaN/∞) means "no fresh
//! observation this tick" — the loop holds its last output instead of
//! stepping the law on garbage (e.g. no requests arrived in the window).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::law::ControlLaw;

/// One closed feedback loop managed by the plane.
pub struct ControlLoop {
    name: String,
    law: Box<dyn ControlLaw>,
    signal: Box<dyn FnMut() -> f64 + Send>,
    apply: Box<dyn FnMut(f64) + Send>,
}

impl ControlLoop {
    pub fn new(
        name: impl Into<String>,
        law: Box<dyn ControlLaw>,
        signal: Box<dyn FnMut() -> f64 + Send>,
        apply: Box<dyn FnMut(f64) + Send>,
    ) -> Self {
        ControlLoop { name: name.into(), law, signal, apply }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Run one control step; returns the new output, or None when the
    /// signal had no fresh observation.
    pub fn step(&mut self, dt: f64) -> Option<f64> {
        let s = (self.signal)();
        if !s.is_finite() {
            return None;
        }
        let out = self.law.step(s, dt);
        (self.apply)(out);
        Some(out)
    }
}

/// Introspection view of one loop (the `/v2/control/loops` endpoint).
#[derive(Debug, Clone, PartialEq)]
pub struct LoopState {
    pub name: String,
    /// The law driving it ("aimd", "setpoint", "budget").
    pub law: String,
    /// The law's current output (what the `Adaptive` handle last saw).
    pub output: f64,
}

impl std::fmt::Debug for ControlLoop {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ControlLoop")
            .field("name", &self.name)
            .field("law", &self.law.name())
            .field("output", &self.law.output())
            .finish()
    }
}

/// Holds the loops and (optionally) the background ticker thread.
#[derive(Debug)]
pub struct ControlPlane {
    loops: Arc<Mutex<Vec<ControlLoop>>>,
    stop: Arc<AtomicBool>,
    ticker: Option<JoinHandle<()>>,
}

impl ControlPlane {
    pub fn new() -> Self {
        ControlPlane {
            loops: Arc::new(Mutex::new(Vec::new())),
            stop: Arc::new(AtomicBool::new(false)),
            ticker: None,
        }
    }

    pub fn add_loop(&self, l: ControlLoop) {
        self.loops.lock().unwrap().push(l);
    }

    /// Remove a loop by name (models detach their per-path loops on
    /// unload); returns whether one was present. The loop's signal and
    /// apply closures are dropped with it, releasing anything they
    /// captured (e.g. a version handle keeping engine threads alive).
    pub fn remove_loop(&self, name: &str) -> bool {
        let mut g = self.loops.lock().unwrap();
        let before = g.len();
        g.retain(|l| l.name() != name);
        g.len() != before
    }

    pub fn loop_names(&self) -> Vec<String> {
        self.loops.lock().unwrap().iter().map(|l| l.name().to_string()).collect()
    }

    /// Snapshot every loop's (name, law, current output) for
    /// introspection endpoints and reports.
    pub fn loop_states(&self) -> Vec<LoopState> {
        self.loops
            .lock()
            .unwrap()
            .iter()
            .map(|l| LoopState {
                name: l.name().to_string(),
                law: l.law.name().to_string(),
                output: l.law.output(),
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.loops.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Step every loop once with elapsed interval `dt` seconds. The
    /// deterministic entry point: the sim and tests drive this directly;
    /// the background ticker calls it on a wall-clock cadence. Each
    /// loop's latest output is published as a `gf_control_<name>` gauge.
    pub fn tick(&self, dt: f64) {
        step_all(&self.loops, dt);
    }

    /// Spawn the background ticker at `interval`. Idempotent-ish: calling
    /// twice panics (one ticker per plane). Each tick passes the *measured*
    /// elapsed time as `dt` — sleep overshoot and loop-body time must not
    /// slow time-integrating laws like the budget pacer.
    pub fn start(&mut self, interval: Duration) {
        assert!(self.ticker.is_none(), "control plane already started");
        let loops = self.loops.clone();
        let stop = self.stop.clone();
        let handle = std::thread::Builder::new()
            .name("gf-control-plane".to_string())
            .spawn(move || {
                let mut last = std::time::Instant::now();
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let now = std::time::Instant::now();
                    step_all(&loops, (now - last).as_secs_f64());
                    last = now;
                }
            })
            .expect("spawn control plane ticker");
        self.ticker = Some(handle);
    }

    pub fn running(&self) -> bool {
        self.ticker.is_some()
    }

    /// Stop the ticker (no-op when never started).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.ticker.take() {
            let _ = h.join();
        }
    }
}

impl Default for ControlPlane {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ControlPlane {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Step every loop and publish outputs as telemetry gauges (shared by the
/// manual `tick` and the background ticker).
fn step_all(loops: &Mutex<Vec<ControlLoop>>, dt: f64) {
    let mut guard = loops.lock().unwrap();
    for l in guard.iter_mut() {
        if let Some(out) = l.step(dt) {
            crate::telemetry::MetricsRegistry::global()
                .gauge(&format!("gf_control_{}", l.name()))
                .set(out);
        }
    }
}

// ---------------------------------------------------------------------
// Configuration the serving system wires loops from.
// ---------------------------------------------------------------------

/// Adaptive-τ: servo the admission-rate toward a target by correcting
/// τ(t) (positive correction = stricter).
#[derive(Debug, Clone)]
pub struct AdaptiveTauConfig {
    pub target_admit_rate: f64,
    /// Integral gain per control step.
    pub gain: f64,
    /// |correction| clamp in normalised-J units.
    pub max_correction: f64,
}

impl Default for AdaptiveTauConfig {
    fn default() -> Self {
        // Target = the paper's Table III admission rate.
        AdaptiveTauConfig { target_admit_rate: 0.58, gain: 0.05, max_correction: 1.0 }
    }
}

/// AIMD on the batcher's queue-delay window vs the p95 SLO.
#[derive(Debug, Clone)]
pub struct AdaptiveDelayConfig {
    pub slo_p95_secs: f64,
    pub min_us: u64,
    pub max_us: u64,
    /// Additive µs probed per healthy tick.
    pub increase_us: u64,
    /// Multiplicative cut on SLO violation, in (0, 1).
    pub decrease: f64,
}

impl Default for AdaptiveDelayConfig {
    fn default() -> Self {
        AdaptiveDelayConfig {
            slo_p95_secs: 0.25,
            min_us: 0,
            max_us: 50_000,
            increase_us: 200,
            decrease: 0.5,
        }
    }
}

/// AIMD on the router's QPS threshold: under SLO pressure more traffic is
/// pushed to the batched path (threshold drops), healthy ticks raise it
/// back toward the configured ceiling.
#[derive(Debug, Clone)]
pub struct AdaptiveRouterConfig {
    pub slo_p95_secs: f64,
    pub min_qps: f64,
    pub max_qps: f64,
    pub increase_qps: f64,
    pub decrease: f64,
}

impl Default for AdaptiveRouterConfig {
    fn default() -> Self {
        AdaptiveRouterConfig {
            slo_p95_secs: 0.25,
            min_qps: 5.0,
            max_qps: 500.0,
            increase_qps: 5.0,
            decrease: 0.7,
        }
    }
}

/// Energy-budget pacing: sustained watts over `budget_watts` adds a
/// positive τ correction until the draw returns under budget.
#[derive(Debug, Clone)]
pub struct EnergyBudgetConfig {
    pub budget_watts: f64,
    /// Correction growth per (joule/s of overspend × second).
    pub gain: f64,
    pub max_correction: f64,
}

impl Default for EnergyBudgetConfig {
    fn default() -> Self {
        EnergyBudgetConfig { budget_watts: 60.0, gain: 0.005, max_correction: 0.5 }
    }
}

/// Replica autoscaling: a per-version `ReplicaScaler` loop decides a
/// target replica count from windowed demand (in-flight + queue depth,
/// inflated by latency pressure and the energy-budget throttle) and
/// applies the delta through the lifecycle executor.
#[derive(Debug, Clone)]
pub struct ReplicaScalerConfig {
    /// Hard per-version replica ceiling.
    pub max_replicas: usize,
    /// Per-replica utilization above which one more replica is added.
    pub up_threshold: f64,
    /// Utilization of the one-smaller set below which one is retired.
    pub down_threshold: f64,
    /// Continuous zero-demand seconds before the last replica retires
    /// (scale-to-zero); the next request cold-starts.
    pub idle_secs: f64,
    /// Concurrent requests one replica is sized for (demand divisor).
    pub per_replica_capacity: f64,
}

impl Default for ReplicaScalerConfig {
    fn default() -> Self {
        ReplicaScalerConfig {
            max_replicas: 4,
            up_threshold: 0.8,
            down_threshold: 0.4,
            idle_secs: 60.0,
            per_replica_capacity: 4.0,
        }
    }
}

/// Tenant quota scaling: a `QuotaScaler` loop shrinks every tenant's
/// GCRA rate (via `crate::qos::QosLayer::set_quota_scale`) while the
/// windowed power draw runs over budget, and lets quotas recover when
/// pressure clears.
#[derive(Debug, Clone)]
pub struct QuotaScalerConfig {
    /// Power budget (watts) above which tenant quotas shrink.
    pub budget_watts: f64,
    /// Fractional scale change per unit *relative* overshoot per second.
    pub gain: f64,
    /// Quota-scale floor in `(0, 1)`; tenants are never throttled to zero.
    pub min_scale: f64,
}

impl Default for QuotaScalerConfig {
    fn default() -> Self {
        QuotaScalerConfig { budget_watts: 60.0, gain: 0.5, min_scale: 0.05 }
    }
}

/// Carbon-aware pacing: a `CarbonPacer` loop observes the grid carbon
/// intensity (a `crate::energy::CarbonIntensityTrace` sampled per tick)
/// and applies its deferral pressure as an admission-τ bias plus a
/// batch-delay stretch on low-priority work only.
#[derive(Debug, Clone)]
pub struct CarbonPacerConfig {
    /// Intensity above which deferrable work starts waiting (kg CO₂/kWh).
    /// Default sits between the EU average (~0.35) and the world average
    /// (0.475): dirty grids defer, clean grids run free.
    pub threshold_kg_per_kwh: f64,
    /// Pressure change per second per unit relative overshoot.
    pub gain: f64,
    /// Admission-τ bias at full pressure (added to low-priority
    /// decisions; the skip threshold for deferrable work).
    pub tau_weight: f64,
    /// Batch-delay stretch at full pressure: the effective queue-delay
    /// window becomes `delay × (1 + pressure × delay_weight)`.
    pub delay_weight: f64,
}

impl Default for CarbonPacerConfig {
    fn default() -> Self {
        CarbonPacerConfig {
            threshold_kg_per_kwh: 0.35,
            gain: 0.5,
            tau_weight: 0.5,
            delay_weight: 1.0,
        }
    }
}

/// Which loops the serving system boots, and the tick cadence.
#[derive(Debug, Clone)]
pub struct ControlPlaneConfig {
    pub tick_secs: f64,
    pub adaptive_tau: Option<AdaptiveTauConfig>,
    pub adaptive_batch_delay: Option<AdaptiveDelayConfig>,
    pub adaptive_router: Option<AdaptiveRouterConfig>,
    pub energy_budget: Option<EnergyBudgetConfig>,
    pub replica_scaler: Option<ReplicaScalerConfig>,
    pub quota_scaler: Option<QuotaScalerConfig>,
    pub carbon_pacer: Option<CarbonPacerConfig>,
}

impl Default for ControlPlaneConfig {
    fn default() -> Self {
        ControlPlaneConfig {
            tick_secs: 0.1,
            adaptive_tau: None,
            adaptive_batch_delay: None,
            adaptive_router: None,
            energy_budget: None,
            replica_scaler: None,
            quota_scaler: None,
            carbon_pacer: None,
        }
    }
}

impl ControlPlaneConfig {
    pub fn with_adaptive_tau(mut self, target_admit_rate: f64) -> Self {
        self.adaptive_tau =
            Some(AdaptiveTauConfig { target_admit_rate, ..AdaptiveTauConfig::default() });
        self
    }

    pub fn with_adaptive_batch_delay(mut self, slo_p95_secs: f64) -> Self {
        self.adaptive_batch_delay =
            Some(AdaptiveDelayConfig { slo_p95_secs, ..AdaptiveDelayConfig::default() });
        self
    }

    pub fn with_adaptive_router(mut self, slo_p95_secs: f64) -> Self {
        self.adaptive_router =
            Some(AdaptiveRouterConfig { slo_p95_secs, ..AdaptiveRouterConfig::default() });
        self
    }

    pub fn with_energy_budget(mut self, budget_watts: f64) -> Self {
        self.energy_budget =
            Some(EnergyBudgetConfig { budget_watts, ..EnergyBudgetConfig::default() });
        self
    }

    pub fn with_replica_scaler(mut self, max_replicas: usize, idle_secs: f64) -> Self {
        self.replica_scaler = Some(ReplicaScalerConfig {
            max_replicas,
            idle_secs,
            ..ReplicaScalerConfig::default()
        });
        self
    }

    pub fn with_quota_scaler(mut self, budget_watts: f64) -> Self {
        self.quota_scaler =
            Some(QuotaScalerConfig { budget_watts, ..QuotaScalerConfig::default() });
        self
    }

    pub fn with_carbon_pacer(mut self, threshold_kg_per_kwh: f64) -> Self {
        self.carbon_pacer =
            Some(CarbonPacerConfig { threshold_kg_per_kwh, ..CarbonPacerConfig::default() });
        self
    }

    /// Any loop enabled?
    pub fn any_enabled(&self) -> bool {
        self.adaptive_tau.is_some()
            || self.adaptive_batch_delay.is_some()
            || self.adaptive_router.is_some()
            || self.energy_budget.is_some()
            || self.replica_scaler.is_some()
            || self.quota_scaler.is_some()
            || self.carbon_pacer.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::adaptive::Adaptive;
    use crate::control::law::SetpointTracker;

    fn rate_loop(handle: Adaptive<f64>, signal: Adaptive<f64>) -> ControlLoop {
        let sig = move || signal.get();
        let out = handle.clone();
        ControlLoop::new(
            "test",
            Box::new(SetpointTracker::new(0.0, 0.5, 0.5, -1.0, 1.0)),
            Box::new(sig),
            Box::new(move |v| out.set(v)),
        )
    }

    #[test]
    fn manual_tick_closes_the_loop() {
        let plane = ControlPlane::new();
        let handle = Adaptive::new(0.0f64);
        let signal = Adaptive::new(0.9f64);
        plane.add_loop(rate_loop(handle.clone(), signal.clone()));
        assert_eq!(plane.loop_names(), ["test"]);

        plane.tick(0.1);
        assert!((handle.get() - 0.2).abs() < 1e-12, "0.5 * (0.9 - 0.5)");
        // signal at setpoint: output holds
        signal.set(0.5);
        plane.tick(0.1);
        assert!((handle.get() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn loop_states_reflect_outputs() {
        let plane = ControlPlane::new();
        let handle = Adaptive::new(0.0f64);
        let signal = Adaptive::new(0.9f64);
        plane.add_loop(rate_loop(handle.clone(), signal));
        let before = plane.loop_states();
        assert_eq!(before.len(), 1);
        assert_eq!(before[0].name, "test");
        assert_eq!(before[0].law, "setpoint");
        plane.tick(0.1);
        let after = plane.loop_states();
        assert!((after[0].output - handle.get()).abs() < 1e-12);
    }

    #[test]
    fn non_finite_signal_skips_the_step() {
        let plane = ControlPlane::new();
        let handle = Adaptive::new(0.0f64);
        let signal = Adaptive::new(f64::NAN);
        plane.add_loop(rate_loop(handle.clone(), signal.clone()));
        plane.tick(0.1);
        assert_eq!(handle.get(), 0.0, "no observation, no step");
        signal.set(1.0);
        plane.tick(0.1);
        assert!(handle.get() > 0.0);
    }

    #[test]
    fn remove_loop_detaches_by_name() {
        let plane = ControlPlane::new();
        let handle = Adaptive::new(0.0f64);
        let signal = Adaptive::new(0.9f64);
        plane.add_loop(rate_loop(handle.clone(), signal.clone()));
        assert!(plane.remove_loop("test"));
        assert!(plane.is_empty());
        assert!(!plane.remove_loop("test"), "second removal is a no-op");
        // A removed loop no longer steps.
        plane.tick(0.1);
        assert_eq!(handle.get(), 0.0);
    }

    #[test]
    fn background_ticker_steps_and_stops() {
        let mut plane = ControlPlane::new();
        let handle = Adaptive::new(0.0f64);
        let signal = Adaptive::new(1.0f64);
        plane.add_loop(rate_loop(handle.clone(), signal));
        plane.start(Duration::from_millis(5));
        assert!(plane.running());
        // wait for at least one tick
        let t0 = std::time::Instant::now();
        while handle.get() == 0.0 && t0.elapsed() < Duration::from_secs(2) {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(handle.get() > 0.0, "ticker never stepped");
        plane.stop();
        assert!(!plane.running());
        let frozen = handle.get();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(handle.get(), frozen, "stopped plane must not step");
    }

    #[test]
    fn config_builders() {
        let c = ControlPlaneConfig::default()
            .with_adaptive_tau(0.6)
            .with_adaptive_batch_delay(0.05)
            .with_adaptive_router(0.1)
            .with_energy_budget(75.0)
            .with_replica_scaler(6, 30.0)
            .with_quota_scaler(45.0)
            .with_carbon_pacer(0.3);
        assert!(c.any_enabled());
        assert_eq!(c.adaptive_tau.unwrap().target_admit_rate, 0.6);
        assert_eq!(c.adaptive_batch_delay.unwrap().slo_p95_secs, 0.05);
        assert_eq!(c.adaptive_router.unwrap().slo_p95_secs, 0.1);
        assert_eq!(c.energy_budget.unwrap().budget_watts, 75.0);
        let rs = c.replica_scaler.unwrap();
        assert_eq!(rs.max_replicas, 6);
        assert_eq!(rs.idle_secs, 30.0);
        assert_eq!(c.quota_scaler.unwrap().budget_watts, 45.0);
        assert_eq!(c.carbon_pacer.unwrap().threshold_kg_per_kwh, 0.3);
        assert!(!ControlPlaneConfig::default().any_enabled());
    }
}
