//! Observe layer: windowed metric primitives and the lock-light aggregator
//! that taps the serving pipeline's existing telemetry/energy events.
//!
//! Unlike the cumulative estimators in [`crate::stats`] (Welford moments,
//! log-bucketed histograms), everything here *forgets*: control laws must
//! react to the recent regime, not the whole run, or a burst at t=0 biases
//! every later decision (the stale-feedback failure mode the sim guards
//! against).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Arrival-rate estimator over a ring of the last `window` event instants.
///
/// This is the estimator `router::Router` previously hard-wired at
/// `window: 32`, extracted so the router, the aggregator, and tests share
/// one implementation with a configurable window.
#[derive(Debug, Clone)]
pub struct RateWindow {
    times: VecDeque<f64>,
    window: usize,
}

impl RateWindow {
    /// `window` >= 2 (a rate needs at least two instants).
    pub fn new(window: usize) -> Self {
        assert!(window >= 2, "RateWindow needs window >= 2, got {window}");
        RateWindow { times: VecDeque::with_capacity(window + 1), window }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Record an event at time `t` (seconds, monotonic). Concurrent
    /// recorders can race their clock reads, so a `t` earlier than the
    /// newest entry is clamped forward — keeping the window monotone and
    /// `rate()` finite — instead of poisoning the span.
    pub fn record(&mut self, t: f64) {
        let t = match self.times.back() {
            Some(&back) if t < back => back,
            _ => t,
        };
        self.times.push_back(t);
        if self.times.len() > self.window {
            self.times.pop_front();
        }
    }

    /// Events per second across the observation window. 0 until two
    /// events have been seen; +inf when the window spans zero time.
    pub fn rate(&self) -> f64 {
        if self.times.len() < 2 {
            return 0.0;
        }
        let span = self.times.back().unwrap() - self.times.front().unwrap();
        if span <= 0.0 {
            return f64::INFINITY;
        }
        (self.times.len() - 1) as f64 / span
    }

    pub fn clear(&mut self) {
        self.times.clear();
    }
}

/// Rolling sample window with quantile estimation (p95 of the last N
/// latencies, not of the whole run). Quantiles sort a copy — O(n log n)
/// on the control tick, never on the request hot path.
#[derive(Debug, Clone)]
pub struct LatencyWindow {
    samples: Vec<f64>,
    next: usize,
    filled: usize,
}

impl LatencyWindow {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        LatencyWindow { samples: vec![0.0; capacity], next: 0, filled: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.samples.len()
    }

    pub fn len(&self) -> usize {
        self.filled
    }

    pub fn is_empty(&self) -> bool {
        self.filled == 0
    }

    pub fn record(&mut self, x: f64) {
        self.samples[self.next] = x;
        self.next = (self.next + 1) % self.samples.len();
        self.filled = (self.filled + 1).min(self.samples.len());
    }

    /// Quantile of the current window contents (0.0 when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.filled == 0 {
            return 0.0;
        }
        crate::stats::quantile(&self.samples[..self.filled], q)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn mean(&self) -> f64 {
        crate::stats::mean(&self.samples[..self.filled])
    }

    pub fn clear(&mut self) {
        self.next = 0;
        self.filled = 0;
    }
}

/// Windowed power estimator: (instant, joules) pairs over a ring, read
/// back as watts across the window span. The BudgetPacer's input.
#[derive(Debug, Clone)]
pub struct EnergyWindow {
    samples: VecDeque<(f64, f64)>,
    window: usize,
}

impl EnergyWindow {
    pub fn new(window: usize) -> Self {
        assert!(window >= 2);
        EnergyWindow { samples: VecDeque::with_capacity(window + 1), window }
    }

    /// Record `joules` attributed at time `t`. Out-of-order instants from
    /// racing recorders are clamped forward (see [`RateWindow::record`]).
    pub fn record(&mut self, t: f64, joules: f64) {
        let t = match self.samples.back() {
            Some(&(back, _)) if t < back => back,
            _ => t,
        };
        self.samples.push_back((t, joules));
        if self.samples.len() > self.window {
            self.samples.pop_front();
        }
    }

    /// Mean power (W) across the window: Σ joules / span. 0 until two
    /// samples; falls back to 0 when the window spans zero time.
    pub fn watts(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let span = self.samples.back().unwrap().0 - self.samples.front().unwrap().0;
        if span <= 0.0 {
            return 0.0;
        }
        let total: f64 = self.samples.iter().map(|&(_, j)| j).sum();
        total / span
    }

    /// Mean joules per event across the window.
    pub fn mean_joules(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|&(_, j)| j).sum::<f64>() / self.samples.len() as f64
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Point-in-time view of the aggregator (one control tick's inputs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Recent arrival rate (req/s).
    pub qps: f64,
    /// Rolling latency quantiles over the sample window (s).
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub mean_latency: f64,
    /// Windowed power draw (W) and per-request joules.
    pub watts: f64,
    pub mean_joules: f64,
    /// Total events recorded since boot.
    pub events: u64,
    /// Per-path rolling p95 (s): requests served on the direct
    /// (latency-sensitive) path vs the batched path. 0.0 until that path
    /// has samples. The blended `p95_latency` mixes both populations —
    /// a loop steering one path must read its own signal or the other
    /// path's tail pollutes the feedback (see `pipeline::system`).
    pub p95_direct: f64,
    pub p95_batched: f64,
    /// Per-path completion counts since boot (freshness gates).
    pub events_direct: u64,
    pub events_batched: u64,
    /// Last observed grid carbon intensity (kg CO₂/kWh); `NaN` until the
    /// carbon loop records a sample (no trace configured).
    pub carbon_intensity: f64,
}

/// Lock-light shared aggregator: the serving pipeline calls the three
/// `record_*` taps from its existing event sites (request arrival,
/// latency histogram record, energy meter record); control loops read
/// [`WindowedMetrics::snapshot`] once per tick.
///
/// "Lock-light": each tap takes one short `Mutex` for a ring push (~tens
/// of ns, once per request — the same budget as the energy meter); the
/// event counter is a plain atomic.
#[derive(Debug)]
pub struct WindowedMetrics {
    arrivals: Mutex<RateWindow>,
    latencies: Mutex<LatencyWindow>,
    direct: Mutex<LatencyWindow>,
    batched: Mutex<LatencyWindow>,
    energy: Mutex<EnergyWindow>,
    events: AtomicU64,
    events_direct: AtomicU64,
    events_batched: AtomicU64,
    /// f64 bits; `NaN` until the first carbon sample lands.
    carbon_intensity: AtomicU64,
}

impl WindowedMetrics {
    /// `rate_window`: instants kept for the QPS/power estimators;
    /// `sample_window`: latency samples kept for rolling quantiles.
    pub fn new(rate_window: usize, sample_window: usize) -> Self {
        WindowedMetrics {
            arrivals: Mutex::new(RateWindow::new(rate_window)),
            latencies: Mutex::new(LatencyWindow::new(sample_window)),
            direct: Mutex::new(LatencyWindow::new(sample_window)),
            batched: Mutex::new(LatencyWindow::new(sample_window)),
            energy: Mutex::new(EnergyWindow::new(rate_window)),
            events: AtomicU64::new(0),
            events_direct: AtomicU64::new(0),
            events_batched: AtomicU64::new(0),
            carbon_intensity: AtomicU64::new(f64::NAN.to_bits()),
        }
    }

    pub fn record_arrival(&self, t: f64) {
        self.arrivals.lock().unwrap().record(t);
        self.events.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a latency with no path attribution (admission skips,
    /// callers predating the split). Feeds only the blended window.
    pub fn record_latency(&self, secs: f64) {
        self.latencies.lock().unwrap().record(secs);
    }

    /// Record a direct-path completion: feeds the direct window *and*
    /// the blended one, so blended consumers keep seeing every sample.
    pub fn record_latency_direct(&self, secs: f64) {
        self.latencies.lock().unwrap().record(secs);
        self.direct.lock().unwrap().record(secs);
        self.events_direct.fetch_add(1, Ordering::Relaxed);
    }

    /// Batched-path counterpart of [`Self::record_latency_direct`].
    pub fn record_latency_batched(&self, secs: f64) {
        self.latencies.lock().unwrap().record(secs);
        self.batched.lock().unwrap().record(secs);
        self.events_batched.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the latest observed grid carbon intensity (kg CO₂/kWh).
    /// A point sample, not a window: the intensity trace is a slow step
    /// function, so "last seen" is the right estimator.
    pub fn record_carbon_intensity(&self, kg_co2_per_kwh: f64) {
        self.carbon_intensity.store(kg_co2_per_kwh.to_bits(), Ordering::Relaxed);
    }

    /// Last recorded carbon intensity; `NaN` before any sample.
    pub fn carbon_intensity(&self) -> f64 {
        f64::from_bits(self.carbon_intensity.load(Ordering::Relaxed))
    }

    pub fn record_joules(&self, t: f64, joules: f64) {
        self.energy.lock().unwrap().record(t, joules);
    }

    pub fn events(&self) -> u64 {
        self.events.load(Ordering::Relaxed)
    }

    pub fn events_direct(&self) -> u64 {
        self.events_direct.load(Ordering::Relaxed)
    }

    pub fn events_batched(&self) -> u64 {
        self.events_batched.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let qps = self.arrivals.lock().unwrap().rate();
        let (p50, p95, mean_latency) = {
            let l = self.latencies.lock().unwrap();
            (l.quantile(0.5), l.p95(), l.mean())
        };
        let p95_direct = self.direct.lock().unwrap().p95();
        let p95_batched = self.batched.lock().unwrap().p95();
        let (watts, mean_joules) = {
            let e = self.energy.lock().unwrap();
            (e.watts(), e.mean_joules())
        };
        MetricsSnapshot {
            qps,
            p50_latency: p50,
            p95_latency: p95,
            mean_latency,
            watts,
            mean_joules,
            events: self.events(),
            p95_direct,
            p95_batched,
            events_direct: self.events_direct(),
            events_batched: self.events_batched(),
            carbon_intensity: self.carbon_intensity(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Clock, ManualClock};

    #[test]
    fn rate_window_matches_synthetic_clock() {
        // 100 req/s fed from the ManualClock must read back as 100 req/s.
        let clock = ManualClock::new();
        let mut w = RateWindow::new(32);
        for _ in 0..100 {
            clock.advance(0.01);
            w.record(clock.now());
        }
        assert!((w.rate() - 100.0).abs() < 1e-6, "rate {}", w.rate());
        assert_eq!(w.len(), 32, "ring keeps only the window");
    }

    #[test]
    fn rate_window_forgets_old_regime() {
        let clock = ManualClock::new();
        let mut w = RateWindow::new(16);
        // fast regime: 1000 req/s
        for _ in 0..32 {
            clock.advance(0.001);
            w.record(clock.now());
        }
        assert!(w.rate() > 900.0);
        // slow regime refills the ring: 2 req/s
        for _ in 0..16 {
            clock.advance(0.5);
            w.record(clock.now());
        }
        assert!((w.rate() - 2.0).abs() < 0.1, "rate {}", w.rate());
    }

    #[test]
    fn rate_window_degenerate_cases() {
        let mut w = RateWindow::new(4);
        assert_eq!(w.rate(), 0.0);
        w.record(1.0);
        assert_eq!(w.rate(), 0.0, "one sample has no rate");
        w.record(1.0);
        assert_eq!(w.rate(), f64::INFINITY, "zero span saturates");
        w.clear();
        assert!(w.is_empty());
    }

    #[test]
    #[should_panic]
    fn rate_window_rejects_tiny_window() {
        RateWindow::new(1);
    }

    #[test]
    fn out_of_order_records_are_clamped_monotone() {
        let mut w = RateWindow::new(8);
        w.record(1.0);
        w.record(0.5); // racing recorder with a stale clock read
        w.record(1.1);
        assert!(w.rate().is_finite());
        assert!(w.rate() > 0.0);

        let mut e = EnergyWindow::new(8);
        e.record(1.0, 2.0);
        e.record(0.5, 2.0);
        e.record(2.0, 2.0);
        assert!((e.watts() - 6.0).abs() < 1e-9, "span clamps to 1.0s: {}", e.watts());
    }

    #[test]
    fn latency_window_rolls() {
        let mut l = LatencyWindow::new(4);
        assert_eq!(l.quantile(0.95), 0.0);
        for x in [1.0, 2.0, 3.0, 4.0] {
            l.record(x);
        }
        assert_eq!(l.quantile(0.0), 1.0);
        assert_eq!(l.quantile(1.0), 4.0);
        // Overwrite the oldest: window is now [5, 2, 3, 4].
        l.record(5.0);
        assert_eq!(l.quantile(1.0), 5.0);
        assert_eq!(l.quantile(0.0), 2.0, "1.0 rolled out");
        assert_eq!(l.len(), 4);
    }

    #[test]
    fn latency_window_partial_fill() {
        let mut l = LatencyWindow::new(100);
        l.record(0.25);
        assert_eq!(l.len(), 1);
        assert_eq!(l.p95(), 0.25);
        assert_eq!(l.mean(), 0.25);
    }

    #[test]
    fn energy_window_watts() {
        let mut e = EnergyWindow::new(8);
        assert_eq!(e.watts(), 0.0);
        // 2 J every 0.5 s -> 4 W (first sample anchors the span).
        for i in 0..5 {
            e.record(i as f64 * 0.5, 2.0);
        }
        // Σ = 10 J over span 2.0 s = 5 W (includes the anchor's joules).
        assert!((e.watts() - 5.0).abs() < 1e-9, "{}", e.watts());
        assert!((e.mean_joules() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn aggregator_snapshot() {
        let m = WindowedMetrics::new(16, 16);
        let clock = ManualClock::new();
        for _ in 0..10 {
            clock.advance(0.1);
            m.record_arrival(clock.now());
            m.record_latency(0.02);
            m.record_joules(clock.now(), 0.5);
        }
        let s = m.snapshot();
        assert!((s.qps - 10.0).abs() < 1e-6);
        assert!((s.p95_latency - 0.02).abs() < 1e-12);
        assert!((s.watts - 5.0 / 0.9).abs() < 1e-6, "watts {}", s.watts);
        assert_eq!(s.events, 10);
    }

    #[test]
    fn per_path_windows_separate_the_tails() {
        let m = WindowedMetrics::new(16, 64);
        // Fast direct path (1 ms) next to a slow batched path (100 ms):
        // the blended p95 is dominated by the batched tail, while the
        // direct signal stays honest.
        for _ in 0..50 {
            m.record_latency_direct(0.001);
            m.record_latency_batched(0.100);
        }
        let s = m.snapshot();
        assert!((s.p95_direct - 0.001).abs() < 1e-12, "direct {}", s.p95_direct);
        assert!((s.p95_batched - 0.100).abs() < 1e-12, "batched {}", s.p95_batched);
        assert!(
            s.p95_latency > 10.0 * s.p95_direct,
            "blended p95 {} should be polluted by the batched tail",
            s.p95_latency
        );
        assert_eq!(s.events_direct, 50);
        assert_eq!(s.events_batched, 50);
    }

    #[test]
    fn unattributed_latency_feeds_only_the_blend() {
        let m = WindowedMetrics::new(16, 16);
        m.record_latency(0.5);
        let s = m.snapshot();
        assert_eq!(s.p95_direct, 0.0);
        assert_eq!(s.p95_batched, 0.0);
        assert!((s.p95_latency - 0.5).abs() < 1e-12);
        assert_eq!(s.events_direct, 0);
        assert_eq!(s.events_batched, 0);
    }

    #[test]
    fn carbon_intensity_is_nan_until_recorded() {
        let m = WindowedMetrics::new(16, 64);
        assert!(m.carbon_intensity().is_nan(), "no trace, no signal");
        assert!(m.snapshot().carbon_intensity.is_nan());
        m.record_carbon_intensity(0.475);
        assert_eq!(m.carbon_intensity(), 0.475);
        assert_eq!(m.snapshot().carbon_intensity, 0.475);
        // Point sample: a newer value replaces, never averages.
        m.record_carbon_intensity(0.056);
        assert_eq!(m.snapshot().carbon_intensity, 0.056);
    }
}
