//! The closed-loop admission controller (paper Appendix A, Algorithm 1).
//!
//! Per request: compute J(x) from the live signals, compare against τ(t),
//! admit or skip. The *closed loop* is the feedback path: the energy
//! meter's EWMA and the congestion tracker feed the next decision's
//! CostInputs, and every decision is logged to telemetry (MLflow analog)
//! exactly as Algorithm 1 lines 11–12 prescribe.

use crate::control::Adaptive;
use crate::controller::cost::{CostInputs, CostWeights};
use crate::controller::threshold::{AdaptiveThreshold, ThresholdSchedule};
use crate::controller::AdmissionPolicy;

/// Static configuration of the bio-controller.
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    pub weights: CostWeights,
    pub schedule: ThresholdSchedule,
    /// Skipped requests may be answered from cache; when false they are
    /// rejected outright (HTTP 429-style).
    pub respond_from_cache: bool,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::paper_default(),
            respond_from_cache: true,
        }
    }
}

/// Running statistics the controller exposes (admission rate feeds the
/// adaptive-τ extension and the report rows).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdmissionStats {
    pub admitted: u64,
    pub skipped: u64,
    pub last_j: f64,
    pub last_tau: f64,
}

impl AdmissionStats {
    pub fn total(&self) -> u64 {
        self.admitted + self.skipped
    }

    pub fn admission_rate(&self) -> f64 {
        if self.total() == 0 {
            1.0
        } else {
            self.admitted as f64 / self.total() as f64
        }
    }
}

/// The bio-inspired closed-loop controller.
///
/// The effective threshold is `schedule.τ(t − t0) + rate_correction`
/// (an [`Adaptive<f64>`] handle, 0.0 unless the adaptive-τ loop drives
/// it — one relaxed atomic load on the hot path), plus any per-call
/// bias passed to [`AdmissionController::decide_biased`] — how the
/// per-model energy-budget pacers tighten one model's admission.
/// `Clone` shares the handle — a cloned controller sees the same live
/// correction.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: ControllerConfig,
    stats: AdmissionStats,
    /// Controller epoch: τ(t) is evaluated relative to this origin.
    t0: f64,
    /// Live τ correction from the admission-rate → τ servo.
    rate_correction: Adaptive<f64>,
}

impl AdmissionController {
    pub fn new(cfg: ControllerConfig) -> Self {
        cfg.schedule.validate().expect("invalid threshold schedule");
        AdmissionController {
            cfg,
            stats: AdmissionStats::default(),
            t0: 0.0,
            rate_correction: Adaptive::new(0.0),
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(ControllerConfig::default())
    }

    /// Reset the τ(t) origin (e.g. after a deployment event); the paper's
    /// "folding" restarts when the landscape changes.
    pub fn restart_epoch(&mut self, now: f64) {
        self.t0 = now;
    }

    pub fn stats(&self) -> AdmissionStats {
        self.stats
    }

    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// Current threshold at absolute time `t`: the configured schedule
    /// plus whatever correction the adaptive-τ loop has published.
    /// Per-model energy-budget biases ride in per call via
    /// [`AdmissionController::decide_biased`], not through shared state.
    pub fn tau_at(&self, t: f64) -> f64 {
        self.cfg.schedule.tau(t - self.t0) + self.rate_correction.get()
    }

    /// Handle the adaptive-τ loop writes (admission-rate → τ servo).
    pub fn rate_correction_handle(&self) -> Adaptive<f64> {
        self.rate_correction.handle()
    }

    /// Score a request without committing to a decision (used by the
    /// landscape sketches).
    pub fn score(&self, x: &CostInputs) -> f64 {
        x.j(&self.cfg.weights)
    }

    /// Decide with an extra per-call τ bias on top of the schedule and
    /// the global corrections — how per-model energy-budget pacers
    /// tighten one model's admission without fighting over the shared
    /// correction cell (positive bias = stricter).
    pub fn decide_biased(&mut self, x: &CostInputs, t: f64, tau_bias: f64) -> Decision {
        let j = x.j(&self.cfg.weights);
        let tau = self.tau_at(t) + tau_bias;
        self.stats.last_j = j;
        self.stats.last_tau = tau;
        // Paper Eq. 2: admit iff J(x) >= tau(t).
        if j >= tau {
            self.stats.admitted += 1;
            Decision::Admit { j, tau }
        } else {
            self.stats.skipped += 1;
            let reason = if x.c_norm() < 0.2 {
                SkipReason::Congestion
            } else if x.e_norm() < 0.2 {
                SkipReason::EnergySpike
            } else {
                SkipReason::LowUtility
            };
            Decision::Skip { j, tau, reason, cacheable: self.cfg.respond_from_cache }
        }
    }
}

impl AdmissionPolicy for AdmissionController {
    fn decide(&mut self, x: &CostInputs, t: f64) -> Decision {
        self.decide_biased(x, t, 0.0)
    }

    fn name(&self) -> &'static str {
        "bio-controller"
    }
}

/// A decision outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    Admit {
        j: f64,
        tau: f64,
    },
    Skip {
        j: f64,
        tau: f64,
        reason: SkipReason,
        /// Whether the skip path may answer from cache.
        cacheable: bool,
    },
}

impl Decision {
    pub fn admitted(&self) -> bool {
        matches!(self, Decision::Admit { .. })
    }

    pub fn j(&self) -> f64 {
        match *self {
            Decision::Admit { j, .. } | Decision::Skip { j, .. } => j,
        }
    }

    pub fn tau(&self) -> f64 {
        match *self {
            Decision::Admit { tau, .. } | Decision::Skip { tau, .. } => tau,
        }
    }
}

/// The controller's adaptive-τ mode as a self-contained
/// [`AdmissionPolicy`]: an [`AdaptiveThreshold`] servo (the §IX
/// closed-loop τ extension) windowed over live decisions — runnable
/// anywhere a policy is (live pipeline, sim ablation, benches).
///
/// Every `update_every` decisions the policy measures the admission rate
/// over that window, feeds it to the servo, and publishes the resulting
/// correction through the wrapped controller's
/// [`AdmissionController::rate_correction_handle`] — the same cell the
/// live control plane drives.
#[derive(Debug, Clone)]
pub struct AdaptiveTauPolicy {
    inner: AdmissionController,
    servo: AdaptiveThreshold,
    update_every: u64,
    window_total: u64,
    window_admitted: u64,
}

impl AdaptiveTauPolicy {
    /// `gain`: integral gain per window update; `update_every`: decisions
    /// per observation window (>= 1).
    pub fn new(
        cfg: ControllerConfig,
        target_admit_rate: f64,
        gain: f64,
        update_every: u64,
    ) -> Self {
        assert!(update_every >= 1);
        let servo = AdaptiveThreshold::new(cfg.schedule.clone(), target_admit_rate, gain);
        AdaptiveTauPolicy {
            inner: AdmissionController::new(cfg),
            servo,
            update_every,
            window_total: 0,
            window_admitted: 0,
        }
    }

    pub fn stats(&self) -> AdmissionStats {
        self.inner.stats()
    }

    pub fn target_admit_rate(&self) -> f64 {
        self.servo.target_admit_rate()
    }

    /// The τ correction currently in force.
    pub fn correction(&self) -> f64 {
        self.servo.correction()
    }

    pub fn restart_epoch(&mut self, now: f64) {
        self.inner.restart_epoch(now);
    }
}

impl AdmissionPolicy for AdaptiveTauPolicy {
    fn decide(&mut self, x: &CostInputs, t: f64) -> Decision {
        let d = self.inner.decide(x, t);
        self.window_total += 1;
        if d.admitted() {
            self.window_admitted += 1;
        }
        if self.window_total >= self.update_every {
            let rate = self.window_admitted as f64 / self.window_total as f64;
            self.servo.observe(rate);
            self.inner.rate_correction_handle().set(self.servo.correction());
            self.window_total = 0;
            self.window_admitted = 0;
        }
        d
    }

    fn name(&self) -> &'static str {
        "adaptive-tau"
    }
}

/// Why a request was skipped (Table I's "costly transitions" taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkipReason {
    /// Confident prediction: inference adds little information (§IV-A-A).
    LowUtility,
    /// Rolling joules/request spiked (§IV-A-B).
    EnergySpike,
    /// Queue/latency pressure (§IV-A-C, protects the stable basin).
    Congestion,
}

impl SkipReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            SkipReason::LowUtility => "low_utility",
            SkipReason::EnergySpike => "energy_spike",
            SkipReason::Congestion => "congestion",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::cost::WeightPolicy;

    fn controller(schedule: ThresholdSchedule) -> AdmissionController {
        AdmissionController::new(ControllerConfig {
            weights: WeightPolicy::Balanced.weights(),
            schedule,
            respond_from_cache: true,
        })
    }

    fn inputs(entropy_frac: f64) -> CostInputs {
        CostInputs::from_entropy(entropy_frac * 2f64.ln(), 2f64.ln())
    }

    #[test]
    fn admits_when_j_at_least_tau() {
        let mut c = controller(ThresholdSchedule::Constant { tau: 0.5 });
        // Idle system: E=C=1, so J = (L + 2)/3 with balanced weights.
        let d = c.decide(&inputs(1.0), 0.0); // J = 1.0
        assert!(d.admitted());
        assert!((d.j() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn equality_admits() {
        // J == tau must admit (Eq. 2 is >=).
        let mut c = controller(ThresholdSchedule::Constant { tau: 1.0 });
        let d = c.decide(&inputs(1.0), 0.0);
        assert!(d.admitted());
    }

    #[test]
    fn skips_low_utility_when_tight() {
        let mut c = controller(ThresholdSchedule::Constant { tau: 0.7 });
        let d = c.decide(&inputs(0.0), 0.0); // J = 2/3 < 0.7
        match d {
            Decision::Skip { reason, cacheable, .. } => {
                assert_eq!(reason, SkipReason::LowUtility);
                assert!(cacheable);
            }
            _ => panic!("expected skip, got {d:?}"),
        }
    }

    #[test]
    fn congestion_reason_when_jammed() {
        let mut c = controller(ThresholdSchedule::Constant { tau: 0.9 });
        let mut x = inputs(0.5);
        x.queue_depth = 64;
        x.queue_capacity = 64;
        match c.decide(&x, 0.0) {
            Decision::Skip { reason, .. } => assert_eq!(reason, SkipReason::Congestion),
            d => panic!("expected skip, got {d:?}"),
        }
    }

    #[test]
    fn energy_spike_reason() {
        let mut c = controller(ThresholdSchedule::Constant { tau: 0.9 });
        let mut x = inputs(0.5);
        x.energy_ewma = 10.0;
        x.energy_ref = 10.0;
        match c.decide(&x, 0.0) {
            Decision::Skip { reason, .. } => assert_eq!(reason, SkipReason::EnergySpike),
            d => panic!("expected skip, got {d:?}"),
        }
    }

    #[test]
    fn threshold_tightens_with_time() {
        // Early permissive epoch admits what the late strict epoch skips.
        let mut c = controller(ThresholdSchedule::Exponential {
            tau0: 0.0,
            tau_inf: 0.9,
            k: 0.5,
        });
        let x = inputs(0.2); // J = (0.2 + 2)/3 ≈ 0.733
        assert!(c.decide(&x, 0.0).admitted(), "permissive at t=0");
        assert!(!c.decide(&x, 100.0).admitted(), "strict at t→∞");
    }

    #[test]
    fn stats_and_admission_rate() {
        let mut c = controller(ThresholdSchedule::Constant { tau: 0.8 });
        for i in 0..10 {
            let frac = if i < 6 { 1.0 } else { 0.0 };
            c.decide(&inputs(frac), 0.0);
        }
        let s = c.stats();
        assert_eq!(s.admitted, 6);
        assert_eq!(s.skipped, 4);
        assert!((s.admission_rate() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn epoch_restart_resets_tau() {
        let mut c = controller(ThresholdSchedule::Exponential {
            tau0: 0.1,
            tau_inf: 0.9,
            k: 1.0,
        });
        let strict = c.tau_at(100.0);
        c.restart_epoch(100.0);
        let fresh = c.tau_at(100.0);
        assert!(fresh < strict);
        assert!((fresh - 0.1).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_rate_is_one() {
        assert_eq!(AdmissionStats::default().admission_rate(), 1.0);
    }

    #[test]
    fn correction_handle_shifts_tau() {
        let c = controller(ThresholdSchedule::Constant { tau: 0.5 });
        assert_eq!(c.tau_at(0.0), 0.5);
        c.rate_correction_handle().set(0.2);
        assert!((c.tau_at(0.0) - 0.7).abs() < 1e-12);
        // a clone shares the live correction
        let clone = c.clone();
        c.rate_correction_handle().set(-0.1);
        assert!((clone.tau_at(0.0) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn per_call_bias_shifts_the_threshold() {
        let mut c = controller(ThresholdSchedule::Constant { tau: 0.5 });
        let x = inputs(0.0); // J = 2/3 on an idle system
        assert!(c.decide_biased(&x, 0.0, 0.0).admitted());
        // A per-model energy pacer pushing +0.3 makes the same request skip.
        let d = c.decide_biased(&x, 0.0, 0.3);
        assert!(!d.admitted());
        assert!((d.tau() - 0.8).abs() < 1e-12, "bias rides on τ: {}", d.tau());
    }

    #[test]
    fn correction_changes_decisions() {
        let mut c = controller(ThresholdSchedule::Constant { tau: 0.7 });
        let x = inputs(0.0); // J = 2/3 with balanced weights on an idle system
        assert!(!c.decide(&x, 0.0).admitted());
        c.rate_correction_handle().set(-0.1); // τ_eff = 0.6
        assert!(c.decide(&x, 0.0).admitted());
    }

    #[test]
    fn adaptive_tau_policy_tracks_target_on_synthetic_mix() {
        // Entropy fractions uniform in [0,1] -> J uniform in [2/3, 1]
        // (idle system, balanced weights), so any admission rate is
        // reachable by sliding τ. Target 30% admission.
        let cfg = ControllerConfig {
            weights: WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.8 },
            respond_from_cache: true,
        };
        let mut p = AdaptiveTauPolicy::new(cfg, 0.3, 0.05, 20);
        let mut rng = crate::util::Rng::new(17);
        for _ in 0..4000 {
            let frac = rng.range(0.0, 1.0);
            p.decide(&inputs(frac), 0.0);
        }
        // measure the steady-state rate over a fresh window
        let before = p.stats();
        for _ in 0..2000 {
            let frac = rng.range(0.0, 1.0);
            p.decide(&inputs(frac), 0.0);
        }
        let after = p.stats();
        let rate = (after.admitted - before.admitted) as f64
            / (after.total() - before.total()) as f64;
        assert!((rate - 0.3).abs() < 0.05, "steady-state rate {rate}");
    }

    #[test]
    fn adaptive_tau_policy_reports_name_and_correction() {
        let mut p = AdaptiveTauPolicy::new(ControllerConfig::default(), 0.5, 0.1, 1);
        assert_eq!(p.name(), "adaptive-tau");
        assert_eq!(p.target_admit_rate(), 0.5);
        p.decide(&inputs(1.0), 0.0); // admitted -> rate 1.0 -> correction up
        assert!(p.correction() > 0.0);
    }
}
