//! Ablation baselines for Table III and the weight sweeps:
//!
//! * [`OpenLoop`]   — the paper's "Standard" policy: admit everything.
//! * [`StaticThreshold`] — Eq. 2 with a constant τ (no folding dynamics);
//!   isolates the *decay* from the *thresholding*.
//! * [`RandomDrop`] — admits a fixed fraction uniformly at random;
//!   isolates "selective" from "fewer requests" (same admission rate as
//!   the bio-controller but no utility awareness ⇒ larger accuracy loss).
//! * [`Oracle`]     — admits exactly the requests whose prediction would
//!   be wrong at skip time (upper bound on accuracy-per-joule).

use crate::controller::cost::CostInputs;
use crate::controller::{AdmissionPolicy, Decision, SkipReason};
use crate::util::Rng;

/// Admit everything (open-loop "Standard" row of Table III).
#[derive(Debug, Default, Clone)]
pub struct OpenLoop;

impl AdmissionPolicy for OpenLoop {
    fn decide(&mut self, x: &CostInputs, _t: f64) -> Decision {
        Decision::Admit { j: x.j(&crate::controller::cost::WeightPolicy::Balanced.weights()), tau: 0.0 }
    }

    fn name(&self) -> &'static str {
        "open-loop"
    }
}

/// Constant-τ thresholding (no decay).
#[derive(Debug, Clone)]
pub struct StaticThreshold {
    pub tau: f64,
    pub weights: crate::controller::cost::CostWeights,
}

impl StaticThreshold {
    pub fn new(tau: f64) -> Self {
        StaticThreshold { tau, weights: crate::controller::cost::WeightPolicy::Balanced.weights() }
    }
}

impl AdmissionPolicy for StaticThreshold {
    fn decide(&mut self, x: &CostInputs, _t: f64) -> Decision {
        let j = x.j(&self.weights);
        if j >= self.tau {
            Decision::Admit { j, tau: self.tau }
        } else {
            Decision::Skip { j, tau: self.tau, reason: SkipReason::LowUtility, cacheable: true }
        }
    }

    fn name(&self) -> &'static str {
        "static-threshold"
    }
}

/// Uniform random admission at rate `p` (utility-blind comparator).
#[derive(Debug)]
pub struct RandomDrop {
    pub admit_prob: f64,
    rng: Rng,
}

impl RandomDrop {
    pub fn new(admit_prob: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&admit_prob));
        RandomDrop { admit_prob, rng: Rng::new(seed) }
    }
}

impl AdmissionPolicy for RandomDrop {
    fn decide(&mut self, x: &CostInputs, _t: f64) -> Decision {
        let j = x.j(&crate::controller::cost::WeightPolicy::Balanced.weights());
        if self.rng.chance(self.admit_prob) {
            Decision::Admit { j, tau: f64::NAN }
        } else {
            Decision::Skip { j, tau: f64::NAN, reason: SkipReason::LowUtility, cacheable: true }
        }
    }

    fn name(&self) -> &'static str {
        "random-drop"
    }
}

/// Oracle: admit iff the cached/skip answer would be wrong — requires the
/// latent confidence, so it only exists in simulation. Bounds what any
/// admission policy could achieve.
#[derive(Debug)]
pub struct Oracle {
    /// Entropy (normalised) above which the skip answer is likely wrong.
    pub entropy_cut: f64,
}

impl Oracle {
    pub fn new(entropy_cut: f64) -> Self {
        Oracle { entropy_cut }
    }
}

impl AdmissionPolicy for Oracle {
    fn decide(&mut self, x: &CostInputs, _t: f64) -> Decision {
        let l = x.l_norm();
        if l >= self.entropy_cut {
            Decision::Admit { j: l, tau: self.entropy_cut }
        } else {
            Decision::Skip {
                j: l,
                tau: self.entropy_cut,
                reason: SkipReason::LowUtility,
                cacheable: true,
            }
        }
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x(frac: f64) -> CostInputs {
        CostInputs::from_entropy(frac * 2f64.ln(), 2f64.ln())
    }

    #[test]
    fn open_loop_admits_everything() {
        let mut p = OpenLoop;
        for i in 0..100 {
            assert!(p.decide(&x(i as f64 / 100.0), i as f64).admitted());
        }
    }

    #[test]
    fn static_threshold_cuts_by_j() {
        let mut p = StaticThreshold::new(0.8);
        assert!(p.decide(&x(1.0), 0.0).admitted());
        assert!(!p.decide(&x(0.0), 0.0).admitted());
        // Time-invariant: same decision at any t.
        assert!(!p.decide(&x(0.0), 1e6).admitted());
    }

    #[test]
    fn random_drop_hits_target_rate() {
        let mut p = RandomDrop::new(0.58, 7);
        let n = 20_000;
        let admitted = (0..n).filter(|&i| p.decide(&x(0.5), i as f64).admitted()).count();
        let rate = admitted as f64 / n as f64;
        assert!((rate - 0.58).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn random_drop_is_utility_blind() {
        // Admission must be independent of entropy: compare rates.
        let mut p = RandomDrop::new(0.5, 9);
        let mut lo = 0;
        let mut hi = 0;
        for i in 0..10_000 {
            if p.decide(&x(0.05), i as f64).admitted() {
                lo += 1;
            }
            if p.decide(&x(0.95), i as f64).admitted() {
                hi += 1;
            }
        }
        assert!((lo as f64 - hi as f64).abs() / 10_000.0 < 0.03);
    }

    #[test]
    fn oracle_splits_on_entropy() {
        let mut p = Oracle::new(0.5);
        assert!(p.decide(&x(0.9), 0.0).admitted());
        assert!(!p.decide(&x(0.1), 0.0).admitted());
    }

    #[test]
    fn names_are_distinct() {
        let names = [
            OpenLoop.name(),
            StaticThreshold::new(0.5).name(),
            RandomDrop::new(0.5, 1).name(),
            Oracle::new(0.5).name(),
        ];
        let set: std::collections::BTreeSet<_> = names.iter().collect();
        assert_eq!(set.len(), names.len());
    }
}
