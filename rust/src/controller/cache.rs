//! The skip path: a response cache (Algorithm 1 line 9, "Skip or respond
//! from cache").
//!
//! Keyed by a quantised input signature so near-duplicate requests hit.
//! For cold skips the cache answers with the screener's argmax (cheap
//! prediction) — this is why skipping confident requests costs almost no
//! accuracy: a confident screener is almost always right, by calibration.

use std::collections::HashMap;
use std::collections::VecDeque;

/// Cached answer for a request signature.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedResponse {
    pub label: u32,
    pub confidence: f64,
}

/// Bounded LRU-ish response cache (FIFO eviction; the workload has no
/// scan-resistance requirement).
#[derive(Debug)]
pub struct ResponseCache {
    map: HashMap<u64, CachedResponse>,
    order: VecDeque<u64>,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl ResponseCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ResponseCache {
            map: HashMap::with_capacity(capacity),
            order: VecDeque::with_capacity(capacity),
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    /// Quantise an input signature: bucket the payload seed space so
    /// similar payloads (same generator cluster) share an entry.
    pub fn signature(model: &str, seed: u64, clusters: u64) -> u64 {
        // FNV-1a over the model name, mixed with the seed's cluster.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in model.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h ^ (seed % clusters.max(1))
    }

    pub fn get(&mut self, sig: u64) -> Option<CachedResponse> {
        let r = self.map.get(&sig).copied();
        if r.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        r
    }

    pub fn put(&mut self, sig: u64, resp: CachedResponse) {
        if self.map.len() >= self.capacity && !self.map.contains_key(&sig) {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
        if self.map.insert(sig, resp).is_none() {
            self.order.push_back(sig);
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut c = ResponseCache::new(4);
        let sig = ResponseCache::signature("m", 42, 100);
        assert!(c.get(sig).is_none());
        c.put(sig, CachedResponse { label: 1, confidence: 0.9 });
        assert_eq!(c.get(sig).unwrap().label, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn eviction_bounds_size() {
        let mut c = ResponseCache::new(3);
        for i in 0..10u64 {
            c.put(i, CachedResponse { label: i as u32, confidence: 1.0 });
        }
        assert_eq!(c.len(), 3);
        assert!(c.get(0).is_none(), "oldest evicted");
        assert!(c.get(9).is_some(), "newest kept");
    }

    #[test]
    fn update_does_not_grow() {
        let mut c = ResponseCache::new(2);
        c.put(1, CachedResponse { label: 0, confidence: 0.5 });
        c.put(1, CachedResponse { label: 1, confidence: 0.6 });
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(1).unwrap().label, 1);
    }

    #[test]
    fn signature_clusters_seeds() {
        let a = ResponseCache::signature("m", 5, 10);
        let b = ResponseCache::signature("m", 15, 10); // same cluster (5 mod 10)
        let c = ResponseCache::signature("m", 6, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, ResponseCache::signature("other", 5, 10));
    }

    #[test]
    fn zero_cluster_guard() {
        // clusters=0 must not divide by zero.
        let _ = ResponseCache::signature("m", 5, 0);
    }
}
