//! The skip path: a response cache (Algorithm 1 line 9, "Skip or respond
//! from cache").
//!
//! Keyed by a quantised input signature so near-duplicate requests hit.
//! For cold skips the cache answers with the screener's argmax (cheap
//! prediction) — this is why skipping confident requests costs almost no
//! accuracy: a confident screener is almost always right, by calibration.

use std::collections::HashMap;
use std::collections::VecDeque;

/// Cached answer for a request signature.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedResponse {
    pub label: u32,
    pub confidence: f64,
}

/// Bounded LRU-ish response cache (FIFO eviction; the workload has no
/// scan-resistance requirement).
#[derive(Debug)]
pub struct ResponseCache {
    map: HashMap<u64, CachedResponse>,
    order: VecDeque<u64>,
    capacity: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl ResponseCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ResponseCache {
            map: HashMap::with_capacity(capacity),
            order: VecDeque::with_capacity(capacity),
            capacity,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Hard ceiling on the cluster count: the per-version base hash
    /// reserves exactly these low bits for the cluster index, and
    /// [`Self::invalidate`]'s walk is bounded by it. `signature` and
    /// `invalidate` **must** clamp identically or unload would leave
    /// high-cluster entries alive.
    pub const MAX_CLUSTERS: u64 = 1 << 20;

    fn cluster_of(seed: u64, clusters: u64) -> u64 {
        seed % clusters.clamp(1, Self::MAX_CLUSTERS)
    }

    /// Quantise an input signature: bucket the payload seed space so
    /// similar payloads (same generator cluster) share an entry. The
    /// model **version** is part of the key — a reloaded version must
    /// never serve the previous version's cached answers (the ROADMAP
    /// lifecycle follow-up this fixed). Cluster counts are clamped to
    /// [`Self::MAX_CLUSTERS`].
    pub fn signature(model: &str, version: u64, seed: u64, clusters: u64) -> u64 {
        Self::base(model, version) ^ Self::cluster_of(seed, clusters)
    }

    /// FNV-1a over the model name and version — the per-version key
    /// prefix every cluster signature is XORed onto. Keeping the cluster
    /// in the low bits (XOR of the clamped cluster index) makes a
    /// version's full signature set enumerable, which is what
    /// [`Self::invalidate`] walks on unload.
    fn base(model: &str, version: u64) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in model.as_bytes().iter().copied().chain(version.to_le_bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        // Clear the low cluster bits (the MAX_CLUSTERS space) so a
        // cluster index can never bleed into a neighbouring base.
        h & !(Self::MAX_CLUSTERS - 1)
    }

    /// Drop every entry a (model, version) pair could have minted:
    /// called on unload so a later reload starts cold instead of
    /// inheriting the dead version's answers. Returns how many entries
    /// were removed. `clusters` must match the value used at `put`
    /// time (it is a system-wide config constant).
    pub fn invalidate(&mut self, model: &str, version: u64, clusters: u64) -> usize {
        let base = Self::base(model, version);
        let mut removed = 0;
        // The signature space for one version is exactly
        // {base ^ c | c < clamped clusters} (the config default is 256).
        for c in 0..clusters.clamp(1, Self::MAX_CLUSTERS) {
            if self.map.remove(&(base ^ c)).is_some() {
                removed += 1;
            }
        }
        if removed > 0 {
            // Purge the eviction-queue slots too: a reload re-caching
            // the same signature must get a *fresh* slot — a leftover
            // one would make eviction drop the newest entry instead of
            // the oldest.
            let map = &self.map;
            self.order.retain(|k| map.contains_key(k));
        }
        removed
    }

    /// Remove an explicit signature set, purging eviction-queue slots
    /// like [`Self::invalidate`] does. The sharded cache's invalidation
    /// walk routes each enumerated signature to its owning shard and
    /// hands that shard its slice through this.
    pub fn remove_all(&mut self, sigs: &[u64]) -> usize {
        let mut removed = 0;
        for sig in sigs {
            if self.map.remove(sig).is_some() {
                removed += 1;
            }
        }
        if removed > 0 {
            let map = &self.map;
            self.order.retain(|k| map.contains_key(k));
        }
        removed
    }

    pub fn get(&mut self, sig: u64) -> Option<CachedResponse> {
        let r = self.map.get(&sig).copied();
        if r.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        r
    }

    /// Insert, FIFO-evicting when full. Returns `true` when an older
    /// entry was evicted to make room (feeds `gf_cache_evictions_total`).
    pub fn put(&mut self, sig: u64, resp: CachedResponse) -> bool {
        // `order` only ever holds live keys (`invalidate` purges the
        // slots of the entries it drops), so the front of the queue is
        // always a real eviction victim.
        let mut evicted = false;
        if self.map.len() >= self.capacity && !self.map.contains_key(&sig) {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
                self.evictions += 1;
                evicted = true;
            }
        }
        if self.map.insert(sig, resp).is_none() {
            self.order.push_back(sig);
        }
        evicted
    }

    /// Enumerate the full signature set a (model, version) pair can
    /// mint — `{base ^ c | c < clamped clusters}` — with the exact
    /// clamp [`Self::signature`] applies. This is the key set
    /// [`Self::invalidate`] walks; the singleflight table retires the
    /// same set on unload so in-flight coalesced entries die with the
    /// version.
    pub fn signatures_of(
        model: &str,
        version: u64,
        clusters: u64,
    ) -> impl Iterator<Item = u64> {
        let base = Self::base(model, version);
        (0..clusters.clamp(1, Self::MAX_CLUSTERS)).map(move |c| base ^ c)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut c = ResponseCache::new(4);
        let sig = ResponseCache::signature("m", 1, 42, 100);
        assert!(c.get(sig).is_none());
        c.put(sig, CachedResponse { label: 1, confidence: 0.9 });
        assert_eq!(c.get(sig).unwrap().label, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn eviction_bounds_size() {
        let mut c = ResponseCache::new(3);
        for i in 0..10u64 {
            c.put(i, CachedResponse { label: i as u32, confidence: 1.0 });
        }
        assert_eq!(c.len(), 3);
        assert!(c.get(0).is_none(), "oldest evicted");
        assert!(c.get(9).is_some(), "newest kept");
    }

    #[test]
    fn update_does_not_grow() {
        let mut c = ResponseCache::new(2);
        c.put(1, CachedResponse { label: 0, confidence: 0.5 });
        c.put(1, CachedResponse { label: 1, confidence: 0.6 });
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(1).unwrap().label, 1);
    }

    #[test]
    fn signature_clusters_seeds() {
        let a = ResponseCache::signature("m", 1, 5, 10);
        let b = ResponseCache::signature("m", 1, 15, 10); // same cluster (5 mod 10)
        let c = ResponseCache::signature("m", 1, 6, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, ResponseCache::signature("other", 1, 5, 10));
    }

    #[test]
    fn signature_is_version_aware() {
        // The reload bugfix: v1 and v2 of the same model must never
        // share an entry, even for the same seed cluster.
        let v1 = ResponseCache::signature("m", 1, 5, 10);
        let v2 = ResponseCache::signature("m", 2, 5, 10);
        assert_ne!(v1, v2);
    }

    #[test]
    fn invalidate_drops_exactly_one_versions_entries() {
        let mut c = ResponseCache::new(64);
        for seed in 0..20u64 {
            c.put(
                ResponseCache::signature("m", 1, seed, 10),
                CachedResponse { label: 1, confidence: 0.9 },
            );
            c.put(
                ResponseCache::signature("m", 2, seed, 10),
                CachedResponse { label: 2, confidence: 0.9 },
            );
        }
        assert_eq!(c.len(), 20); // 10 clusters per version
        let removed = c.invalidate("m", 1, 10);
        assert_eq!(removed, 10);
        assert!(c.get(ResponseCache::signature("m", 1, 5, 10)).is_none());
        assert_eq!(c.get(ResponseCache::signature("m", 2, 5, 10)).unwrap().label, 2);
        // Idempotent: a second pass finds nothing.
        assert_eq!(c.invalidate("m", 1, 10), 0);
    }

    #[test]
    fn invalidate_purges_queue_slots_and_capacity_holds() {
        let mut c = ResponseCache::new(3);
        c.put(ResponseCache::signature("m", 1, 0, 4), CachedResponse { label: 0, confidence: 1.0 });
        c.put(ResponseCache::signature("m", 2, 1, 4), CachedResponse { label: 1, confidence: 1.0 });
        c.put(ResponseCache::signature("m", 2, 2, 4), CachedResponse { label: 2, confidence: 1.0 });
        c.invalidate("m", 1, 4);
        assert_eq!(c.len(), 2);
        // Two more puts: the purged v1 slot must not distort eviction,
        // and len stays bounded.
        c.put(ResponseCache::signature("m", 2, 3, 4), CachedResponse { label: 3, confidence: 1.0 });
        c.put(ResponseCache::signature("m", 2, 0, 4), CachedResponse { label: 4, confidence: 1.0 });
        assert!(c.len() <= 3, "capacity respected after invalidation: {}", c.len());
        assert_eq!(c.get(ResponseCache::signature("m", 2, 0, 4)).unwrap().label, 4);
    }

    #[test]
    fn zero_cluster_guard() {
        // clusters=0 must not divide by zero (signature or invalidate).
        let _ = ResponseCache::signature("m", 1, 5, 0);
        let mut c = ResponseCache::new(2);
        let _ = c.invalidate("m", 1, 0);
    }

    #[test]
    fn reinserting_after_invalidate_keeps_eviction_order() {
        // A reload that re-caches an invalidated signature must not
        // inherit its stale eviction slot (which would evict the fresh
        // entry while older ones survive).
        let mut c = ResponseCache::new(2);
        let a = ResponseCache::signature("m", 1, 0, 4);
        let b = ResponseCache::signature("m", 2, 0, 4);
        let newest = ResponseCache::signature("m", 2, 1, 4);
        c.put(a, CachedResponse { label: 1, confidence: 1.0 });
        c.put(b, CachedResponse { label: 2, confidence: 1.0 });
        c.invalidate("m", 1, 4); // drops a, must also drop its queue slot
        c.put(a, CachedResponse { label: 9, confidence: 1.0 }); // reload re-caches a
        c.put(newest, CachedResponse { label: 3, confidence: 1.0 }); // evicts oldest: b
        assert_eq!(c.get(a).unwrap().label, 9, "fresh entry survives");
        assert!(c.get(b).is_none(), "oldest entry evicted");
        assert_eq!(c.get(newest).unwrap().label, 3);
    }

    #[test]
    fn signatures_of_enumerates_exactly_the_version_space() {
        // The retirement walk must reach every signature `signature`
        // can mint for the version — and nothing else.
        let sigs: Vec<u64> = ResponseCache::signatures_of("m", 3, 10).collect();
        assert_eq!(sigs.len(), 10);
        for seed in 0..50u64 {
            assert!(sigs.contains(&ResponseCache::signature("m", 3, seed, 10)));
        }
        assert!(!sigs.contains(&ResponseCache::signature("m", 4, 0, 10)));
        // Zero clamps to one, like signature/invalidate.
        assert_eq!(ResponseCache::signatures_of("m", 3, 0).count(), 1);
    }

    #[test]
    fn put_reports_evictions() {
        let mut c = ResponseCache::new(2);
        assert!(!c.put(1, CachedResponse { label: 0, confidence: 1.0 }));
        assert!(!c.put(2, CachedResponse { label: 0, confidence: 1.0 }));
        assert!(!c.put(1, CachedResponse { label: 1, confidence: 1.0 }), "update is not an eviction");
        assert!(c.put(3, CachedResponse { label: 0, confidence: 1.0 }), "full + new key evicts");
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn oversized_cluster_counts_clamp_consistently() {
        // A cluster count past MAX_CLUSTERS clamps the same way in
        // signature and invalidate, so unload still finds every entry.
        let huge = ResponseCache::MAX_CLUSTERS << 2;
        let mut c = ResponseCache::new(8);
        let seed = ResponseCache::MAX_CLUSTERS + 7; // would exceed the base's low bits unclamped
        let sig = ResponseCache::signature("m", 1, seed, huge);
        c.put(sig, CachedResponse { label: 3, confidence: 1.0 });
        assert_eq!(
            sig,
            ResponseCache::signature("m", 1, seed % ResponseCache::MAX_CLUSTERS, huge),
            "cluster index is computed in the clamped space"
        );
        assert_eq!(c.invalidate("m", 1, huge), 1, "invalidate visits the clamped space");
        assert!(c.get(sig).is_none());
    }
}
