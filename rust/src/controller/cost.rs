//! The cost functional J(x) = α·L(x) + β·E(x) + γ·C(x) (paper Eq. 1) with
//! the weight policies of §IV-A ("performance priority → increase α, γ;
//! ecology priority → increase β").
//!
//! Proxies are normalised to [0, 1] before weighting so a single τ scale
//! works across models and devices:
//!
//! * `L` — entropy / ln(classes) (max-entropy ⇒ 1);
//! * `E` — *inverted* rolling-energy headroom: low recent joules/request
//!   means executing is cheap ⇒ contributes toward admission; an energy
//!   spike pushes E(x)'s contribution down so "only very valuable ...
//!   requests pass" (§IV-A-B). Encoded as `1 − min(ewma/e_ref, 1)`.
//! * `C` — congestion headroom, likewise inverted: an idle system (short
//!   queue, low P95) leaves C(x) near 1; congestion pushes it to 0 so
//!   high-γ policies shed load under pressure (§IV-A-C, Table I row 4).

/// Weights (α, β, γ) of Eq. 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostWeights {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
}

impl CostWeights {
    pub fn new(alpha: f64, beta: f64, gamma: f64) -> Self {
        assert!(alpha >= 0.0 && beta >= 0.0 && gamma >= 0.0, "weights must be >= 0");
        CostWeights { alpha, beta, gamma }
    }

    /// Normalise weights to sum 1 (keeps J in [0, 1]).
    pub fn normalised(self) -> Self {
        let s = self.alpha + self.beta + self.gamma;
        assert!(s > 0.0, "at least one weight must be positive");
        CostWeights { alpha: self.alpha / s, beta: self.beta / s, gamma: self.gamma / s }
    }

    pub fn sum(&self) -> f64 {
        self.alpha + self.beta + self.gamma
    }
}

/// §IV-A weight presets ("policy knobs").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightPolicy {
    /// Equal weighting.
    Balanced,
    /// Performance priority: raise α (utility) and γ (protect latency).
    Performance,
    /// Ecology priority: raise β (energy dominates admission).
    Ecology,
}

impl WeightPolicy {
    pub fn weights(self) -> CostWeights {
        match self {
            WeightPolicy::Balanced => CostWeights::new(1.0, 1.0, 1.0).normalised(),
            WeightPolicy::Performance => CostWeights::new(2.0, 0.5, 1.5).normalised(),
            WeightPolicy::Ecology => CostWeights::new(1.0, 2.5, 0.5).normalised(),
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "balanced" => Some(WeightPolicy::Balanced),
            "performance" | "perf" => Some(WeightPolicy::Performance),
            "ecology" | "eco" => Some(WeightPolicy::Ecology),
            _ => None,
        }
    }
}

/// Raw signals for one request, before normalisation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostInputs {
    /// Prediction entropy estimate in nats (screener or cached).
    pub entropy: f64,
    /// ln(number of classes) — entropy normaliser.
    pub max_entropy: f64,
    /// Rolling joules/request EWMA (the meter's E(x) input).
    pub energy_ewma: f64,
    /// Reference joules/request for normalisation (e.g. the model's
    /// steady-state per-request energy at batch 1).
    pub energy_ref: f64,
    /// Current queue depth (requests waiting).
    pub queue_depth: usize,
    /// Queue depth considered saturated (normaliser).
    pub queue_capacity: usize,
    /// Recent P95 latency (s).
    pub p95_latency: f64,
    /// Latency SLO used to normalise P95 (s).
    pub slo_latency: f64,
}

impl CostInputs {
    /// Normalised utility L(x) ∈ [0, 1].
    pub fn l_norm(&self) -> f64 {
        if self.max_entropy <= 0.0 {
            return 0.0;
        }
        (self.entropy / self.max_entropy).clamp(0.0, 1.0)
    }

    /// Normalised energy-headroom term E(x) ∈ [0, 1]
    /// (1 = cheap to execute now, 0 = energy spike).
    pub fn e_norm(&self) -> f64 {
        if self.energy_ref <= 0.0 {
            return 1.0;
        }
        1.0 - (self.energy_ewma / self.energy_ref).clamp(0.0, 1.0)
    }

    /// Normalised congestion-headroom term C(x) ∈ [0, 1]
    /// (1 = idle, 0 = saturated queue or blown SLO).
    pub fn c_norm(&self) -> f64 {
        let q = if self.queue_capacity == 0 {
            0.0
        } else {
            (self.queue_depth as f64 / self.queue_capacity as f64).clamp(0.0, 1.0)
        };
        let lat = if self.slo_latency <= 0.0 {
            0.0
        } else {
            (self.p95_latency / self.slo_latency).clamp(0.0, 1.0)
        };
        // Worst of the two pressures dominates (max pressure = min headroom).
        1.0 - q.max(lat)
    }

    /// The weighted functional J(x) (Eq. 1) over normalised proxies.
    pub fn j(&self, w: &CostWeights) -> f64 {
        w.alpha * self.l_norm() + w.beta * self.e_norm() + w.gamma * self.c_norm()
    }

    /// Convenience constructor for an idle system observing only entropy
    /// (tests, landscape sketches).
    pub fn from_entropy(entropy: f64, max_entropy: f64) -> Self {
        CostInputs {
            entropy,
            max_entropy,
            energy_ewma: 0.0,
            energy_ref: 1.0,
            queue_depth: 0,
            queue_capacity: 64,
            p95_latency: 0.0,
            slo_latency: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(entropy: f64) -> CostInputs {
        CostInputs::from_entropy(entropy, 2f64.ln())
    }

    #[test]
    fn l_normalises_entropy() {
        assert!((idle(2f64.ln()).l_norm() - 1.0).abs() < 1e-12);
        assert_eq!(idle(0.0).l_norm(), 0.0);
        assert_eq!(idle(10.0).l_norm(), 1.0, "clamped");
    }

    #[test]
    fn e_headroom_inverts_spikes() {
        let mut x = idle(0.3);
        x.energy_ref = 10.0;
        x.energy_ewma = 0.0;
        assert_eq!(x.e_norm(), 1.0);
        x.energy_ewma = 10.0;
        assert_eq!(x.e_norm(), 0.0);
        x.energy_ewma = 2.5;
        assert!((x.e_norm() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn c_headroom_takes_worst_pressure() {
        let mut x = idle(0.3);
        x.queue_depth = 32;
        x.queue_capacity = 64;
        x.p95_latency = 0.9;
        x.slo_latency = 1.0;
        // queue pressure 0.5, latency pressure 0.9 -> headroom 0.1
        assert!((x.c_norm() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn j_is_weighted_sum() {
        let x = idle(2f64.ln()); // L=1, E=1, C=1
        let w = CostWeights::new(1.0, 1.0, 1.0).normalised();
        assert!((x.j(&w) - 1.0).abs() < 1e-12);
        let w2 = CostWeights::new(1.0, 0.0, 0.0);
        assert!((x.j(&w2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn uncertain_requests_score_higher() {
        // §IV-A-A: admit high-uncertainty, reject already-confident.
        let w = WeightPolicy::Balanced.weights();
        assert!(idle(0.69).j(&w) > idle(0.05).j(&w));
    }

    #[test]
    fn congestion_lowers_j() {
        // Table I row 4: high C(x) pressure must push J below τ.
        let w = WeightPolicy::Balanced.weights();
        let calm = idle(0.3);
        let mut jammed = calm;
        jammed.queue_depth = 64;
        assert!(jammed.j(&w) < calm.j(&w));
    }

    #[test]
    fn policies_order_weights_as_stated() {
        let p = WeightPolicy::Performance.weights();
        let e = WeightPolicy::Ecology.weights();
        let b = WeightPolicy::Balanced.weights();
        assert!(p.alpha > b.alpha && p.gamma > b.gamma);
        assert!(e.beta > b.beta);
        for w in [p, e, b] {
            assert!((w.sum() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn policy_lookup() {
        assert_eq!(WeightPolicy::by_name("eco"), Some(WeightPolicy::Ecology));
        assert!(WeightPolicy::by_name("chaos").is_none());
    }

    #[test]
    #[should_panic]
    fn negative_weights_panic() {
        CostWeights::new(-1.0, 0.0, 0.0);
    }

    #[test]
    fn zero_capacity_degrades_gracefully() {
        let mut x = idle(0.1);
        x.queue_capacity = 0;
        x.slo_latency = 0.0;
        assert_eq!(x.c_norm(), 1.0);
        x.energy_ref = 0.0;
        assert_eq!(x.e_norm(), 1.0);
    }
}
