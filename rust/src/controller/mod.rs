//! The paper's contribution: closed-loop, bio-inspired admission control.
//!
//! A request `x` is scored with the cost functional (paper Eq. 1)
//!
//! ```text
//! J(x) = α·L(x) + β·E(x) + γ·C(x)
//! ```
//!
//! where `L` is an uncertainty/utility proxy (softmax entropy from the
//! screener or cache), `E` the rolling marginal energy (joules/request
//! EWMA from [`crate::energy::EnergyMeter`]), and `C` a congestion
//! penalty (queue depth, recent P95). It is admitted iff (Eq. 2)
//!
//! ```text
//! J(x) ≥ τ(t),    τ(t) = τ∞ + (τ0 − τ∞)·e^(−kt)      (Eq. 3)
//! ```
//!
//! — the protein-folding analogy of §IV-A: permissive exploration at
//! startup (high τ₀ admits broadly while the system finds a basin), then
//! admission tightens toward τ∞ once the serving regime stabilises,
//! pruning low-utility work instead of chasing the costly global minimum.
//!
//! Note the direction: the controller **admits high-J** requests — high
//! uncertainty means the model's answer carries information; a
//! low-entropy request is answered from the response cache at near-zero
//! energy (Appendix A line 9, "skip or respond from cache").
//!
//! Submodules: [`threshold`] (τ(t) schedules), [`cost`] (J(x) and weight
//! policies), [`admission`] (the closed-loop controller), [`cache`]
//! (the skip path), [`baselines`] (open-loop / static-τ / random-drop
//! comparators for the Table III ablation).

pub mod admission;
pub mod baselines;
pub mod cache;
pub mod cost;
pub mod threshold;

pub use admission::{
    AdaptiveTauPolicy, AdmissionController, ControllerConfig, Decision, SkipReason,
};
pub use baselines::{OpenLoop, Oracle, RandomDrop, StaticThreshold};
pub use cost::{CostInputs, CostWeights, WeightPolicy};
pub use threshold::{AdaptiveThreshold, ThresholdSchedule};

/// Common interface for the bio-controller and every ablation baseline.
pub trait AdmissionPolicy: Send {
    /// Decide whether to admit the request with signals `x` at time `t`.
    fn decide(&mut self, x: &CostInputs, t: f64) -> Decision;

    /// Human-readable policy name (report rows).
    fn name(&self) -> &'static str;
}
