//! τ(t) schedules. The paper's Eq. 3 is the exponential decay; linear and
//! step variants are ablation comparators for the Fig. 1/Fig. 5 benches,
//! and [`AdaptiveThreshold`] is the §IX "future work" extension
//! (closed-loop τ that servos on the observed admission rate), built on
//! the [`crate::control`] plane's `SetpointTracker` law and `Adaptive`
//! handle.

use crate::control::law::{ControlLaw, SetpointTracker};
use crate::control::Adaptive;

/// Time-varying admission threshold.
#[derive(Debug, Clone)]
pub enum ThresholdSchedule {
    /// Paper Eq. 3: τ(t) = τ∞ + (τ0 − τ∞)·e^(−kt), k > 0.
    Exponential { tau0: f64, tau_inf: f64, k: f64 },
    /// Linear ramp from τ0 to τ∞ over `duration` seconds.
    Linear { tau0: f64, tau_inf: f64, duration: f64 },
    /// Step from τ0 to τ∞ at `at` seconds.
    Step { tau0: f64, tau_inf: f64, at: f64 },
    /// Constant τ (the "static threshold" ablation baseline).
    Constant { tau: f64 },
}

impl ThresholdSchedule {
    /// The paper's default controller: permissive τ0, strict τ∞.
    /// Values are in normalised-J units (J ∈ [0, 1]; see `cost.rs`);
    /// τ∞ = 0.51 is calibrated so the SST-2-like default stream lands on
    /// Table III's 58% admission rate (see EXPERIMENTS.md T3).
    pub fn paper_default() -> Self {
        ThresholdSchedule::Exponential { tau0: 0.2, tau_inf: 0.51, k: 2.0 }
    }

    /// Evaluate τ at time `t` (seconds since controller start).
    pub fn tau(&self, t: f64) -> f64 {
        let t = t.max(0.0);
        match *self {
            ThresholdSchedule::Exponential { tau0, tau_inf, k } => {
                tau_inf + (tau0 - tau_inf) * (-k * t).exp()
            }
            ThresholdSchedule::Linear { tau0, tau_inf, duration } => {
                if t >= duration {
                    tau_inf
                } else {
                    tau0 + (tau_inf - tau0) * t / duration
                }
            }
            ThresholdSchedule::Step { tau0, tau_inf, at } => {
                if t < at {
                    tau0
                } else {
                    tau_inf
                }
            }
            ThresholdSchedule::Constant { tau } => tau,
        }
    }

    /// Initial threshold τ(0).
    pub fn tau0(&self) -> f64 {
        self.tau(0.0)
    }

    /// Asymptotic threshold τ(∞).
    pub fn tau_inf(&self) -> f64 {
        match *self {
            ThresholdSchedule::Exponential { tau_inf, .. }
            | ThresholdSchedule::Linear { tau_inf, .. }
            | ThresholdSchedule::Step { tau_inf, .. } => tau_inf,
            ThresholdSchedule::Constant { tau } => tau,
        }
    }

    /// Time for the exponential schedule to close 95% of the τ0→τ∞ gap
    /// ("stabilisation time" in the Fig. 1 sketch). None for non-exp.
    pub fn settle_time_95(&self) -> Option<f64> {
        match *self {
            ThresholdSchedule::Exponential { k, .. } => Some(3.0 / k),
            _ => None,
        }
    }

    /// Validate parameters (k > 0 etc.).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            ThresholdSchedule::Exponential { k, .. } if k <= 0.0 => {
                Err(format!("exponential schedule requires k > 0, got {k}"))
            }
            ThresholdSchedule::Linear { duration, .. } if duration <= 0.0 => {
                Err("linear schedule requires duration > 0".to_string())
            }
            _ => Ok(()),
        }
    }
}

/// §IX extension: adaptive τ that servos toward a target admission rate —
/// a [`SetpointTracker`] control law layered on a base schedule
/// (admitting too much raises τ, too little lowers it).
///
/// The current correction is published through an [`Adaptive<f64>`]
/// handle, so the hot path (or a shared [`AdmissionController`], see
/// [`AdmissionController::rate_correction_handle`]) reads it with one
/// atomic load while the control plane drives `observe` on its tick.
///
/// [`AdmissionController`]: crate::controller::AdmissionController
/// [`AdmissionController::rate_correction_handle`]:
///     crate::controller::AdmissionController::rate_correction_handle
#[derive(Debug, Clone)]
pub struct AdaptiveThreshold {
    pub base: ThresholdSchedule,
    law: SetpointTracker,
    correction: Adaptive<f64>,
}

/// Clamp for τ corrections: J is normalised to [0, 1], so ±2 can force
/// admit-all or skip-all from any base schedule.
pub const MAX_TAU_CORRECTION: f64 = 2.0;

impl AdaptiveThreshold {
    /// `ki`: integral gain applied per observation.
    pub fn new(base: ThresholdSchedule, target_admit_rate: f64, ki: f64) -> Self {
        assert!((0.0..=1.0).contains(&target_admit_rate));
        AdaptiveThreshold {
            base,
            law: SetpointTracker::new(
                0.0,
                target_admit_rate,
                ki,
                -MAX_TAU_CORRECTION,
                MAX_TAU_CORRECTION,
            ),
            correction: Adaptive::new(0.0),
        }
    }

    /// Feed back the recently observed admission rate: steps the law and
    /// publishes the new correction.
    pub fn observe(&mut self, admit_rate: f64) {
        let out = self.law.step(admit_rate, 1.0);
        self.correction.set(out);
    }

    pub fn tau(&self, t: f64) -> f64 {
        self.base.tau(t) + self.correction.get()
    }

    pub fn target_admit_rate(&self) -> f64 {
        self.law.setpoint
    }

    pub fn ki(&self) -> f64 {
        self.law.gain
    }

    pub fn correction(&self) -> f64 {
        self.correction.get()
    }

    /// Shared handle onto the live correction (hot-path readers and the
    /// control plane both hold clones of this).
    pub fn correction_handle(&self) -> Adaptive<f64> {
        self.correction.handle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_decay_matches_eq3() {
        let s = ThresholdSchedule::Exponential { tau0: 1.0, tau_inf: 0.2, k: 0.5 };
        assert!((s.tau(0.0) - 1.0).abs() < 1e-12);
        // τ(t) = 0.2 + 0.8·e^(−0.5t)
        let want = 0.2 + 0.8 * (-0.5f64 * 2.0).exp();
        assert!((s.tau(2.0) - want).abs() < 1e-12);
        assert!((s.tau(1e6) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn paper_default_tightens_over_time() {
        // The paper admits high-J work: τ *rises* from permissive (low) to
        // strict (high) in our normalised-J formulation.
        let s = ThresholdSchedule::paper_default();
        assert!(s.tau(0.0) < s.tau(10.0));
        assert!(s.tau(10.0) < s.tau(100.0));
        assert!((s.tau(1e9) - s.tau_inf()).abs() < 1e-9);
        s.validate().unwrap();
    }

    #[test]
    fn monotone_for_all_schedules() {
        let schedules = [
            ThresholdSchedule::Exponential { tau0: 0.0, tau_inf: 1.0, k: 0.3 },
            ThresholdSchedule::Linear { tau0: 0.0, tau_inf: 1.0, duration: 10.0 },
            ThresholdSchedule::Step { tau0: 0.0, tau_inf: 1.0, at: 5.0 },
        ];
        for s in &schedules {
            let mut last = f64::NEG_INFINITY;
            for i in 0..100 {
                let tau = s.tau(i as f64 * 0.5);
                assert!(tau >= last - 1e-12, "{s:?} at {i}");
                last = tau;
            }
        }
    }

    #[test]
    fn linear_endpoints() {
        let s = ThresholdSchedule::Linear { tau0: 2.0, tau_inf: 1.0, duration: 4.0 };
        assert_eq!(s.tau(0.0), 2.0);
        assert_eq!(s.tau(2.0), 1.5);
        assert_eq!(s.tau(4.0), 1.0);
        assert_eq!(s.tau(9.0), 1.0);
    }

    #[test]
    fn step_switches() {
        let s = ThresholdSchedule::Step { tau0: 5.0, tau_inf: 7.0, at: 1.0 };
        assert_eq!(s.tau(0.99), 5.0);
        assert_eq!(s.tau(1.0), 7.0);
    }

    #[test]
    fn settle_time() {
        let s = ThresholdSchedule::Exponential { tau0: 1.0, tau_inf: 0.0, k: 0.15 };
        let t95 = s.settle_time_95().unwrap();
        assert!((s.tau(t95) - 0.0).abs() < 0.05 * 1.0 + 1e-9);
        assert!(ThresholdSchedule::Constant { tau: 1.0 }.settle_time_95().is_none());
    }

    #[test]
    fn negative_time_clamps() {
        let s = ThresholdSchedule::paper_default();
        assert_eq!(s.tau(-5.0), s.tau(0.0));
    }

    #[test]
    fn validation_catches_bad_k() {
        assert!(ThresholdSchedule::Exponential { tau0: 1.0, tau_inf: 0.0, k: -1.0 }
            .validate()
            .is_err());
    }

    #[test]
    fn adaptive_servos_toward_target() {
        let base = ThresholdSchedule::Constant { tau: 0.5 };
        let mut a = AdaptiveThreshold::new(base, 0.6, 0.1);
        let t0 = a.tau(0.0);
        // Observing over-admission raises τ.
        for _ in 0..10 {
            a.observe(0.9);
        }
        assert!(a.tau(0.0) > t0);
        // Observing under-admission lowers it back.
        for _ in 0..30 {
            a.observe(0.1);
        }
        assert!(a.tau(0.0) < t0 + 0.3);
    }

    #[test]
    fn adaptive_publishes_through_the_shared_handle() {
        let mut a = AdaptiveThreshold::new(ThresholdSchedule::Constant { tau: 0.5 }, 0.5, 0.1);
        let handle = a.correction_handle();
        assert_eq!(handle.get(), 0.0);
        a.observe(0.9); // +0.1 * 0.4
        assert!((handle.get() - 0.04).abs() < 1e-12);
        assert!((a.tau(0.0) - 0.54).abs() < 1e-12);
        assert_eq!(a.target_admit_rate(), 0.5);
        assert_eq!(a.ki(), 0.1);
    }

    #[test]
    fn adaptive_correction_is_clamped() {
        let mut a = AdaptiveThreshold::new(ThresholdSchedule::Constant { tau: 0.5 }, 0.0, 1.0);
        for _ in 0..100 {
            a.observe(1.0);
        }
        assert_eq!(a.correction(), MAX_TAU_CORRECTION);
    }
}
