//! CO₂ accounting — the CodeCarbon analog.
//!
//! kWh × regional grid carbon intensity (kg CO₂eq / kWh). The intensity
//! table carries representative 2024 grid averages; the paper's §VIII
//! explicitly flags that CO₂ depends on the region, so the region is a
//! first-class parameter here and in the CLI.

/// Grid carbon intensity in kg CO₂eq per kWh.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridIntensity {
    pub region: &'static str,
    pub kg_co2_per_kwh: f64,
}

/// Representative regional averages (order: dirtiest first).
pub const REGIONS: &[GridIntensity] = &[
    GridIntensity { region: "world", kg_co2_per_kwh: 0.475 },
    GridIntensity { region: "us", kg_co2_per_kwh: 0.38 },
    GridIntensity { region: "de", kg_co2_per_kwh: 0.35 },
    GridIntensity { region: "tn", kg_co2_per_kwh: 0.47 }, // Tunisia (authors' lab)
    GridIntensity { region: "fr", kg_co2_per_kwh: 0.056 },
    GridIntensity { region: "se", kg_co2_per_kwh: 0.013 },
];

/// The paper's Table II implicitly uses ~0.5 kg/kWh (energy 0.1972 kWh ->
/// 0.0986 kg): exactly a 0.5 factor. We expose it for reproducing rows.
pub const PAPER_TABLE2_FACTOR: f64 = 0.5;

/// Look up a region's intensity.
pub fn intensity(region: &str) -> Option<GridIntensity> {
    REGIONS.iter().copied().find(|g| g.region == region)
}

/// Stateful accountant: accumulates kWh and converts to CO₂.
#[derive(Debug, Clone)]
pub struct CarbonAccountant {
    factor: f64,
    kwh: f64,
}

impl CarbonAccountant {
    pub fn new(kg_co2_per_kwh: f64) -> Self {
        assert!(kg_co2_per_kwh >= 0.0);
        CarbonAccountant { factor: kg_co2_per_kwh, kwh: 0.0 }
    }

    /// Accountant matching the paper's Table II CO₂/energy ratio.
    pub fn paper() -> Self {
        CarbonAccountant::new(PAPER_TABLE2_FACTOR)
    }

    pub fn for_region(region: &str) -> Option<Self> {
        intensity(region).map(|g| CarbonAccountant::new(g.kg_co2_per_kwh))
    }

    pub fn add_kwh(&mut self, kwh: f64) {
        self.kwh += kwh;
    }

    pub fn add_joules(&mut self, j: f64) {
        self.kwh += super::joules_to_kwh(j);
    }

    pub fn total_kwh(&self) -> f64 {
        self.kwh
    }

    /// Total kg CO₂eq so far.
    pub fn total_co2_kg(&self) -> f64 {
        self.kwh * self.factor
    }

    /// One-shot conversion.
    pub fn co2_for_kwh(&self, kwh: f64) -> f64 {
        kwh * self.factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_factor_reproduces_table2() {
        let acc = CarbonAccountant::paper();
        // DistilBERT @ FastAPI row: 0.1972 kWh -> 0.0986 kg
        assert!((acc.co2_for_kwh(0.1972) - 0.0986).abs() < 1e-9);
        // ResNet @ Triton row: 0.2198 kWh -> 0.1099 kg
        assert!((acc.co2_for_kwh(0.2198) - 0.1099).abs() < 1e-9);
    }

    #[test]
    fn accumulation() {
        let mut acc = CarbonAccountant::new(0.4);
        acc.add_kwh(1.0);
        acc.add_joules(crate::energy::J_PER_KWH); // +1 kWh
        assert!((acc.total_kwh() - 2.0).abs() < 1e-12);
        assert!((acc.total_co2_kg() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn region_lookup() {
        assert!(intensity("fr").unwrap().kg_co2_per_kwh < intensity("us").unwrap().kg_co2_per_kwh);
        assert!(intensity("atlantis").is_none());
        assert!(CarbonAccountant::for_region("se").is_some());
    }
}
