//! CO₂ accounting — the CodeCarbon analog.
//!
//! kWh × regional grid carbon intensity (kg CO₂eq / kWh). The intensity
//! table carries representative 2024 grid averages; the paper's §VIII
//! explicitly flags that CO₂ depends on the region, so the region is a
//! first-class parameter here and in the CLI.

/// Grid carbon intensity in kg CO₂eq per kWh.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridIntensity {
    pub region: &'static str,
    pub kg_co2_per_kwh: f64,
}

/// World-average grid intensity — the fallback when no region or trace
/// is configured.
pub const WORLD_KG_CO2_PER_KWH: f64 = 0.475;

/// Representative regional averages (order: dirtiest first).
pub const REGIONS: &[GridIntensity] = &[
    GridIntensity { region: "world", kg_co2_per_kwh: WORLD_KG_CO2_PER_KWH },
    GridIntensity { region: "us", kg_co2_per_kwh: 0.38 },
    GridIntensity { region: "de", kg_co2_per_kwh: 0.35 },
    GridIntensity { region: "tn", kg_co2_per_kwh: 0.47 }, // Tunisia (authors' lab)
    GridIntensity { region: "fr", kg_co2_per_kwh: 0.056 },
    GridIntensity { region: "se", kg_co2_per_kwh: 0.013 },
];

/// The paper's Table II implicitly uses ~0.5 kg/kWh (energy 0.1972 kWh ->
/// 0.0986 kg): exactly a 0.5 factor. We expose it for reproducing rows.
pub const PAPER_TABLE2_FACTOR: f64 = 0.5;

/// Look up a region's intensity.
pub fn intensity(region: &str) -> Option<GridIntensity> {
    REGIONS.iter().copied().find(|g| g.region == region)
}

/// Stateful accountant: accumulates kWh and converts to CO₂.
#[derive(Debug, Clone)]
pub struct CarbonAccountant {
    factor: f64,
    kwh: f64,
}

impl CarbonAccountant {
    pub fn new(kg_co2_per_kwh: f64) -> Self {
        assert!(kg_co2_per_kwh >= 0.0);
        CarbonAccountant { factor: kg_co2_per_kwh, kwh: 0.0 }
    }

    /// Accountant matching the paper's Table II CO₂/energy ratio.
    pub fn paper() -> Self {
        CarbonAccountant::new(PAPER_TABLE2_FACTOR)
    }

    pub fn for_region(region: &str) -> Option<Self> {
        intensity(region).map(|g| CarbonAccountant::new(g.kg_co2_per_kwh))
    }

    pub fn add_kwh(&mut self, kwh: f64) {
        self.kwh += kwh;
    }

    pub fn add_joules(&mut self, j: f64) {
        self.kwh += super::joules_to_kwh(j);
    }

    pub fn total_kwh(&self) -> f64 {
        self.kwh
    }

    /// Total kg CO₂eq so far.
    pub fn total_co2_kg(&self) -> f64 {
        self.kwh * self.factor
    }

    /// One-shot conversion.
    pub fn co2_for_kwh(&self, kwh: f64) -> f64 {
        kwh * self.factor
    }
}

/// Time-varying grid carbon intensity: a right-continuous step function
/// of `(t_secs, kg CO₂/kWh)` breakpoints, the signal the `CarbonPacer`
/// control law observes. Loadable from a two-column CSV
/// (`t_secs,kg_co2_per_kwh`, header required — docs/SCENARIOS.md) so a
/// real grid forecast can be replayed against the gateway.
#[derive(Debug, Clone, PartialEq)]
pub struct CarbonIntensityTrace {
    /// Sorted ascending by time; the first step's intensity also covers
    /// t < steps[0].0.
    steps: Vec<(f64, f64)>,
}

impl CarbonIntensityTrace {
    /// Build from breakpoints. Sorts by time; panics on empty input or
    /// non-finite / negative values (a trace is config, not data).
    pub fn new(mut steps: Vec<(f64, f64)>) -> Self {
        assert!(!steps.is_empty(), "carbon trace needs at least one step");
        for &(t, v) in &steps {
            assert!(t.is_finite() && v.is_finite() && v >= 0.0, "bad step ({t}, {v})");
        }
        steps.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        CarbonIntensityTrace { steps }
    }

    /// A flat trace (the regional-average degenerate case).
    pub fn constant(kg_co2_per_kwh: f64) -> Self {
        CarbonIntensityTrace::new(vec![(0.0, kg_co2_per_kwh)])
    }

    /// Intensity at time `t` (seconds from trace start): the last step at
    /// or before `t`, clamped to the first step before it.
    pub fn intensity_at(&self, t: f64) -> f64 {
        let mut current = self.steps[0].1;
        for &(start, v) in &self.steps {
            if start <= t {
                current = v;
            } else {
                break;
            }
        }
        current
    }

    pub fn steps(&self) -> &[(f64, f64)] {
        &self.steps
    }

    /// Lowest intensity anywhere on the trace — the "clean window" level
    /// a pacer threshold is usually set just above.
    pub fn min_intensity(&self) -> f64 {
        self.steps.iter().map(|s| s.1).fold(f64::INFINITY, f64::min)
    }

    /// Serialise to the CSV schema (`t_secs,kg_co2_per_kwh`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("t_secs,kg_co2_per_kwh\n");
        for &(t, v) in &self.steps {
            out.push_str(&format!("{t:.3},{v:.6}\n"));
        }
        out
    }

    /// Parse the CSV schema back (header line skipped, blanks ignored).
    pub fn from_csv(text: &str) -> Result<Self, String> {
        let mut steps = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            if ln == 0 || line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != 2 {
                return Err(format!("line {}: expected 2 fields, got {}", ln + 1, f.len()));
            }
            let t: f64 = f[0].trim().parse().map_err(|e| format!("line {}: t: {e}", ln + 1))?;
            let v: f64 =
                f[1].trim().parse().map_err(|e| format!("line {}: intensity: {e}", ln + 1))?;
            if !t.is_finite() || !v.is_finite() || v < 0.0 {
                return Err(format!("line {}: non-finite or negative step ({t}, {v})", ln + 1));
            }
            steps.push((t, v));
        }
        if steps.is_empty() {
            return Err("carbon trace has no steps".to_string());
        }
        Ok(CarbonIntensityTrace::new(steps))
    }

    /// Load the CSV schema from a file.
    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_csv(&text)
    }
}

/// Running CO₂ ledger for a live serving system: grams emitted (energy ×
/// intensity-at-spend-time) and grams *avoided* by deferring or skipping
/// deferrable work under carbon pressure. Backs the `gf_co2_total` /
/// `gf_co2_deferred_grams` gauges and the gateway's `carbon` stats block.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CarbonLedger {
    grams: f64,
    deferred_grams: f64,
}

impl CarbonLedger {
    pub fn new() -> Self {
        CarbonLedger::default()
    }

    /// Charge `joules` spent at the given intensity (kg CO₂/kWh → grams).
    pub fn record(&mut self, joules: f64, kg_co2_per_kwh: f64) {
        if joules.is_finite() && kg_co2_per_kwh.is_finite() {
            self.grams += super::joules_to_kwh(joules.max(0.0)) * kg_co2_per_kwh.max(0.0) * 1000.0;
        }
    }

    /// Credit `joules` of work *not* done now because the pacer deferred
    /// it out of a dirty window.
    pub fn record_deferred(&mut self, joules: f64, kg_co2_per_kwh: f64) {
        if joules.is_finite() && kg_co2_per_kwh.is_finite() {
            self.deferred_grams +=
                super::joules_to_kwh(joules.max(0.0)) * kg_co2_per_kwh.max(0.0) * 1000.0;
        }
    }

    /// Total grams CO₂eq emitted.
    pub fn grams(&self) -> f64 {
        self.grams
    }

    /// Total grams CO₂eq avoided by deferral.
    pub fn deferred_grams(&self) -> f64 {
        self.deferred_grams
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_factor_reproduces_table2() {
        let acc = CarbonAccountant::paper();
        // DistilBERT @ FastAPI row: 0.1972 kWh -> 0.0986 kg
        assert!((acc.co2_for_kwh(0.1972) - 0.0986).abs() < 1e-9);
        // ResNet @ Triton row: 0.2198 kWh -> 0.1099 kg
        assert!((acc.co2_for_kwh(0.2198) - 0.1099).abs() < 1e-9);
    }

    #[test]
    fn accumulation() {
        let mut acc = CarbonAccountant::new(0.4);
        acc.add_kwh(1.0);
        acc.add_joules(crate::energy::J_PER_KWH); // +1 kWh
        assert!((acc.total_kwh() - 2.0).abs() < 1e-12);
        assert!((acc.total_co2_kg() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn region_lookup() {
        assert!(intensity("fr").unwrap().kg_co2_per_kwh < intensity("us").unwrap().kg_co2_per_kwh);
        assert!(intensity("atlantis").is_none());
        assert!(CarbonAccountant::for_region("se").is_some());
    }

    #[test]
    fn trace_step_function_semantics() {
        let tr = CarbonIntensityTrace::new(vec![(0.0, 0.5), (10.0, 0.1), (20.0, 0.4)]);
        assert_eq!(tr.intensity_at(-5.0), 0.5); // clamp before first step
        assert_eq!(tr.intensity_at(0.0), 0.5);
        assert_eq!(tr.intensity_at(9.999), 0.5);
        assert_eq!(tr.intensity_at(10.0), 0.1); // right-continuous
        assert_eq!(tr.intensity_at(19.0), 0.1);
        assert_eq!(tr.intensity_at(25.0), 0.4);
        assert_eq!(tr.min_intensity(), 0.1);
        assert_eq!(CarbonIntensityTrace::constant(0.3).intensity_at(1e9), 0.3);
    }

    #[test]
    fn trace_sorts_unordered_steps() {
        let tr = CarbonIntensityTrace::new(vec![(20.0, 0.4), (0.0, 0.5), (10.0, 0.1)]);
        assert_eq!(tr.steps()[0], (0.0, 0.5));
        assert_eq!(tr.intensity_at(15.0), 0.1);
    }

    #[test]
    fn trace_csv_round_trip() {
        let tr = CarbonIntensityTrace::new(vec![(0.0, 0.475), (30.0, 0.056), (60.0, 0.475)]);
        let parsed = CarbonIntensityTrace::from_csv(&tr.to_csv()).unwrap();
        assert_eq!(parsed.steps().len(), 3);
        for (a, b) in tr.steps().iter().zip(parsed.steps()) {
            assert!((a.0 - b.0).abs() < 1e-6 && (a.1 - b.1).abs() < 1e-6);
        }
    }

    #[test]
    fn trace_csv_rejects_bad_rows() {
        assert!(CarbonIntensityTrace::from_csv("h\n").is_err()); // empty
        assert!(CarbonIntensityTrace::from_csv("h\n1.0\n").is_err()); // field count
        assert!(CarbonIntensityTrace::from_csv("h\nx,0.3\n").is_err()); // parse
        assert!(CarbonIntensityTrace::from_csv("h\n0.0,NaN\n").is_err()); // non-finite
        assert!(CarbonIntensityTrace::from_csv("h\n0.0,-0.1\n").is_err()); // negative
    }

    #[test]
    fn ledger_accumulates_grams() {
        let mut l = CarbonLedger::new();
        // 1 kWh at 0.5 kg/kWh = 500 g.
        l.record(crate::energy::J_PER_KWH, 0.5);
        assert!((l.grams() - 500.0).abs() < 1e-9);
        l.record_deferred(crate::energy::J_PER_KWH / 2.0, 0.4);
        assert!((l.deferred_grams() - 200.0).abs() < 1e-9);
        // Garbage inputs are ignored, not propagated.
        l.record(f64::NAN, 0.5);
        l.record(-1.0, 0.5);
        assert!((l.grams() - 500.0).abs() < 1e-9);
    }
}
