//! The energy meter: attributes joules to requests and maintains the
//! rolling joules/request EWMA that the controller reads as E(x).
//!
//! Two attribution modes, mirroring how the paper's numbers were produced:
//!
//! * **Simulated** — energy = profile.exec_energy(flops): what the paper's
//!   GPU *would* burn for that much work (plus idle leakage attributed
//!   over wallclock). Used to report kWh/CO₂ on the paper's devices.
//! * **Measured** — energy = wallclock × power(profile, utilization):
//!   integrates the actual CPU execution interval. Used for §Perf where
//!   relative changes matter.

use std::sync::Mutex;

use super::profile::DeviceProfile;
use crate::stats::{Ewma, Streaming};

/// One request's energy attribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReading {
    /// Joules attributed to this request.
    pub joules: f64,
    /// Rolling joules/request EWMA *after* this reading (the E(x) proxy).
    pub ewma_joules: f64,
}

/// Attribution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeterMode {
    /// Energy from FLOPs through the device profile roofline.
    SimulatedFlops,
    /// Energy from measured busy seconds at full utilization.
    MeasuredWallclock,
}

/// Thread-safe energy accountant for one serving path.
///
/// A single `Mutex` is fine here: the critical section is ~100 ns and the
/// meter is touched once per request (not per batch item).
#[derive(Debug)]
pub struct EnergyMeter {
    inner: Mutex<Inner>,
    profile: DeviceProfile,
    mode: MeterMode,
}

#[derive(Debug)]
struct Inner {
    ewma: Ewma,
    totals: Streaming,
    total_joules: f64,
    /// Joules an execution *would* have burned but didn't (coalesced
    /// followers). Never added to `total_joules` — spent and saved are
    /// disjoint ledgers.
    saved_joules: f64,
}

impl EnergyMeter {
    /// `ewma_span`: number of requests over which E(x) forgets (paper uses
    /// a "rolling average of joules per request").
    pub fn new(profile: DeviceProfile, mode: MeterMode, ewma_span: f64) -> Self {
        EnergyMeter {
            inner: Mutex::new(Inner {
                ewma: Ewma::with_span(ewma_span),
                totals: Streaming::new(),
                total_joules: 0.0,
                saved_joules: 0.0,
            }),
            profile,
            mode,
        }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    pub fn mode(&self) -> MeterMode {
        self.mode
    }

    /// Record a request execution: `flops` of attributed work over
    /// `busy_secs` of wallclock (per-item share of its batch).
    pub fn record(&self, flops: f64, busy_secs: f64) -> EnergyReading {
        let joules = match self.mode {
            MeterMode::SimulatedFlops => self.profile.exec_energy(flops),
            MeterMode::MeasuredWallclock => self.profile.power_at(1.0) * busy_secs,
        };
        let mut g = self.inner.lock().unwrap();
        g.total_joules += joules;
        g.totals.push(joules);
        let ewma = g.ewma.push(joules);
        EnergyReading { joules, ewma_joules: ewma }
    }

    /// Attribute idle leakage over a wallclock interval with no requests
    /// (counted into totals but not into the per-request EWMA).
    pub fn record_idle(&self, secs: f64) {
        let joules = self.profile.power_at(0.0) * secs;
        self.inner.lock().unwrap().total_joules += joules;
    }

    /// Credit joules an avoided execution would have burned (a
    /// coalesced follower answered from its leader's result). Kept out
    /// of `total_joules` and the EWMA: E(x) must keep reflecting what
    /// executions actually cost.
    pub fn record_saved(&self, joules: f64) {
        if joules.is_finite() && joules > 0.0 {
            self.inner.lock().unwrap().saved_joules += joules;
        }
    }

    /// Total joules avoided through coalescing (`gf_joules_saved_total`).
    pub fn total_joules_saved(&self) -> f64 {
        self.inner.lock().unwrap().saved_joules
    }

    /// Current rolling joules/request (the controller's E(x) input);
    /// `default` until the first request.
    pub fn ewma_joules(&self, default: f64) -> f64 {
        self.inner.lock().unwrap().ewma.get_or(default)
    }

    /// Total attributed joules so far.
    pub fn total_joules(&self) -> f64 {
        self.inner.lock().unwrap().total_joules
    }

    /// Total in kWh (CodeCarbon's reporting unit).
    pub fn total_kwh(&self) -> f64 {
        super::joules_to_kwh(self.total_joules())
    }

    /// (count, mean, std) of per-request joules.
    pub fn per_request_stats(&self) -> (u64, f64, f64) {
        let g = self.inner.lock().unwrap();
        (g.totals.count(), g.totals.mean(), g.totals.std_dev())
    }

    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.ewma.reset();
        g.totals = Streaming::new();
        g.total_joules = 0.0;
        g.saved_joules = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meter(mode: MeterMode) -> EnergyMeter {
        EnergyMeter::new(DeviceProfile::rtx4000_ada(), mode, 16.0)
    }

    #[test]
    fn simulated_mode_uses_flops() {
        let m = meter(MeterMode::SimulatedFlops);
        let r1 = m.record(1e9, 0.0);
        let r2 = m.record(2e9, 0.0);
        assert!((r2.joules / r1.joules - 2.0).abs() < 1e-9);
    }

    #[test]
    fn measured_mode_uses_wallclock() {
        let m = meter(MeterMode::MeasuredWallclock);
        let r = m.record(0.0, 0.5);
        let expect = DeviceProfile::rtx4000_ada().peak_watts * 0.5;
        assert!((r.joules - expect).abs() < 1e-9);
    }

    #[test]
    fn ewma_tracks_constant_load() {
        let m = meter(MeterMode::SimulatedFlops);
        let mut last = 0.0;
        for _ in 0..200 {
            last = m.record(1e9, 0.0).ewma_joules;
        }
        let single = DeviceProfile::rtx4000_ada().exec_energy(1e9);
        assert!((last - single).abs() / single < 1e-6);
    }

    #[test]
    fn totals_accumulate() {
        let m = meter(MeterMode::SimulatedFlops);
        for _ in 0..10 {
            m.record(1e9, 0.0);
        }
        let (n, mean, std) = m.per_request_stats();
        assert_eq!(n, 10);
        assert!(std.abs() < 1e-12);
        assert!((m.total_joules() - 10.0 * mean).abs() < 1e-9);
        assert!(m.total_kwh() > 0.0);
    }

    #[test]
    fn idle_counts_into_totals_not_ewma() {
        let m = meter(MeterMode::SimulatedFlops);
        m.record_idle(10.0);
        assert!(m.total_joules() > 0.0);
        assert_eq!(m.ewma_joules(-1.0), -1.0, "EWMA untouched by idle");
    }

    #[test]
    fn reset_clears() {
        let m = meter(MeterMode::SimulatedFlops);
        m.record(1e9, 0.0);
        m.record_saved(1.0);
        m.reset();
        assert_eq!(m.total_joules(), 0.0);
        assert_eq!(m.total_joules_saved(), 0.0);
        assert_eq!(m.per_request_stats().0, 0);
    }

    #[test]
    fn saved_joules_stay_out_of_spent_ledger() {
        let m = meter(MeterMode::SimulatedFlops);
        let spent = m.record(1e9, 0.0).joules;
        m.record_saved(spent);
        m.record_saved(spent);
        m.record_saved(f64::NAN); // ignored
        m.record_saved(-1.0); // ignored
        assert!((m.total_joules_saved() - 2.0 * spent).abs() < 1e-12);
        assert!((m.total_joules() - spent).abs() < 1e-12, "spent unchanged");
        let (n, _, _) = m.per_request_stats();
        assert_eq!(n, 1, "EWMA/totals see only real executions");
    }

    #[test]
    fn meter_is_sync() {
        fn is_sync<T: Sync>() {}
        is_sync::<EnergyMeter>();
    }
}
