//! Energy metering substrate — the CodeCarbon + NVML analog (DESIGN.md §2).
//!
//! The paper estimates per-request energy by sampling GPU power via NVML
//! and attributing it with CodeCarbon. Without the physical GPU we keep the
//! *interface* and the *dynamics* identical and substitute the power
//! source:
//!
//! * [`profile::DeviceProfile`] — published idle/peak power and peak
//!   FLOP/s for the paper's devices (RTX 4000 Ada, A100, RTX 4090) plus
//!   the CPU we actually run on;
//! * [`meter::EnergyMeter`] — integrates utilization-derived power over
//!   measured execution intervals, maintaining the rolling joules/request
//!   EWMA that is the controller's E(x) proxy (Appendix A, line 3);
//! * [`sampler::PowerSampler`] — NVML-style noisy periodic power readings
//!   for telemetry export;
//! * [`carbon::CarbonAccountant`] — kWh -> CO₂ with a regional grid
//!   intensity table (the paper's §VIII threat: "CO₂ estimates depend on
//!   regional grid intensity").

pub mod carbon;
pub mod meter;
pub mod profile;
pub mod sampler;

pub use carbon::{CarbonAccountant, CarbonIntensityTrace, CarbonLedger};
pub use meter::{EnergyMeter, EnergyReading};
pub use profile::DeviceProfile;

/// Joules -> kWh.
pub const J_PER_KWH: f64 = 3.6e6;

/// Convert joules to kWh.
pub fn joules_to_kwh(j: f64) -> f64 {
    j / J_PER_KWH
}

#[cfg(test)]
mod tests {
    #[test]
    fn kwh_conversion() {
        assert!((super::joules_to_kwh(3.6e6) - 1.0).abs() < 1e-12);
    }
}
