//! Device power/compute profiles.
//!
//! Numbers are public board specifications (TGP, boost-clock FLOP/s); the
//! energy model only needs *ratios* to be plausible — DESIGN.md §2 notes
//! that absolute joules are testbed-bound while the controller consumes
//! only the rolling EWMA and the report compares deltas.

/// Static description of an execution device.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Power draw when idle (W).
    pub idle_watts: f64,
    /// Power draw at full utilization (W).
    pub peak_watts: f64,
    /// Peak dense f32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// Memory bandwidth (B/s) — used for roofline estimates.
    pub mem_bw: f64,
    /// Fraction of peak FLOP/s a well-tuned serving kernel achieves.
    /// Calibrates simulated execution time; ~0.25–0.45 on small batches.
    pub achievable_frac: f64,
}

impl DeviceProfile {
    /// NVIDIA RTX 4000 Ada (the paper's abstract/eval GPU): 130 W board
    /// power, 26.7 TFLOP/s f32, 360 GB/s.
    pub fn rtx4000_ada() -> Self {
        DeviceProfile {
            name: "rtx4000_ada",
            idle_watts: 16.0,
            peak_watts: 130.0,
            peak_flops: 26.7e12,
            mem_bw: 360.0e9,
            achievable_frac: 0.30,
        }
    }

    /// NVIDIA A100 SXM (Table III's ablation device): 400 W, 19.5 TFLOP/s
    /// f32 (non-tensor), 1555 GB/s.
    pub fn a100() -> Self {
        DeviceProfile {
            name: "a100",
            idle_watts: 55.0,
            peak_watts: 400.0,
            peak_flops: 19.5e12,
            mem_bw: 1555.0e9,
            achievable_frac: 0.35,
        }
    }

    /// NVIDIA RTX 4090 (Appendix B PoC box): 450 W, 82.6 TFLOP/s f32,
    /// 1008 GB/s.
    pub fn rtx4090() -> Self {
        DeviceProfile {
            name: "rtx4090",
            idle_watts: 22.0,
            peak_watts: 450.0,
            peak_flops: 82.6e12,
            mem_bw: 1008.0e9,
            achievable_frac: 0.30,
        }
    }

    /// The EPYC-class CPU the reproduction actually executes on (PJRT CPU
    /// backend). Used when metering *measured* wallclock.
    pub fn cpu_epyc() -> Self {
        DeviceProfile {
            name: "cpu_epyc",
            idle_watts: 90.0,
            peak_watts: 280.0,
            peak_flops: 2.0e12,
            mem_bw: 200.0e9,
            achievable_frac: 0.20,
        }
    }

    /// Look up a profile by name (CLI `--device`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "rtx4000_ada" | "rtx4000ada" => Some(Self::rtx4000_ada()),
            "a100" => Some(Self::a100()),
            "rtx4090" => Some(Self::rtx4090()),
            "cpu" | "cpu_epyc" => Some(Self::cpu_epyc()),
            _ => None,
        }
    }

    /// Power draw at a given utilization in [0, 1]: affine interpolation
    /// between idle and peak (the first-order NVML-observed behaviour).
    pub fn power_at(&self, utilization: f64) -> f64 {
        let u = utilization.clamp(0.0, 1.0);
        self.idle_watts + (self.peak_watts - self.idle_watts) * u
    }

    /// Simulated execution time for `flops` of work at a given achieved
    /// utilization (compute roofline; the serving models are far from the
    /// bandwidth roof at these sizes).
    pub fn exec_time(&self, flops: f64) -> f64 {
        flops / (self.peak_flops * self.achievable_frac)
    }

    /// Energy (J) to run `flops` of work: busy power times roofline time.
    pub fn exec_energy(&self, flops: f64) -> f64 {
        self.power_at(1.0) * self.exec_time(flops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(DeviceProfile::by_name("a100").unwrap().name, "a100");
        assert_eq!(DeviceProfile::by_name("cpu").unwrap().name, "cpu_epyc");
        assert!(DeviceProfile::by_name("tpu9000").is_none());
    }

    #[test]
    fn power_interpolates_and_clamps() {
        let d = DeviceProfile::rtx4000_ada();
        assert_eq!(d.power_at(0.0), d.idle_watts);
        assert_eq!(d.power_at(1.0), d.peak_watts);
        assert_eq!(d.power_at(2.0), d.peak_watts);
        let mid = d.power_at(0.5);
        assert!(mid > d.idle_watts && mid < d.peak_watts);
    }

    #[test]
    fn exec_time_scales_linearly() {
        let d = DeviceProfile::a100();
        let t1 = d.exec_time(1e9);
        let t2 = d.exec_time(2e9);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_gpu_is_faster() {
        let f = 1e12;
        assert!(DeviceProfile::rtx4090().exec_time(f) < DeviceProfile::rtx4000_ada().exec_time(f));
    }

    #[test]
    fn energy_positive_and_finite() {
        for d in [
            DeviceProfile::rtx4000_ada(),
            DeviceProfile::a100(),
            DeviceProfile::rtx4090(),
            DeviceProfile::cpu_epyc(),
        ] {
            let e = d.exec_energy(4.7e6); // distilbert_mini b1
            assert!(e.is_finite() && e > 0.0, "{}: {e}", d.name);
        }
    }
}
