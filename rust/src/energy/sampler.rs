//! NVML-style power sampler: periodic, noisy power readings derived from
//! the device's recent utilization — produces the power trace that the
//! telemetry exporter logs next to MLflow metrics, as CodeCarbon does.

use crate::energy::profile::DeviceProfile;
use crate::stats::ewma::TimeEwma;
use crate::util::Rng;

/// One power sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerSample {
    /// Sample time (seconds since meter start).
    pub t: f64,
    /// Instantaneous board power (W).
    pub watts: f64,
    /// Utilization estimate in [0,1] at sample time.
    pub utilization: f64,
}

/// Collects busy intervals and renders an NVML-like sampled power trace.
#[derive(Debug)]
pub struct PowerSampler {
    profile: DeviceProfile,
    /// Utilization smoothing constant — NVML power readings lag real load.
    util: TimeEwma,
    busy_until: f64,
    samples: Vec<PowerSample>,
    period: f64,
    next_sample: f64,
    noise_std: f64,
    rng: Rng,
}

impl PowerSampler {
    /// `period`: sampling interval in seconds (NVML default ~0.1 s);
    /// `noise_std`: gaussian measurement noise in watts.
    pub fn new(profile: DeviceProfile, period: f64, noise_std: f64, seed: u64) -> Self {
        assert!(period > 0.0);
        PowerSampler {
            profile,
            util: TimeEwma::new(period * 2.0),
            busy_until: 0.0,
            samples: Vec::new(),
            period,
            next_sample: 0.0,
            noise_std,
            rng: Rng::new(seed),
        }
    }

    /// Report that the device is busy for `[start, start+dur)` seconds.
    pub fn report_busy(&mut self, start: f64, dur: f64) {
        self.busy_until = self.busy_until.max(start + dur);
        self.util.push(start, 1.0);
    }

    /// Advance sampled time to `t`, emitting periodic samples.
    pub fn advance_to(&mut self, t: f64) {
        while self.next_sample <= t {
            let ts = self.next_sample;
            let busy = if ts < self.busy_until { 1.0 } else { 0.0 };
            let u = self.util.push(ts, busy).clamp(0.0, 1.0);
            let base = self.profile.power_at(u);
            let noise = self.rng.normal_with(0.0, self.noise_std);
            self.samples.push(PowerSample {
                t: ts,
                watts: (base + noise).max(0.0),
                utilization: u,
            });
            self.next_sample += self.period;
        }
    }

    pub fn samples(&self) -> &[PowerSample] {
        &self.samples
    }

    /// Trapezoidal energy integral of the sampled trace (J) — what
    /// CodeCarbon reports from NVML.
    pub fn integrated_joules(&self) -> f64 {
        self.samples
            .windows(2)
            .map(|w| 0.5 * (w[0].watts + w[1].watts) * (w[1].t - w[0].t))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler() -> PowerSampler {
        PowerSampler::new(DeviceProfile::rtx4000_ada(), 0.1, 0.0, 1)
    }

    #[test]
    fn idle_trace_at_idle_power() {
        let mut s = sampler();
        s.advance_to(1.0);
        let idle = DeviceProfile::rtx4000_ada().idle_watts;
        for smp in s.samples() {
            assert!((smp.watts - idle).abs() < 1.0, "{:?}", smp);
        }
    }

    #[test]
    fn busy_raises_power() {
        let mut s = sampler();
        s.advance_to(0.5);
        s.report_busy(0.5, 2.0);
        s.advance_to(2.5);
        let max = s.samples().iter().map(|x| x.watts).fold(0.0, f64::max);
        assert!(max > DeviceProfile::rtx4000_ada().idle_watts + 20.0, "max={max}");
    }

    #[test]
    fn integral_positive_and_bounded() {
        let mut s = sampler();
        s.report_busy(0.0, 1.0);
        s.advance_to(2.0);
        let j = s.integrated_joules();
        let d = DeviceProfile::rtx4000_ada();
        assert!(j > d.idle_watts * 1.9, "j={j}");
        assert!(j < d.peak_watts * 2.1, "j={j}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut s = PowerSampler::new(DeviceProfile::a100(), 0.05, 3.0, 42);
            s.report_busy(0.1, 0.5);
            s.advance_to(1.0);
            s.samples().to_vec()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn sample_cadence() {
        let mut s = sampler();
        s.advance_to(1.05);
        assert_eq!(s.samples().len(), 11); // t = 0.0 .. 1.0 step 0.1
    }
}
