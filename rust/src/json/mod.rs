//! Minimal JSON parser + writer (serde is unavailable offline; DESIGN.md §6).
//!
//! Covers the full JSON grammar needed by `manifest.json` /
//! `repository.json` and by telemetry export: objects, arrays, strings
//! with escapes, numbers, booleans, null. Not intended as a general-purpose
//! library: numbers parse to f64 (the manifests only carry ints that fit).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) so output is canonical.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

// Hand-written error impls (no `thiserror`) keep the dependency graph
// path-only — see `runtime::RuntimeError`.
#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
    Type(&'static str),
    Missing(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(c, i) => write!(f, "unexpected character {c:?} at byte {i}"),
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(i) => write!(f, "invalid escape at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing garbage at byte {i}"),
            JsonError::Type(what) => write!(f, "type error: expected {what}"),
            JsonError::Missing(key) => write!(f, "missing key {key:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Value {
    // ---------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(JsonError::Type("number")),
        }
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value], JsonError> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(JsonError::Type("array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>, JsonError> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => Err(JsonError::Type("object")),
        }
    }

    /// Object field access: `v.get("params")?`.
    pub fn get(&self, key: &str) -> Result<&Value, JsonError> {
        self.as_obj()?.get(key).ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// Optional field access (None when absent, error when not an object).
    pub fn opt(&self, key: &str) -> Result<Option<&Value>, JsonError> {
        Ok(self.as_obj()?.get(key))
    }

    // ---------------------------------------------------------- writer

    /// Serialise to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

// ------------------------------------------------------------------ parser

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(JsonError::Trailing(p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or(JsonError::BadNumber(start))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::Eof(self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            // BMP only — manifests never carry surrogate pairs.
                            out.push(char::from_u32(cp).ok_or(JsonError::BadEscape(self.i))?);
                            self.i += 4;
                        }
                        _ => return Err(JsonError::BadEscape(self.i - 1)),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.i - 1;
                        if start + len > self.b.len() {
                            return Err(JsonError::Eof(self.i));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| JsonError::BadEscape(start))?;
                        out.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"énergie ☘\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "énergie ☘");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","params":[{"numel":3,"shape":[1,3]}],"z":true}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn writer_escapes() {
        let v = Value::Str("a\"b\nc".into());
        assert_eq!(v.to_json(), r#""a\"b\nc""#);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Value::Num(5.0).to_json(), "5");
        assert_eq!(Value::Num(5.5).to_json(), "5.5");
    }

    #[test]
    fn real_manifest_parses() {
        // Shape of what aot.py writes
        let src = r#"{
 "name": "screener",
 "batch_buckets": [1, 4],
 "hlo_files": {"1": "model.b1.hlo.txt"},
 "params": [{"name": "embed", "shape": [512, 16], "offset": 0, "numel": 8192}]
}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "screener");
        assert_eq!(v.get("batch_buckets").unwrap().as_arr().unwrap()[1].as_i64().unwrap(), 4);
        let p0 = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("numel").unwrap().as_i64().unwrap(), 8192);
    }

    #[test]
    fn missing_key_error() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(matches!(v.get("b"), Err(JsonError::Missing(_))));
    }
}
