//! # greenflow — Green MLOps: closed-loop, energy-aware inference serving
//!
//! Reproduction of *"Green MLOps: Closed-Loop, Energy-Aware Inference with
//! NVIDIA Triton, FastAPI, and Bio-Inspired Thresholding"* (Hamdi & Jabou,
//! 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels (tiled GEMM,
//!   fused softmax+entropy, fused attention, LayerNorm), validated against
//!   pure-jnp oracles.
//! * **Layer 2** (`python/compile/model.py`) — JAX models (`distilbert_mini`,
//!   `resnet_tiny`, `screener`) AOT-lowered to HLO text at build time.
//! * **Layer 3** (this crate) — the serving coordinator: the paper's
//!   bio-inspired closed-loop admission controller ([`controller`]), the
//!   dual-path serving stack (direct "FastAPI+ORT"-style path and a
//!   Triton-style dynamic-batching path, [`batching`] + [`pipeline`]),
//!   energy metering ([`energy`]), and MLflow-style telemetry
//!   ([`telemetry`]).
//!
//! The paper's closed loop is generalised by the [`control`] plane
//! (Observe → Decide → Act): windowed metrics feed pluggable control laws
//! (AIMD, setpoint tracking, energy-budget pacing) whose outputs are
//! published through lock-free `Adaptive<T>` handles — driving the
//! adaptive-τ admission mode, the batcher's queue-delay window, and the
//! router's QPS threshold from one substrate. See [`control`] for the
//! diagram and [`pipeline::system`] for the end-to-end wiring.
//!
//! Python never runs on the request path: `make artifacts` exports a model
//! repository (HLO text + weights + Triton-style `config.pbtxt`) which the
//! [`runtime`] loads through the PJRT C API (`xla` crate).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a bench target.

pub mod batching;
pub mod benchkit;
pub mod cli;
pub mod configsys;
pub mod control;
pub mod controller;
pub mod energy;
pub mod json;
pub mod models;
pub mod pipeline;
pub mod qos;
pub mod router;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod stats;
pub mod telemetry;
pub mod util;
pub mod workload;

/// Crate version reported by the CLI and the HTTP gateway.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default location of the AOT model repository relative to the repo root.
pub const DEFAULT_REPOSITORY: &str = "artifacts";
