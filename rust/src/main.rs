fn main() { greenflow::cli::main(); }
