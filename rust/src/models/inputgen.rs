//! Deterministic synthetic payload generation from request seeds.
//!
//! Every request carries a `seed`; the actual tensor is derived from it on
//! the worker, so traces stay tiny and replays are bit-exact. Token
//! payloads are drawn uniformly from the model's vocab; dense payloads
//! are standard-normal pixels.

use crate::runtime::manifest::{InputKind, ModelManifest};
use crate::runtime::tensor::InputBatch;
use crate::util::Rng;

/// Generate one item's token ids from a seed.
pub fn tokens_one(seed: u64, per_item: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..per_item).map(|_| rng.below(vocab as u64) as i32).collect()
}

/// Generate one item's dense payload from a seed.
pub fn dense_one(seed: u64, per_item: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..per_item).map(|_| rng.normal() as f32).collect()
}

/// Build a token batch for a manifest from request seeds.
pub fn tokens_for(m: &ModelManifest, seeds: &[u64], salt: u64) -> InputBatch {
    let per_item = m.input_numel();
    let vocab = m.vocab.unwrap_or(2);
    let mut data = Vec::with_capacity(seeds.len() * per_item);
    for &s in seeds {
        data.extend(tokens_one(s ^ salt, per_item, vocab));
    }
    InputBatch::Tokens { data, batch: seeds.len(), per_item }
}

/// Build a dense batch for a manifest from request seeds.
pub fn dense_for(m: &ModelManifest, seeds: &[u64], salt: u64) -> InputBatch {
    let per_item = m.input_numel();
    let mut data = Vec::with_capacity(seeds.len() * per_item);
    for &s in seeds {
        data.extend(dense_one(s ^ salt, per_item));
    }
    InputBatch::Dense { data, batch: seeds.len(), per_item }
}

/// Build the right batch kind for the manifest.
pub fn batch_for(m: &ModelManifest, seeds: &[u64], salt: u64) -> InputBatch {
    match m.input_kind {
        InputKind::Tokens => tokens_for(m, seeds, salt),
        InputKind::Dense => dense_for(m, seeds, salt),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelManifest;

    fn toy_manifest(kind: &str) -> ModelManifest {
        let json = format!(
            r#"{{
          "name": "toy", "family": "x", "classes": 2,
          "batch_buckets": [1],
          "weights_file": "weights.bin",
          "hlo_files": {{"1": "model.b1.hlo.txt"}},
          "params": [],
          "input": {{"name": "x", "kind": "{kind}", "shape_per_item": [4, 2],
                    "dtype": "i32", "vocab": 16}}
        }}"#
        );
        ModelManifest::from_json(&json).unwrap()
    }

    #[test]
    fn tokens_respect_vocab() {
        let ids = tokens_one(42, 1000, 16);
        assert!(ids.iter().all(|&t| (0..16).contains(&t)));
        assert_eq!(ids.len(), 1000);
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(tokens_one(1, 32, 512), tokens_one(1, 32, 512));
        assert_ne!(tokens_one(1, 32, 512), tokens_one(2, 32, 512));
        assert_eq!(dense_one(3, 10), dense_one(3, 10));
    }

    #[test]
    fn batch_layout() {
        let m = toy_manifest("tokens");
        let b = batch_for(&m, &[1, 2, 3], 0);
        assert_eq!(b.batch(), 3);
        assert_eq!(b.per_item(), 8);
        match b {
            InputBatch::Tokens { data, .. } => assert_eq!(data.len(), 24),
            _ => panic!("expected token batch"),
        }
    }

    #[test]
    fn dense_kind_dispatch() {
        let m = toy_manifest("image");
        let b = batch_for(&m, &[5], 0);
        assert!(matches!(b, InputBatch::Dense { .. }));
    }

    #[test]
    fn salt_changes_payload() {
        let m = toy_manifest("tokens");
        assert_ne!(batch_for(&m, &[1], 0), batch_for(&m, &[1], 99));
    }
}
