//! Model-zoo helpers: synthetic payload generation (§V "dummy inputs to
//! remove data-loading confounds") and model-facing constants.

pub mod inputgen;

/// Canonical model names in the exported repository.
pub const DISTILBERT: &str = "distilbert_mini";
pub const RESNET: &str = "resnet_tiny";
pub const SCREENER: &str = "screener";
