//! Path B — the Triton analog: per-model scheduler queue + dynamic
//! batcher thread fusing requests into bucket-sized batches dispatched
//! round-robin to an instance group.
//!
//! The batch=1 "orchestration overhead" the paper measures (Table II) is
//! the queue hop + window wait + fuse/split done here; under concurrency
//! the same machinery amortises execution across fused requests (Fig. 3).

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::batching::policy::BatcherPolicy;
use crate::batching::queue::{EnqueueError, PendingQueue};
use crate::models::inputgen;
use crate::runtime::engine::{ExecMode, ExecStats};
use crate::runtime::manifest::ModelManifest;
use crate::runtime::tensor::OutputBatch;
use crate::runtime::RuntimeError;

use super::worker::{InstancePool, Job};

/// One queued request: payload seed + reply slot.
struct Item {
    seed: u64,
    reply: mpsc::SyncSender<Result<(OutputBatch, ExecStats), RuntimeError>>,
}

/// The batched serving path for one model.
pub struct BatchedPath {
    model: String,
    queue: Arc<PendingQueue<Item>>,
    batcher: Option<JoinHandle<()>>,
}

impl BatchedPath {
    /// Start the scheduler queue + batcher thread + instance pool.
    ///
    /// `salt` must match what the client side uses for payload generation
    /// (see [`inputgen::batch_for`]).
    pub fn start(
        model_dir: PathBuf,
        policy: BatcherPolicy,
        instances: usize,
        queue_capacity: usize,
        mode: ExecMode,
        salt: u64,
    ) -> Result<Self, RuntimeError> {
        let manifest = ModelManifest::load(&model_dir)?;
        let model = manifest.name.clone();
        let pool = InstancePool::new(vec![model_dir], instances, mode)?;
        let queue: Arc<PendingQueue<Item>> = Arc::new(PendingQueue::new(queue_capacity));

        let q2 = queue.clone();
        let model2 = model.clone();
        let batcher = std::thread::Builder::new()
            .name(format!("gf-batcher-{model}"))
            .spawn(move || {
                while let Some(batch) = q2.next_batch(&policy) {
                    if batch.is_empty() {
                        continue;
                    }
                    let seeds: Vec<u64> = batch.iter().map(|i| i.seed).collect();
                    let input = inputgen::batch_for(&manifest, &seeds, salt);
                    // Execute on one instance, synchronously (the batcher
                    // resumes queueing while the worker runs only if
                    // instances > 1; dispatch + per-item reply keeps the
                    // fuse/split cost on this thread).
                    let (reply, rx) = mpsc::sync_channel(1);
                    pool.dispatch(Job { model: model2.clone(), input, reply });
                    match rx.recv() {
                        Ok(Ok((out, stats))) => {
                            let parts = out.split();
                            for (item, part) in batch.into_iter().zip(parts) {
                                let _ = item.reply.send(Ok((part, stats)));
                            }
                        }
                        Ok(Err(e)) => {
                            for item in batch {
                                let _ = item
                                    .reply
                                    .send(Err(RuntimeError::Xla(format!("batch failed: {e}"))));
                            }
                        }
                        Err(_) => {
                            for item in batch {
                                let _ = item
                                    .reply
                                    .send(Err(RuntimeError::Xla("worker dropped".into())));
                            }
                        }
                    }
                }
            })
            .expect("spawn batcher");

        Ok(BatchedPath { model, queue, batcher: Some(batcher) })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Current scheduler-queue depth (the C(x) congestion input).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    /// Submit a request (by payload seed) and block for its result.
    pub fn infer(&self, seed: u64) -> Result<(OutputBatch, ExecStats), RuntimeError> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.queue.push(Item { seed, reply }).map_err(|e| match e {
            EnqueueError::Full => RuntimeError::Backpressure(self.model.clone()),
            EnqueueError::Closed => RuntimeError::Xla("path shut down".into()),
        })?;
        rx.recv().map_err(|_| RuntimeError::Xla("reply dropped".into()))?
    }

    /// Non-blocking submit; returns the reply channel.
    pub fn submit(
        &self,
        seed: u64,
    ) -> Result<mpsc::Receiver<Result<(OutputBatch, ExecStats), RuntimeError>>, RuntimeError>
    {
        let (reply, rx) = mpsc::sync_channel(1);
        self.queue.push(Item { seed, reply }).map_err(|e| match e {
            EnqueueError::Full => RuntimeError::Backpressure(self.model.clone()),
            EnqueueError::Closed => RuntimeError::Xla("path shut down".into()),
        })?;
        Ok(rx)
    }
}

impl Drop for BatchedPath {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn root() -> Option<PathBuf> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    fn path(policy: BatcherPolicy) -> Option<BatchedPath> {
        let root = root()?;
        Some(
            BatchedPath::start(root.join("screener"), policy, 1, 64, ExecMode::Literals, 0)
                .unwrap(),
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let Some(p) = path(BatcherPolicy::immediate(4)) else { return };
        let (out, stats) = p.infer(42).unwrap();
        assert_eq!(out.batch, 1);
        assert!(stats.bucket >= 1);
    }

    #[test]
    fn concurrent_requests_get_fused() {
        // Window 50 ms, preferred 4: four concurrent submits should fuse
        // into one bucket-4 execution.
        let Some(p) = path(BatcherPolicy::new(4, vec![4], 50_000)) else { return };
        let stats: Vec<ExecStats> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|k| {
                    let p = &p;
                    s.spawn(move || p.infer(k as u64).unwrap().1)
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(
            stats.iter().any(|s| s.bucket == 4),
            "expected a fused bucket-4 execution, got {stats:?}"
        );
    }

    #[test]
    fn window_expiry_serves_lone_request() {
        let Some(p) = path(BatcherPolicy::new(4, vec![4], 10_000)) else { return };
        let t0 = std::time::Instant::now();
        let (out, _) = p.infer(7).unwrap();
        assert_eq!(out.batch, 1);
        // must have waited out the 10 ms window but not forever
        let el = t0.elapsed();
        assert!(el >= std::time::Duration::from_millis(9), "{el:?}");
        assert!(el < std::time::Duration::from_secs(2));
    }

    #[test]
    fn per_item_results_match_direct_execution() {
        // Fused batch rows must equal what a lone execution produces.
        let Some(p) = path(BatcherPolicy::new(4, vec![4], 50_000)) else { return };
        let root = root().unwrap();
        let direct = crate::pipeline::direct::DirectPath::start(
            vec![root.join("screener")],
            ExecMode::Literals,
        )
        .unwrap();
        let man = ModelManifest::load(&root.join("screener")).unwrap();

        let fused: Vec<OutputBatch> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|k| {
                    let p = &p;
                    s.spawn(move || p.infer(k as u64).unwrap().0)
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (k, part) in fused.iter().enumerate() {
            let (solo, _) = direct
                .infer("screener", inputgen::tokens_for(&man, &[k as u64], 0))
                .unwrap();
            for c in 0..2 {
                assert!(
                    (part.probs[c] - solo.probs[c]).abs() < 1e-5,
                    "item {k} class {c}: fused {} vs solo {}",
                    part.probs[c],
                    solo.probs[c]
                );
            }
        }
    }

    #[test]
    fn shutdown_drains() {
        let Some(p) = path(BatcherPolicy::immediate(4)) else { return };
        let (out, _) = p.infer(1).unwrap();
        assert_eq!(out.batch, 1);
        drop(p); // must not hang
    }
}
