//! Singleflight request coalescing and the sharded response cache.
//!
//! A duplicate of an already-in-flight request has *zero* marginal
//! utility at full marginal energy: the response cache only helps
//! **after** the first completion, so a thundering herd of identical
//! requests pays admission, queueing, and compute N times. This module
//! closes that window.
//!
//! Two pieces:
//!
//! * [`ShardedResponseCache`] — the post-completion dedup store. Same
//!   version-aware `signature`/`get`/`put`/`invalidate` semantics as
//!   [`ResponseCache`] (it *is* N of them), but with per-shard locks so
//!   the per-request cache probe never serializes the whole hot path on
//!   one global mutex.
//! * [`SingleflightTable`] — the in-flight dedup. The first arrival for
//!   a signature becomes the **leader** and runs the normal
//!   admit → schedule → execute path; concurrent duplicates attach as
//!   **followers** and block until the leader publishes its answer.
//!   Each follower that is answered this way is an engine execution
//!   that never happened — accounted as joules saved by the energy
//!   meter.
//!
//! Correctness properties (tested in `integration_serving.rs`):
//!
//! * **Leader failure propagates.** A leader that errors (or panics —
//!   the RAII [`LeaderGuard`] publishes on drop) wakes every follower
//!   with a typed error. Followers never hang on a dead leader.
//! * **Deadlines detach, not cancel.** A follower whose deadline
//!   expires leaves with `DEADLINE_EXCEEDED`; the leader (and any other
//!   follower) is unaffected.
//! * **Unload retires in-flight entries.** [`SingleflightTable::retire`]
//!   walks the same signature set cache invalidation walks, so a reload
//!   starts cold: followers parked on a dying version get
//!   `MODEL_UNAVAILABLE` instead of inheriting the dead version's
//!   answer, and post-reload arrivals start a fresh flight.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::controller::cache::{CachedResponse, ResponseCache};
use crate::router::PathKind;
use crate::runtime::RuntimeError;
use crate::telemetry::{MetricsRegistry, ShardedCounter};

/// Shard count for both the cache and the singleflight table. A power
/// of two (shard pick is a multiply + shift, no division). 16 matches
/// the gateway's reactor/worker parallelism; past that the locks are
/// effectively uncontended.
pub const SHARDS: usize = 16;

/// Fibonacci-hash a signature into a shard index. The cluster index
/// lives in the signature's low bits (see [`ResponseCache::signature`]),
/// so a plain low-bit mask would work for spreading one hot model's
/// clusters — but multiplying first also spreads the per-version base
/// bits, so many single-cluster models don't pile onto shard 0.
#[inline]
fn shard_of(sig: u64) -> usize {
    (sig.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> (64 - 4)) as usize & (SHARDS - 1)
}

/// Counter totals for `/v2/admission/stats` (per-system, unlike the
/// process-global telemetry registry).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub len: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// N independently locked [`ResponseCache`] shards behind the exact
/// keying contract of the single-mutex cache it replaces: `get`/`put`
/// route one signature to one shard, `invalidate` enumerates the
/// version's signature set and routes each member to its shard — so
/// the set of live (signature → answer) pairs after any operation
/// sequence is bit-for-bit what the global cache would hold.
#[derive(Debug)]
pub struct ShardedResponseCache {
    shards: Vec<Mutex<ResponseCache>>,
    /// Global telemetry mirrors (`gf_cache_{hits,misses,evictions}_total`),
    /// pre-resolved so the hot path never touches the registry lock.
    hits: Arc<ShardedCounter>,
    misses: Arc<ShardedCounter>,
    evictions: Arc<ShardedCounter>,
}

impl ShardedResponseCache {
    /// `capacity` is the total budget, split evenly across shards
    /// (rounded up, so the aggregate is never below the configured
    /// capacity).
    pub fn new(capacity: usize) -> Self {
        let per_shard = capacity.div_ceil(SHARDS).max(1);
        let reg = MetricsRegistry::global();
        ShardedResponseCache {
            shards: (0..SHARDS).map(|_| Mutex::new(ResponseCache::new(per_shard))).collect(),
            hits: reg.sharded_counter("gf_cache_hits_total"),
            misses: reg.sharded_counter("gf_cache_misses_total"),
            evictions: reg.sharded_counter("gf_cache_evictions_total"),
        }
    }

    pub fn get(&self, sig: u64) -> Option<CachedResponse> {
        let r = self.shards[shard_of(sig)].lock().unwrap().get(sig);
        if r.is_some() {
            self.hits.inc();
        } else {
            self.misses.inc();
        }
        r
    }

    pub fn put(&self, sig: u64, resp: CachedResponse) {
        if self.shards[shard_of(sig)].lock().unwrap().put(sig, resp) {
            self.evictions.inc();
        }
    }

    /// Drop every entry a (model, version) pair could have minted —
    /// same walk as [`ResponseCache::invalidate`], routed shard-wise.
    pub fn invalidate(&self, model: &str, version: u64, clusters: u64) -> usize {
        // Group the enumerated signatures per shard so each shard lock
        // is taken once, not once per cluster.
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); SHARDS];
        for sig in ResponseCache::signatures_of(model, version, clusters) {
            per_shard[shard_of(sig)].push(sig);
        }
        let mut removed = 0;
        for (idx, sigs) in per_shard.into_iter().enumerate() {
            if sigs.is_empty() {
                continue;
            }
            removed += self.shards[idx].lock().unwrap().remove_all(&sigs);
        }
        removed
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for shard in &self.shards {
            let c = shard.lock().unwrap();
            s.hits += c.hits();
            s.misses += c.misses();
            s.evictions += c.evictions();
            s.len += c.len();
        }
        s
    }
}

/// The slice of a leader's result that is meaningful to share with
/// followers. Per-request fields (request id, latency, J/τ) stay with
/// each caller; `joules` is deliberately absent — the leader's energy
/// was spent once and attributed once, a follower's marginal energy is
/// ~zero (that is the point).
#[derive(Debug, Clone, Copy)]
pub struct CoalescedAnswer {
    pub predicted: u32,
    pub confidence: f32,
    pub entropy: f32,
    /// The leader's engine execute seconds (shared, like a fused batch).
    pub exec_secs: f64,
    /// The bucket the leader's execution fused into.
    pub bucket: usize,
    pub path: PathKind,
}

/// What a parked follower wakes up to.
#[derive(Debug)]
pub enum FollowerVerdict {
    /// The leader published an answer.
    Ready(CoalescedAnswer),
    /// The leader failed; a reconstructed copy of its typed error.
    Failed(RuntimeError),
    /// The entry was retired by unload/drain before the leader
    /// finished — the version is gone, reloads must start cold.
    Retired,
    /// The follower's own deadline expired. The leader keeps running.
    TimedOut,
}

#[derive(Debug)]
enum FlightState {
    Pending,
    Done(Result<CoalescedAnswer, RuntimeError>),
    Retired,
}

#[derive(Debug)]
struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Arc<Self> {
        Arc::new(Flight { state: Mutex::new(FlightState::Pending), cv: Condvar::new() })
    }

    /// Publish a terminal state — unless the entry was already retired
    /// (retirement is sticky: a straggler leader completing after its
    /// version's unload must not hand the dead version's answer to a
    /// follower that was already told `Retired`).
    fn publish(&self, result: Result<CoalescedAnswer, RuntimeError>) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, FlightState::Pending) {
            *st = FlightState::Done(result);
        }
        drop(st);
        self.cv.notify_all();
    }

    fn retire(&self) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, FlightState::Pending) {
            *st = FlightState::Retired;
        }
        drop(st);
        self.cv.notify_all();
    }
}

/// `RuntimeError` carries an `std::io::Error` and so is not `Clone`;
/// followers get a structurally identical reconstruction (same variant,
/// same payload), so the wire mapping (429/503/504/...) is preserved.
fn clone_err(e: &RuntimeError) -> RuntimeError {
    match e {
        RuntimeError::Io { path, source } => RuntimeError::Io {
            path: path.clone(),
            source: std::io::Error::new(source.kind(), source.to_string()),
        },
        RuntimeError::Manifest(m) => RuntimeError::Manifest(m.clone()),
        RuntimeError::Xla(m) => RuntimeError::Xla(m.clone()),
        RuntimeError::UnknownModel(m) => RuntimeError::UnknownModel(m.clone()),
        RuntimeError::BatchTooLarge { model, requested, max } => RuntimeError::BatchTooLarge {
            model: model.clone(),
            requested: *requested,
            max: *max,
        },
        RuntimeError::InputMismatch(m) => RuntimeError::InputMismatch(m.clone()),
        RuntimeError::Backpressure(m) => RuntimeError::Backpressure(m.clone()),
        RuntimeError::DeadlineExceeded { elapsed_ms, timeout_ms } => {
            RuntimeError::DeadlineExceeded { elapsed_ms: *elapsed_ms, timeout_ms: *timeout_ms }
        }
        RuntimeError::ModelUnavailable { model } => {
            RuntimeError::ModelUnavailable { model: model.clone() }
        }
        RuntimeError::InvalidConfig { model, reason } => {
            RuntimeError::InvalidConfig { model: model.clone(), reason: reason.clone() }
        }
        RuntimeError::Lifecycle { model, reason } => {
            RuntimeError::Lifecycle { model: model.clone(), reason: reason.clone() }
        }
    }
}

/// Outcome of [`SingleflightTable::join`].
pub enum Join<'a> {
    /// First arrival: run the real path, then publish through the guard.
    Leader(LeaderGuard<'a>),
    /// Duplicate of an in-flight request: wait for the leader.
    Follower(Follower),
}

/// RAII leader handle. Exactly one exists per live flight; dropping it
/// without an explicit [`complete`](Self::complete)/[`fail`](Self::fail)
/// (early return, panic, batch abort) publishes a typed failure so
/// followers can never hang.
pub struct LeaderGuard<'a> {
    table: &'a SingleflightTable,
    sig: u64,
    flight: Arc<Flight>,
    published: bool,
}

impl LeaderGuard<'_> {
    pub fn complete(mut self, answer: CoalescedAnswer) {
        self.flight.publish(Ok(answer));
        self.published = true;
        self.table.remove(self.sig, &self.flight);
    }

    pub fn fail(mut self, err: &RuntimeError) {
        self.flight.publish(Err(clone_err(err)));
        self.published = true;
        self.table.remove(self.sig, &self.flight);
    }
}

impl Drop for LeaderGuard<'_> {
    fn drop(&mut self) {
        if !self.published {
            self.flight.publish(Err(RuntimeError::Xla(
                "coalesce leader abandoned before publishing a result".into(),
            )));
            self.table.remove(self.sig, &self.flight);
        }
    }
}

/// A parked duplicate. Holds only the flight `Arc` — dropping it (e.g.
/// after a timeout) detaches silently without disturbing the leader.
pub struct Follower {
    flight: Arc<Flight>,
}

impl Follower {
    /// Block until the leader publishes, the entry is retired, or
    /// `timeout` (None = wait as long as the leader lives — bounded,
    /// because the leader guard always publishes, even on panic).
    pub fn wait(&self, timeout: Option<Duration>) -> FollowerVerdict {
        let deadline = timeout.map(|t| std::time::Instant::now() + t);
        let mut st = self.flight.state.lock().unwrap();
        loop {
            match &*st {
                FlightState::Done(Ok(a)) => return FollowerVerdict::Ready(*a),
                FlightState::Done(Err(e)) => return FollowerVerdict::Failed(clone_err(e)),
                FlightState::Retired => return FollowerVerdict::Retired,
                FlightState::Pending => {}
            }
            match deadline {
                None => st = self.flight.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        return FollowerVerdict::TimedOut;
                    }
                    let (guard, _) = self.flight.cv.wait_timeout(st, d - now).unwrap();
                    st = guard;
                }
            }
        }
    }
}

/// Per-system coalescing totals for `/v2/admission/stats` and tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoalesceStats {
    /// Followers answered from a leader's result.
    pub coalesced: u64,
    /// Live singleflight entries right now.
    pub inflight: i64,
    /// Engine executions that actually ran (per item).
    pub executions: u64,
}

/// The singleflight table: signature → in-flight flight entry, sharded
/// like the cache so join/leave never contend on one lock.
pub struct SingleflightTable {
    shards: Vec<Mutex<HashMap<u64, Arc<Flight>>>>,
    inflight: AtomicI64,
    coalesced: AtomicU64,
    executions: AtomicU64,
    /// Global telemetry mirrors, pre-resolved.
    coalesced_total: Arc<ShardedCounter>,
    inflight_gauge: Arc<crate::telemetry::registry::Gauge>,
}

impl SingleflightTable {
    pub fn new() -> Self {
        let reg = MetricsRegistry::global();
        SingleflightTable {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            inflight: AtomicI64::new(0),
            coalesced: AtomicU64::new(0),
            executions: AtomicU64::new(0),
            coalesced_total: reg.sharded_counter("gf_coalesced_total"),
            inflight_gauge: reg.gauge("gf_coalesce_inflight"),
        }
    }

    /// Join the flight for `sig`: leader if none is live, follower
    /// otherwise.
    pub fn join(&self, sig: u64) -> Join<'_> {
        let mut map = self.shards[shard_of(sig)].lock().unwrap();
        if let Some(flight) = map.get(&sig) {
            return Join::Follower(Follower { flight: flight.clone() });
        }
        let flight = Flight::new();
        map.insert(sig, flight.clone());
        drop(map);
        let live = self.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.inflight_gauge.set(live as f64);
        Join::Leader(LeaderGuard { table: self, sig, flight, published: false })
    }

    /// Remove `sig` iff it still maps to this exact flight — a fresh
    /// flight for the same signature (post-retire reload) must not be
    /// torn down by a straggler leader's cleanup.
    fn remove(&self, sig: u64, flight: &Arc<Flight>) {
        let mut map = self.shards[shard_of(sig)].lock().unwrap();
        if map.get(&sig).is_some_and(|f| Arc::ptr_eq(f, flight)) {
            map.remove(&sig);
            drop(map);
            let live = self.inflight.fetch_sub(1, Ordering::Relaxed) - 1;
            self.inflight_gauge.set(live as f64);
        }
    }

    /// Retire every live flight in `sigs` (a version's signature set,
    /// from [`ResponseCache::signatures_of`]): parked followers wake
    /// with [`FollowerVerdict::Retired`], the entries leave the table so
    /// post-reload arrivals start fresh flights. The straggler leader's
    /// eventual publish is suppressed by retire-stickiness and its
    /// cleanup by the pointer-identity check in `remove`.
    pub fn retire(&self, sigs: impl Iterator<Item = u64>) -> usize {
        let mut retired = 0;
        for sig in sigs {
            let flight = self.shards[shard_of(sig)].lock().unwrap().remove(&sig);
            if let Some(flight) = flight {
                flight.retire();
                retired += 1;
                let live = self.inflight.fetch_sub(1, Ordering::Relaxed) - 1;
                self.inflight_gauge.set(live as f64);
            }
        }
        retired
    }

    /// Account one follower answered from a leader's result.
    pub fn note_coalesced(&self) {
        self.coalesced.fetch_add(1, Ordering::Relaxed);
        self.coalesced_total.inc();
    }

    /// Account one engine execution that actually ran (per item).
    pub fn note_execution(&self) {
        self.executions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CoalesceStats {
        CoalesceStats {
            coalesced: self.coalesced.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            executions: self.executions.load(Ordering::Relaxed),
        }
    }
}

impl Default for SingleflightTable {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn answer() -> CoalescedAnswer {
        CoalescedAnswer {
            predicted: 7,
            confidence: 0.9,
            entropy: 0.1,
            exec_secs: 0.001,
            bucket: 1,
            path: PathKind::Direct,
        }
    }

    #[test]
    fn sharded_cache_preserves_single_cache_semantics() {
        // Same operation sequence against both; observable state must
        // agree bit-for-bit.
        let sharded = ShardedResponseCache::new(1024);
        let mut single = ResponseCache::new(1024);
        for seed in 0..200u64 {
            let sig = ResponseCache::signature("m", 1, seed, 64);
            let resp = CachedResponse { label: seed as u32, confidence: 0.5 };
            sharded.put(sig, resp);
            single.put(sig, resp);
        }
        assert_eq!(sharded.len(), single.len());
        for seed in 0..200u64 {
            let sig = ResponseCache::signature("m", 1, seed, 64);
            assert_eq!(sharded.get(sig), single.get(sig));
        }
        // Version-aware invalidation removes the same count and leaves
        // other versions intact.
        for seed in 0..50u64 {
            let sig = ResponseCache::signature("m", 2, seed, 64);
            let resp = CachedResponse { label: 9, confidence: 0.5 };
            sharded.put(sig, resp);
            single.put(sig, resp);
        }
        assert_eq!(sharded.invalidate("m", 1, 64), single.invalidate("m", 1, 64));
        assert_eq!(sharded.len(), single.len());
        assert!(sharded.get(ResponseCache::signature("m", 1, 3, 64)).is_none());
        assert!(sharded.get(ResponseCache::signature("m", 2, 3, 64)).is_some());
    }

    #[test]
    fn sharded_cache_counts_hits_misses_evictions() {
        let c = ShardedResponseCache::new(16); // 1 slot per shard
        let sig = ResponseCache::signature("m", 1, 0, 4);
        assert!(c.get(sig).is_none());
        c.put(sig, CachedResponse { label: 1, confidence: 1.0 });
        assert!(c.get(sig).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        // Overflow one shard to force an eviction.
        let mut seed = 1u64;
        let target = shard_of(sig);
        let mut found = 0;
        while found < 2 {
            let other = ResponseCache::signature("m", 1, seed, ResponseCache::MAX_CLUSTERS);
            if shard_of(other) == target && other != sig {
                c.put(other, CachedResponse { label: 2, confidence: 1.0 });
                found += 1;
            }
            seed += 1;
        }
        assert!(c.stats().evictions >= 1);
    }

    #[test]
    fn leader_then_followers_share_one_answer() {
        let t = SingleflightTable::new();
        let guard = match t.join(42) {
            Join::Leader(g) => g,
            Join::Follower(_) => panic!("first join must lead"),
        };
        assert_eq!(t.stats().inflight, 1);
        let followers: Vec<Follower> = (0..3)
            .map(|_| match t.join(42) {
                Join::Follower(f) => f,
                Join::Leader(_) => panic!("duplicate join must follow"),
            })
            .collect();
        guard.complete(answer());
        assert_eq!(t.stats().inflight, 0);
        for f in followers {
            match f.wait(Some(Duration::from_secs(1))) {
                FollowerVerdict::Ready(a) => assert_eq!(a.predicted, 7),
                v => panic!("expected Ready, got {v:?}"),
            }
        }
        // The flight is gone: a new arrival leads again.
        assert!(matches!(t.join(42), Join::Leader(_)));
    }

    #[test]
    fn leader_failure_propagates_typed_error() {
        let t = SingleflightTable::new();
        let Join::Leader(guard) = t.join(1) else { panic!() };
        let Join::Follower(f) = t.join(1) else { panic!() };
        guard.fail(&RuntimeError::Backpressure("m".into()));
        match f.wait(Some(Duration::from_secs(1))) {
            FollowerVerdict::Failed(RuntimeError::Backpressure(m)) => assert_eq!(m, "m"),
            v => panic!("expected Backpressure, got {v:?}"),
        }
    }

    #[test]
    fn dropped_leader_publishes_instead_of_hanging_followers() {
        let t = SingleflightTable::new();
        let Join::Leader(guard) = t.join(1) else { panic!() };
        let Join::Follower(f) = t.join(1) else { panic!() };
        drop(guard); // early return / panic path
        match f.wait(Some(Duration::from_secs(1))) {
            FollowerVerdict::Failed(RuntimeError::Xla(_)) => {}
            v => panic!("expected abandoned-leader error, got {v:?}"),
        }
        assert_eq!(t.stats().inflight, 0);
    }

    #[test]
    fn follower_timeout_detaches_without_cancelling_leader() {
        let t = SingleflightTable::new();
        let Join::Leader(guard) = t.join(1) else { panic!() };
        let Join::Follower(f) = t.join(1) else { panic!() };
        assert!(matches!(f.wait(Some(Duration::from_millis(5))), FollowerVerdict::TimedOut));
        drop(f);
        // Leader unaffected: a later follower still gets the answer.
        let Join::Follower(f2) = t.join(1) else { panic!() };
        guard.complete(answer());
        assert!(matches!(f2.wait(Some(Duration::from_secs(1))), FollowerVerdict::Ready(_)));
    }

    #[test]
    fn retire_wakes_followers_and_suppresses_straggler_publish() {
        let t = SingleflightTable::new();
        let sig = ResponseCache::signature("m", 1, 0, 4);
        let Join::Leader(guard) = t.join(sig) else { panic!() };
        let Join::Follower(f) = t.join(sig) else { panic!() };
        assert_eq!(t.retire(ResponseCache::signatures_of("m", 1, 4)), 1);
        assert!(matches!(f.wait(Some(Duration::from_secs(1))), FollowerVerdict::Retired));
        // Post-retire arrivals start a fresh flight (reload starts cold) ...
        let Join::Leader(fresh) = t.join(sig) else { panic!("expected fresh leader") };
        let Join::Follower(f2) = t.join(sig) else { panic!() };
        // ... and the straggler's publish must not leak into it: even
        // after the old leader completes, the fresh flight is pending.
        guard.complete(answer());
        assert!(matches!(f2.wait(Some(Duration::from_millis(5))), FollowerVerdict::TimedOut));
        fresh.complete(answer());
        assert_eq!(t.stats().inflight, 0);
    }

    #[test]
    fn concurrent_joins_elect_exactly_one_leader() {
        let t = SingleflightTable::new();
        let leaders = AtomicUsize::new(0);
        let ready = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| match t.join(99) {
                    Join::Leader(g) => {
                        leaders.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(10));
                        g.complete(answer());
                    }
                    Join::Follower(f) => {
                        if matches!(
                            f.wait(Some(Duration::from_secs(5))),
                            FollowerVerdict::Ready(_)
                        ) {
                            ready.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                });
            }
        });
        // With staggered joins some threads may arrive after the first
        // flight closed and lead a second one — but a single sleep-held
        // flight window catches most, and every follower was answered.
        let l = leaders.load(Ordering::SeqCst);
        let r = ready.load(Ordering::SeqCst);
        assert!(l >= 1);
        assert_eq!(l + r, 8, "every thread either led or was answered");
    }
}
