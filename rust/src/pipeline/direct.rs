//! Path A — the FastAPI + ONNX Runtime analog: no queueing, no fusion;
//! a request becomes an immediate batch-1 execution on a dedicated
//! engine. With `ExecMode::DeviceBuffers` the per-request H2D traffic is
//! just the input tensor (the ORT I/O-binding discipline of §III-B).

use std::path::PathBuf;

use crate::runtime::engine::{ExecMode, ExecStats};
use crate::runtime::tensor::{InputBatch, OutputBatch};
use crate::runtime::RuntimeError;

use super::worker::InstancePool;

/// The direct serving path.
pub struct DirectPath {
    pool: InstancePool,
}

impl DirectPath {
    /// `model_dirs`: every model this path can serve (it owns one engine
    /// that loads them all — the "local ORT session" of the paper).
    pub fn start(model_dirs: Vec<PathBuf>, mode: ExecMode) -> Result<Self, RuntimeError> {
        Ok(DirectPath { pool: InstancePool::new(model_dirs, 1, mode)? })
    }

    /// Execute one batch synchronously (callers typically pass batch=1;
    /// Table II's sequential 100-iteration loop).
    pub fn infer(
        &self,
        model: &str,
        input: InputBatch,
    ) -> Result<(OutputBatch, ExecStats), RuntimeError> {
        self.pool.execute(model, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inputgen;
    use std::path::Path;

    fn root() -> Option<PathBuf> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    #[test]
    fn serves_multiple_models_from_one_engine() {
        let Some(root) = root() else { return };
        let p = DirectPath::start(
            vec![root.join("screener"), root.join("distilbert_mini")],
            ExecMode::Literals,
        )
        .unwrap();
        let ms = crate::runtime::ModelManifest::load(&root.join("screener")).unwrap();
        let mb = crate::runtime::ModelManifest::load(&root.join("distilbert_mini")).unwrap();
        let (o1, s1) = p.infer("screener", inputgen::tokens_for(&ms, &[1], 0)).unwrap();
        let (o2, _) = p.infer("distilbert_mini", inputgen::tokens_for(&mb, &[1], 0)).unwrap();
        assert_eq!(o1.batch, 1);
        assert_eq!(o2.batch, 1);
        assert_eq!(s1.bucket, 1, "direct path executes the 1-bucket");
    }
}
