//! The live serving pipeline: engine worker threads, the dual serving
//! paths, and the closed-loop system that composes controller → router →
//! path → telemetry.
//!
//! Thread topology (PjRtClient is not Send, so engines are thread-owned):
//!
//! ```text
//!  clients ──submit()──► ServingSystem
//!      │ controller (J(x) ≥ τ(t)?) ── skip ──► ResponseCache
//!      │ admit
//!      ├─ Path A (direct):   job channel ─► [instance 0: Engine]
//!      └─ Path B (batched):  PendingQueue ─► batcher thread ─►
//!                            round-robin ─► [instance i: Engine] ─► split
//! ```
//!
//! Every reply carries exec time + energy attribution; the meter EWMA and
//! queue depth feed back into the next admission decision — the paper's
//! closed loop (Fig. 2).
//!
//! Paths are owned per **model version**: [`system::VersionHandle`]
//! owns a replica set of N engine replicas (each one direct engine +
//! batched path), scheduled power-of-two-choices and scaled by the
//! control plane's per-version `replica_scaler` loop; versions are
//! attached and detached at runtime by the `/v2/repository` lifecycle
//! API (see [`crate::runtime::registry`]) and replicas spawn/retire
//! through the same lifecycle executor (docs/SCALING.md).

pub mod batched;
pub mod coalesce;
pub mod direct;
pub mod system;
pub mod worker;

pub use batched::BatchedPath;
pub use coalesce::{ShardedResponseCache, SingleflightTable};
pub use direct::DirectPath;
pub use system::{
    p2c_indices, InferResult, ModelControl, Served, ServingSystem, SubmitOptions, SystemConfig,
};
pub use worker::{InstancePool, Job};
