//! The live serving pipeline: engine worker threads, the dual serving
//! paths, and the closed-loop system that composes controller → router →
//! path → telemetry.
//!
//! Thread topology (PjRtClient is not Send, so engines are thread-owned):
//!
//! ```text
//!  clients ──submit()──► ServingSystem
//!      │ controller (J(x) ≥ τ(t)?) ── skip ──► ResponseCache
//!      │ admit
//!      ├─ Path A (direct):   job channel ─► [instance 0: Engine]
//!      └─ Path B (batched):  PendingQueue ─► batcher thread ─►
//!                            round-robin ─► [instance i: Engine] ─► split
//! ```
//!
//! Every reply carries exec time + energy attribution; the meter EWMA and
//! queue depth feed back into the next admission decision — the paper's
//! closed loop (Fig. 2).
//!
//! Paths are owned per **model version**: [`system::VersionHandle`]
//! bundles one version's direct engine + batched path, attached and
//! detached at runtime by the `/v2/repository` lifecycle API (see
//! [`crate::runtime::registry`]).

pub mod batched;
pub mod direct;
pub mod system;
pub mod worker;

pub use batched::BatchedPath;
pub use direct::DirectPath;
pub use system::{InferResult, ModelControl, ServingSystem, SubmitOptions, SystemConfig};
pub use worker::{InstancePool, Job};
