//! The closed-loop serving system (paper Fig. 2): controller in front of
//! the dual-path stack, with energy/latency feedback wired back into the
//! next admission decision.
//!
//! Since the lifecycle redesign the system serves from an atomically
//! swapped **snapshot** of per-model, per-version handles instead of a
//! boot-time repository scan: [`crate::runtime::registry::ModelRegistry`]
//! owns the `Unloaded → Loading → Ready → Unloading` state machines and
//! this module owns the resources — each `Ready` version gets its own
//! direct engine and (screener excepted) batched path, attached by
//! [`ServingSystem::load_model`] and detached by
//! [`ServingSystem::unload_model`] without restarting the server. The
//! hot path resolves `Arc<VersionHandle>`s from the snapshot (one brief
//! uncontended read-lock, never held across inference); in-flight
//! requests keep their handle's engines alive through the `Arc` itself,
//! so an unload drains naturally — new requests see a typed
//! [`RuntimeError::ModelUnavailable`] (HTTP 503) the moment the swap
//! lands.
//!
//! Since the async-lifecycle redesign the engine work itself runs on a
//! [`LifecycleExecutor`]: `load_model_async` marks the target versions
//! `Loading` and returns immediately (HTTP 202) while executor threads
//! spawn the engines and swap the snapshot; `unload_model_async` swaps
//! the version out inline (new requests 503 at once) and hands the
//! bounded Arc-refcount drain to the executor. Same-model jobs
//! serialise, different models load concurrently, and an unload of a
//! version whose load is still *queued* cancels the job outright. The
//! synchronous `load_model` / `unload_model` wrappers enqueue the same
//! jobs and block on their completion (boot, `?wait=true`, tests).
//!
//! Beyond the per-request loop, the system can boot a
//! [`ControlPlane`](crate::control::ControlPlane) from
//! [`ControlPlaneConfig`]: a background tick that reads the
//! [`WindowedMetrics`] aggregator and drives the adaptive knobs — τ
//! corrections, batcher queue-delay windows, the router's QPS threshold,
//! and one energy-budget pacer **per loaded batched path**
//! (`energy_budget.<model>/<version>`), each attached and detached with
//! its version.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::batching::policy::BatcherPolicy;
use crate::configsys::ModelConfig;
use crate::control::law::{Aimd, BudgetPacer, SetpointTracker};
use crate::control::{
    Adaptive, ControlLoop, ControlPlane, ControlPlaneConfig, EnergyWindow, WindowedMetrics,
};
use crate::controller::cache::{CachedResponse, ResponseCache};
use crate::controller::cost::CostInputs;
use crate::controller::{AdmissionController, ControllerConfig, Decision};
use crate::energy::meter::{EnergyMeter, MeterMode};
use crate::energy::profile::DeviceProfile;
use crate::models;
use crate::models::inputgen;
use crate::router::{PathKind, RoutePolicy, Router};
use crate::runtime::engine::{ExecMode, ExecStats};
use crate::runtime::lifecycle::{JobKind, JobSpec, LifecycleExecutor};
use crate::runtime::manifest::ModelManifest;
use crate::runtime::registry::{LoadStats, ModelRegistry, VersionInfo};
use crate::runtime::tensor::OutputBatch;
use crate::runtime::RuntimeError;
use crate::stats::LatencyHistogram;
use crate::util::{Clock, SystemClock};
use crate::workload::stream::{Priority, Request};

use super::batched::BatchedPath;
use super::direct::DirectPath;

/// How long an unload waits for in-flight requests to finish before
/// letting the last request thread tear the paths down on its own.
const UNLOAD_DRAIN_TIMEOUT: Duration = Duration::from_secs(5);

/// Lifecycle-executor sizing: enough workers that several models load
/// concurrently, a queue bound that refuses runaway operator scripts
/// with `BACKPRESSURE` instead of buffering them forever.
const LIFECYCLE_WORKERS: usize = 4;
const LIFECYCLE_QUEUE_CAP: usize = 64;

/// Model-control mode (Triton's `--model-control-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ModelControl {
    /// Load every model's policy versions at boot; the repository API
    /// can still swap versions afterwards.
    #[default]
    None,
    /// Start with nothing loaded; models serve only after an explicit
    /// `POST /v2/repository/models/{name}/load`.
    Explicit,
}

impl ModelControl {
    pub fn parse(s: &str) -> Option<ModelControl> {
        match s {
            "none" => Some(ModelControl::None),
            "explicit" => Some(ModelControl::Explicit),
            _ => None,
        }
    }
}

/// System configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub repo_root: PathBuf,
    pub exec_mode: ExecMode,
    /// Device whose power profile attributes energy.
    pub device: DeviceProfile,
    pub meter_mode: MeterMode,
    /// None = open loop (no admission control).
    pub controller: Option<ControllerConfig>,
    /// Scheduler queue capacity per model (C(x) normaliser).
    pub queue_capacity: usize,
    /// Latency SLO for the congestion proxy (s).
    pub slo_latency: f64,
    /// Payload salt (must match trace generation).
    pub salt: u64,
    /// Response-cache capacity and seed-cluster count.
    pub cache_capacity: usize,
    pub cache_clusters: u64,
    /// Policy for [`ServingSystem::submit_auto`]'s shared router.
    pub route: RoutePolicy,
    /// None = no background control loops (all knobs stay static).
    pub control: Option<ControlPlaneConfig>,
    /// Whether models load at boot or only via the repository API.
    pub model_control: ModelControl,
    /// Honour test hooks in the repository (the `slow_load_ms` file
    /// that stalls an engine spawn). Off by default so a stray file in
    /// a production repo can never slow real loads; lifecycle tests
    /// opt in.
    pub load_hooks: bool,
}

impl SystemConfig {
    pub fn new(repo_root: PathBuf) -> Self {
        SystemConfig {
            repo_root,
            exec_mode: ExecMode::DeviceBuffers,
            device: DeviceProfile::rtx4000_ada(),
            meter_mode: MeterMode::SimulatedFlops,
            controller: None,
            queue_capacity: 64,
            slo_latency: 0.25,
            salt: 0,
            cache_capacity: 4096,
            cache_clusters: 256,
            route: RoutePolicy::adaptive(50.0),
            control: None,
            model_control: ModelControl::None,
            load_hooks: false,
        }
    }

    pub fn with_controller(mut self, cfg: ControllerConfig) -> Self {
        self.controller = Some(cfg);
        self
    }

    pub fn with_route(mut self, route: RoutePolicy) -> Self {
        self.route = route;
        self
    }

    pub fn with_control(mut self, cfg: ControlPlaneConfig) -> Self {
        self.control = Some(cfg);
        self
    }

    pub fn with_model_control(mut self, mc: ModelControl) -> Self {
        self.model_control = mc;
        self
    }

    pub fn with_load_hooks(mut self) -> Self {
        self.load_hooks = true;
        self
    }
}

/// Per-submission options the v2 protocol carries (deadline, priority,
/// target version). The zero value (`Default`) reproduces plain
/// `submit` semantics on the default (highest ready) version.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SubmitOptions {
    /// Absolute deadline on the system clock ([`ServingSystem::clock`]
    /// seconds). Expired at entry → the request is refused without work;
    /// expired at completion → the result is discarded as
    /// [`RuntimeError::DeadlineExceeded`] (the client has given up).
    pub deadline: Option<f64>,
    /// Milliseconds the caller granted (kept for the error payload).
    pub timeout_ms: u64,
    pub priority: Priority,
    /// Pin a specific model version (`/v2/models/{m}/versions/{v}/infer`);
    /// None = the highest ready version.
    pub version: Option<u64>,
}

impl SubmitOptions {
    /// Build from a relative timeout: deadline = now + timeout_ms.
    pub fn with_timeout(now: f64, timeout_ms: u64, priority: Priority) -> Self {
        SubmitOptions {
            deadline: Some(now + timeout_ms as f64 / 1e3),
            timeout_ms,
            priority,
            version: None,
        }
    }
}

/// Result of serving one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferResult {
    pub request_id: u64,
    pub predicted: u32,
    pub confidence: f32,
    pub entropy: f32,
    /// End-to-end seconds inside the system.
    pub latency_secs: f64,
    /// Engine execute seconds (shared across the fused batch).
    pub exec_secs: f64,
    /// Bucket the execution used (0 for cache answers).
    pub bucket: usize,
    /// Joules attributed to this request.
    pub joules: f64,
    pub path: PathKind,
    /// J(x) and τ(t) at decision time (NaN when open loop).
    pub j: f64,
    pub tau: f64,
}

/// One `Ready` model version's attached serving resources. In-flight
/// requests hold an `Arc` clone, so the engines and batcher threads
/// survive an unload until the last request completes — that `Arc`
/// refcount *is* the drain mechanism.
pub struct VersionHandle {
    model: String,
    version: u64,
    manifest: ModelManifest,
    config: Option<ModelConfig>,
    direct: DirectPath,
    batched: Option<BatchedPath>,
    stats: LoadStats,
    /// Batcher queue-delay handle, kept for control-loop attach.
    delay_handle: Option<Adaptive<u64>>,
    /// Per-model windowed energy (feeds the `energy_budget.<model>/<v>`
    /// pacer) and its freshness counter.
    energy: Mutex<EnergyWindow>,
    energy_events: AtomicU64,
    /// τ bias the per-model pacer writes; read per decision.
    energy_correction: Adaptive<f64>,
    /// Set when the version leaves the serving snapshot (unload).
    /// In-flight stragglers check it before writing the response cache:
    /// a request that outlives the drain timeout must not re-populate
    /// entries the unload just invalidated (a reload would inherit
    /// them).
    retired: AtomicBool,
}

impl VersionHandle {
    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    pub fn config(&self) -> Option<&ModelConfig> {
        self.config.as_ref()
    }

    pub fn load_stats(&self) -> LoadStats {
        self.stats
    }

    pub fn has_batched(&self) -> bool {
        self.batched.is_some()
    }

    /// Current scheduler-queue depth (0 for batcher-less models).
    pub fn queue_depth(&self) -> usize {
        self.batched.as_ref().map(|b| b.queue_depth()).unwrap_or(0)
    }
}

/// Immutable serving view: model → version → handle. Swapped whole on
/// every load/unload; readers clone the `Arc` once and never block a
/// writer during inference.
#[derive(Default, Clone)]
struct Snapshot {
    models: BTreeMap<String, BTreeMap<u64, Arc<VersionHandle>>>,
}

impl Snapshot {
    fn resolve(&self, model: &str, version: Option<u64>) -> Option<Arc<VersionHandle>> {
        let versions = self.models.get(model)?;
        match version {
            Some(v) => versions.get(&v).cloned(),
            // Default version = highest ready (Triton's "latest").
            None => versions.values().next_back().cloned(),
        }
    }
}

/// The deadline error, with elapsed measured from when the budget
/// started (deadline − timeout), not from the current call's entry: a
/// later batch item that arrives already expired must not report
/// "0 ms elapsed".
fn deadline_error(opts: &SubmitOptions, fallback_start: f64, now: f64) -> RuntimeError {
    let start = opts
        .deadline
        .map(|d| d - opts.timeout_ms as f64 / 1e3)
        .unwrap_or(fallback_start);
    RuntimeError::DeadlineExceeded {
        elapsed_ms: ((now - start).max(0.0) * 1e3).round() as u64,
        timeout_ms: opts.timeout_ms,
    }
}

/// Freshness-gated windowed-p95 signal: NaN (hold the loop output)
/// until new events landed since the previous tick — count-bounded
/// windows would otherwise replay the last regime forever after
/// traffic stops.
///
/// `events` picks the freshness counter and `p95` the quantile to read,
/// so each loop can watch the population it actually steers: the router
/// threshold moves traffic off the *direct* path, so it must see the
/// direct p95 (a blended signal lets the batched tail push the
/// threshold down, starving the direct path it was protecting); the
/// batch-delay loop shapes the *batched* path only.
fn fresh_p95_signal(
    metrics: &Arc<WindowedMetrics>,
    events: fn(&WindowedMetrics) -> u64,
    p95: fn(&WindowedMetrics) -> f64,
) -> Box<dyn FnMut() -> f64 + Send> {
    let m = metrics.clone();
    let mut last_events = 0u64;
    Box::new(move || {
        let ev = events(m.as_ref());
        if ev == last_events {
            return f64::NAN;
        }
        last_events = ev;
        let p95 = p95(m.as_ref());
        if p95 > 0.0 {
            p95
        } else {
            f64::NAN
        }
    })
}

/// Direct-path p95, fresh while direct completions keep landing.
fn fresh_p95_direct(metrics: &Arc<WindowedMetrics>) -> Box<dyn FnMut() -> f64 + Send> {
    fresh_p95_signal(metrics, WindowedMetrics::events_direct, |m| m.snapshot().p95_direct)
}

/// Batched-path p95, fresh while batched completions keep landing.
fn fresh_p95_batched(metrics: &Arc<WindowedMetrics>) -> Box<dyn FnMut() -> f64 + Send> {
    fresh_p95_signal(metrics, WindowedMetrics::events_batched, |m| m.snapshot().p95_batched)
}

/// Outcome of the per-request admission pass (screener → J(x) vs τ(t)).
enum AdmitOutcome {
    /// Execute on the serving path; carry (j, τ) for the result.
    Execute { j: f64, tau: f64 },
    /// Answered without inference (cache / screener argmax).
    Skip { result: InferResult },
}

/// State the lifecycle executor's job closures need: everything a load
/// or unload touches, shared (`Arc`) between the request path and the
/// executor threads. Serving-path-only state (controller, router,
/// latency histogram, clock) stays on [`ServingSystem`] itself.
struct SystemShared {
    /// Declared first so the ticker thread stops before paths shut down.
    plane: Option<ControlPlane>,
    registry: ModelRegistry,
    snapshot: RwLock<Arc<Snapshot>>,
    meter: Arc<EnergyMeter>,
    cache: Mutex<ResponseCache>,
    metrics: Arc<WindowedMetrics>,
    cfg: SystemConfig,
}

/// The full serving system.
pub struct ServingSystem {
    /// Declared first: dropping the executor cancels queued jobs and
    /// joins the workers before the shared state they capture unwinds.
    executor: LifecycleExecutor,
    shared: Arc<SystemShared>,
    latency: Mutex<LatencyHistogram>,
    controller: Option<Arc<Mutex<AdmissionController>>>,
    router: Mutex<Router>,
    clock: SystemClock,
}

impl ServingSystem {
    /// Boot the system: scan the repository into the registry, start the
    /// global control loops, then (unless `ModelControl::Explicit`) load
    /// every model's policy versions — concurrently, through the
    /// lifecycle executor, so boot costs ~the slowest model rather than
    /// the sum. A boot-time load failure aborts the start — a half-up
    /// default-mode server would silently 503.
    pub fn start(cfg: SystemConfig) -> Result<Self, RuntimeError> {
        let registry = ModelRegistry::scan(&cfg.repo_root)?;
        let meter = Arc::new(EnergyMeter::new(cfg.device.clone(), cfg.meter_mode, 16.0));
        let controller = cfg
            .controller
            .clone()
            .map(|c| Arc::new(Mutex::new(AdmissionController::new(c))));
        let metrics = Arc::new(WindowedMetrics::new(64, 256));
        let router = Router::new(cfg.route.clone());
        let plane = cfg
            .control
            .as_ref()
            .and_then(|pc| Self::wire_global_loops(pc, &controller, &metrics, &router));
        let shared = Arc::new(SystemShared {
            plane,
            registry,
            snapshot: RwLock::new(Arc::new(Snapshot::default())),
            meter,
            cache: Mutex::new(ResponseCache::new(cfg.cache_capacity)),
            metrics,
            cfg,
        });
        let sys = ServingSystem {
            executor: LifecycleExecutor::start(LIFECYCLE_WORKERS, LIFECYCLE_QUEUE_CAP),
            shared,
            latency: Mutex::new(LatencyHistogram::for_latency()),
            controller,
            router: Mutex::new(router),
            clock: SystemClock::new(),
        };
        if sys.shared.cfg.model_control == ModelControl::None {
            // Fan every model's load onto the executor, then wait for
            // all of them — cross-model concurrency at boot. A
            // repository with more loadable versions than the job-queue
            // bound must still boot: on backpressure, drain what is in
            // flight to empty the queue, then retry the model (a lone
            // model with more versions than the whole queue is the one
            // shape that still fails).
            let mut pending = Vec::new();
            for name in sys.model_names() {
                let rxs = match sys.spawn_load_jobs(&name, None) {
                    Ok((_, rxs)) => rxs,
                    Err(RuntimeError::Backpressure(_)) => {
                        wait_boot_loads(std::mem::take(&mut pending))?;
                        let (_, rxs) = sys.spawn_load_jobs(&name, None)?;
                        rxs
                    }
                    Err(e) => return Err(e),
                };
                pending.push((name, rxs));
            }
            wait_boot_loads(pending)?;
        }
        Ok(sys)
    }

    /// Build and start the background control plane with the *global*
    /// loops (τ servo, router threshold). Per-model loops (batcher
    /// AIMD, energy-budget pacers) attach per loaded version — the
    /// plane ticks even while empty so later loads find it running.
    fn wire_global_loops(
        pc: &ControlPlaneConfig,
        controller: &Option<Arc<Mutex<AdmissionController>>>,
        metrics: &Arc<WindowedMetrics>,
        router: &Router,
    ) -> Option<ControlPlane> {
        if !pc.any_enabled() {
            return None;
        }
        let mut plane = ControlPlane::new();

        // Adaptive τ: windowed admission rate → τ correction.
        if let (Some(tc), Some(ctrl)) = (&pc.adaptive_tau, controller) {
            let handle = ctrl.lock().unwrap().rate_correction_handle();
            let ctrl = ctrl.clone();
            let mut last = (0u64, 0u64); // (admitted, total) at previous tick
            let signal = move || {
                let s = ctrl.lock().unwrap().stats();
                let (d_admitted, d_total) = (s.admitted - last.0, s.total() - last.1);
                if d_total == 0 {
                    return f64::NAN; // no decisions since the last tick
                }
                last = (s.admitted, s.total());
                d_admitted as f64 / d_total as f64
            };
            let law = SetpointTracker::new(
                0.0,
                tc.target_admit_rate,
                tc.gain,
                -tc.max_correction,
                tc.max_correction,
            );
            plane.add_loop(ControlLoop::new(
                "tau_correction",
                Box::new(law),
                Box::new(signal),
                Box::new(move |v| handle.set(v)),
            ));
        }

        // AIMD router threshold: SLO pressure shifts the direct/batched
        // split toward the batched path (threshold drops).
        if let Some(rc) = &pc.adaptive_router {
            // +inf threshold means a pinned RoutePolicy: nothing to tune.
            if router.qps_threshold().is_finite() {
                let initial = router.qps_threshold().clamp(rc.min_qps, rc.max_qps);
                let law = Aimd::new(
                    initial,
                    rc.slo_p95_secs,
                    rc.increase_qps,
                    rc.decrease,
                    rc.min_qps,
                    rc.max_qps,
                );
                let handle = router.qps_threshold_handle();
                plane.add_loop(ControlLoop::new(
                    "router_qps_threshold",
                    Box::new(law),
                    fresh_p95_direct(metrics),
                    Box::new(move |v| handle.set(v)),
                ));
            }
        }

        plane.start(Duration::from_secs_f64(pc.tick_secs.max(1e-3)));
        Some(plane)
    }
}

/// Lifecycle resource management: runs on executor threads (via the job
/// closures) and at boot. Everything here must be reachable through the
/// `Arc<SystemShared>` alone.
impl SystemShared {
    /// Attach the per-version control loops (batcher-delay AIMD, the
    /// per-model energy-budget pacer) for a freshly loaded handle.
    fn attach_loops(&self, handle: &Arc<VersionHandle>) {
        let (Some(plane), Some(pc)) = (&self.plane, &self.cfg.control) else {
            return;
        };
        let key = format!("{}/{}", handle.model, handle.version);

        // AIMD batch delay, seeded from *this* version's configured
        // window (probe ceiling 4× the configured window, capped by
        // max_us); models configured with no window are left alone —
        // adaptivity must not introduce delay where the operator asked
        // for none.
        if let (Some(dc), Some(delay)) = (&pc.adaptive_batch_delay, &handle.delay_handle) {
            let configured = delay.get();
            if configured > 0 {
                let max_us = dc.max_us.min(configured.saturating_mul(4)).max(dc.min_us);
                let initial = configured.clamp(dc.min_us, max_us);
                let law = Aimd::new(
                    initial as f64,
                    dc.slo_p95_secs,
                    dc.increase_us as f64,
                    dc.decrease,
                    dc.min_us as f64,
                    max_us as f64,
                );
                let h = delay.clone();
                plane.add_loop(ControlLoop::new(
                    format!("batch_delay_us.{key}"),
                    Box::new(law),
                    fresh_p95_batched(&self.metrics),
                    Box::new(move |v| h.set(v.max(0.0).round() as u64)),
                ));
            }
        }

        // One BudgetPacer per batched path (PR-4: replaces the single
        // global pacer): watches this model's windowed watts, writes
        // this model's τ bias. A stale window means the model ran
        // nothing ⇒ report ~0 W so the correction decays while idle.
        if let Some(ec) = &pc.energy_budget {
            if handle.batched.is_some() {
                let law = BudgetPacer::new(ec.budget_watts, ec.gain, 0.0, ec.max_correction);
                let sig = handle.clone();
                let mut last_events = 0u64;
                let signal = move || {
                    let ev = sig.energy_events.load(Ordering::Relaxed);
                    if ev == last_events {
                        return 0.0;
                    }
                    last_events = ev;
                    sig.energy.lock().unwrap().watts()
                };
                let out = handle.energy_correction.handle();
                plane.add_loop(ControlLoop::new(
                    format!("energy_budget.{key}"),
                    Box::new(law),
                    Box::new(signal),
                    Box::new(move |v| out.set(v)),
                ));
            }
        }
    }

    fn detach_loops(&self, handle: &VersionHandle) {
        if let Some(plane) = &self.plane {
            let key = format!("{}/{}", handle.model, handle.version);
            plane.remove_loop(&format!("batch_delay_us.{key}"));
            plane.remove_loop(&format!("energy_budget.{key}"));
        }
    }

    /// Remove one version from the serving snapshot: the moment the swap
    /// lands, new requests get [`RuntimeError::ModelUnavailable`] (503).
    fn swap_out(&self, model: &str, version: u64) -> Option<Arc<VersionHandle>> {
        let mut guard = self.snapshot.write().unwrap();
        let mut next = (**guard).clone();
        let h = next.models.get_mut(model).and_then(|m| m.remove(&version));
        if next.models.get(model).is_some_and(|m| m.is_empty()) {
            next.models.remove(model);
        }
        *guard = Arc::new(next);
        if let Some(h) = &h {
            // From here on, in-flight stragglers must not write the
            // response cache — see `VersionHandle::retired`.
            h.retired.store(true, Ordering::SeqCst);
        }
        h
    }

    /// The slow half of an unload (runs on an executor thread): wait —
    /// bounded — for in-flight requests to drain, drop the engines,
    /// complete the registry transition, and invalidate the dead
    /// version's response-cache entries so a reload starts cold.
    fn drain_and_finish(&self, model: &str, version: u64, handle: Option<Arc<VersionHandle>>) {
        if let Some(handle) = handle {
            // In-flight requests hold their own Arc clone; once the
            // count reaches 1 the engines are idle and this drop joins
            // their threads. Past the timeout the last request thread
            // pays the teardown instead — either way no new request can
            // reach the version (the snapshot swap already landed).
            let deadline = Instant::now() + UNLOAD_DRAIN_TIMEOUT;
            while Arc::strong_count(&handle) > 1 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            drop(handle);
        }
        self.registry.finish_unload(model, version);
        self.cache.lock().unwrap().invalidate(model, version, self.cfg.cache_clusters);
    }

    /// Spin up one version's engines and swap it into the snapshot.
    fn attach_version(&self, model: &str, info: &VersionInfo) -> Result<(), RuntimeError> {
        let t0 = Instant::now();
        // Test/bench hook (opt-in via `SystemConfig::load_hooks`): a
        // `slow_load_ms` file in the version directory stalls the
        // engine spawn — how the lifecycle integration tests prove
        // loads never block the gateway without needing a genuinely
        // slow model. Ignored unless explicitly enabled, so a stray
        // file in a production repository can never slow real loads.
        if self.cfg.load_hooks {
            if let Ok(text) = std::fs::read_to_string(info.dir.join("slow_load_ms")) {
                if let Ok(ms) = text.trim().parse::<u64>() {
                    std::thread::sleep(Duration::from_millis(ms.min(30_000)));
                }
            }
        }
        let manifest = ModelManifest::load(&info.dir)?;
        if manifest.name != model {
            return Err(RuntimeError::Manifest(format!(
                "{}: manifest name {:?} does not match model {:?}",
                info.dir.display(),
                manifest.name,
                model
            )));
        }
        let config = self.registry.config(model)?;
        if let Some(c) = &config {
            // Shape/dtype discipline (the paper's §VII "practical
            // gotchas"), enforced at load so a bad config is a typed
            // 400, not a runtime surprise.
            c.validate().map_err(|e| RuntimeError::InvalidConfig {
                model: model.to_string(),
                reason: e.to_string(),
            })?;
            if manifest.bucket_for(c.max_batch_size).is_none() {
                return Err(RuntimeError::InvalidConfig {
                    model: model.to_string(),
                    reason: format!(
                        "config max_batch_size {} exceeds buckets {:?}",
                        c.max_batch_size, manifest.batch_buckets
                    ),
                });
            }
            if let Some(inp) = c.inputs.first() {
                if inp.dims != manifest.input_shape {
                    return Err(RuntimeError::InvalidConfig {
                        model: model.to_string(),
                        reason: format!(
                            "config dims {:?} != manifest {:?}",
                            inp.dims, manifest.input_shape
                        ),
                    });
                }
            }
        }

        let direct = DirectPath::start(vec![info.dir.clone()], self.cfg.exec_mode)?;
        let mut delay_handle = None;
        let batched = if model == models::SCREENER {
            None // the screener serves inline on its direct engine
        } else {
            let policy = config
                .as_ref()
                .map(BatcherPolicy::from_config)
                .unwrap_or_else(|| BatcherPolicy::immediate(manifest.max_bucket()));
            delay_handle = Some(policy.delay_handle());
            let instances = config.as_ref().map(|c| c.total_instances()).unwrap_or(1);
            Some(BatchedPath::start(
                info.dir.clone(),
                policy,
                instances,
                self.cfg.queue_capacity,
                self.cfg.exec_mode,
                self.cfg.salt,
            )?)
        };

        let load_secs = t0.elapsed().as_secs_f64();
        let stats = LoadStats {
            load_secs,
            weight_bytes: manifest.weights_bytes() as u64,
            // Estimated compile + weight-transfer energy: full draw on
            // the metered device over the load interval.
            est_load_joules: self.meter.profile().power_at(1.0) * load_secs,
        };
        let handle = Arc::new(VersionHandle {
            model: model.to_string(),
            version: info.version,
            manifest,
            config,
            direct,
            batched,
            stats,
            delay_handle,
            energy: Mutex::new(EnergyWindow::new(64)),
            energy_events: AtomicU64::new(0),
            energy_correction: Adaptive::new(0.0),
            retired: AtomicBool::new(false),
        });
        {
            let mut guard = self.snapshot.write().unwrap();
            let mut next = (**guard).clone();
            next.models
                .entry(model.to_string())
                .or_default()
                .insert(info.version, handle.clone());
            *guard = Arc::new(next);
        }
        self.attach_loops(&handle);
        self.registry.finish_load(model, info.version, Ok(stats));
        Ok(())
    }
}

/// Wait for a batch of boot-time load jobs; the first failure aborts
/// the boot (a half-up default-mode server would silently 503).
#[allow(clippy::type_complexity)]
fn wait_boot_loads(
    pending: Vec<(String, Vec<mpsc::Receiver<Result<u64, RuntimeError>>>)>,
) -> Result<(), RuntimeError> {
    for (name, rxs) in pending {
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    return Err(RuntimeError::Lifecycle {
                        model: name.clone(),
                        reason: "boot load job dropped".to_string(),
                    })
                }
            }
        }
    }
    Ok(())
}

/// Outcome of an asynchronous unload request (the 202/200 payload).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct UnloadTicket {
    /// Versions transitioned to `Unloading`, draining on the executor.
    pub unloading: Vec<u64>,
    /// Still-queued load jobs this request cancelled outright
    /// (`Loading → Unloaded`, nothing ever ran).
    pub cancelled: Vec<u64>,
}

impl ServingSystem {
    // ------------------------------------------------------ lifecycle

    /// Validate a load and enqueue one executor job per target version.
    /// Fast path only: repository rescan + state flips to `Loading`; the
    /// engine spawn happens on the executor. Returns the targeted
    /// versions plus one completion receiver per job (each yields the
    /// version on success or the typed attach error).
    #[allow(clippy::type_complexity)]
    fn spawn_load_jobs(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<(Vec<u64>, Vec<mpsc::Receiver<Result<u64, RuntimeError>>>), RuntimeError> {
        let targets = self.shared.registry.begin_load(model, version)?;
        let mut versions = Vec::with_capacity(targets.len());
        let mut rxs = Vec::with_capacity(targets.len());
        let mut specs = Vec::with_capacity(targets.len());
        for info in &targets {
            let (tx, rx) = mpsc::channel();
            let tx_cancel = tx.clone();
            let v = info.version;
            let work = {
                let shared = self.shared.clone();
                let model = model.to_string();
                let info = info.clone();
                Box::new(move || {
                    // A panicking attach must still land the version in
                    // a *terminal* registry state — left as `Loading` it
                    // would read as "busy" to every later load/unload.
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || shared.attach_version(&model, &info),
                    ));
                    match outcome {
                        Ok(Ok(())) => {
                            let _ = tx.send(Ok(info.version));
                        }
                        Ok(Err(e)) => {
                            shared.registry.finish_load(&model, info.version, Err(e.to_string()));
                            let _ = tx.send(Err(e));
                        }
                        Err(_) => {
                            shared.registry.finish_load(
                                &model,
                                info.version,
                                Err("load job panicked".to_string()),
                            );
                            let _ = tx.send(Err(RuntimeError::Lifecycle {
                                model: model.clone(),
                                reason: format!("load of version {} panicked", info.version),
                            }));
                        }
                    }
                }) as Box<dyn FnOnce() + Send>
            };
            // A cancelled job reverts `Loading → Unloaded` and fails any
            // synchronous waiter with a typed error.
            let cancel = {
                let shared = self.shared.clone();
                let model = model.to_string();
                Box::new(move || {
                    shared.registry.abort_load(&model, v);
                    let _ = tx_cancel.send(Err(RuntimeError::Lifecycle {
                        model,
                        reason: format!("load of version {v} cancelled before it started"),
                    }));
                }) as Box<dyn FnOnce() + Send>
            };
            specs.push(JobSpec { version: v, kind: JobKind::Load, work, cancel });
            versions.push(v);
            rxs.push(rx);
        }
        // All-or-nothing enqueue: a full queue reverts *every* target to
        // `Unloaded` (no half-accepted multi-version load whose stranded
        // siblings would read as "busy" to a retry).
        if let Err(e) = self.executor.submit_all(model, specs) {
            for info in &targets {
                self.shared.registry.abort_load(model, info.version);
            }
            return Err(e);
        }
        Ok((versions, rxs))
    }

    /// Non-blocking load (the `POST /v2/repository/models/{m}/load` 202
    /// path): validates, flips the target versions to `Loading`, and
    /// returns them immediately — the engine spawn runs on the lifecycle
    /// executor. Poll `/v2/repository/index` or `GET /v2/models/{m}` for
    /// the outcome (`READY` / `FAILED{reason}`). Validation errors
    /// (unknown model/version, malformed config, busy version) are still
    /// synchronous; a full executor queue is `Backpressure` (429).
    pub fn load_model_async(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Vec<u64>, RuntimeError> {
        let (versions, _rxs) = self.spawn_load_jobs(model, version)?;
        Ok(versions)
    }

    /// Blocking load: enqueues the same executor jobs as
    /// [`ServingSystem::load_model_async`] and waits for all of them
    /// (boot, `?wait=true`, CLI `--wait`, tests). Every targeted version
    /// is attempted; the first failure's typed error is returned after
    /// the rest settle (siblings are independent — one broken version no
    /// longer abandons the others mid-request).
    pub fn load_model(&self, model: &str, version: Option<u64>) -> Result<Vec<u64>, RuntimeError> {
        let (_versions, rxs) = self.spawn_load_jobs(model, version)?;
        let mut loaded = Vec::with_capacity(rxs.len());
        let mut first_err = None;
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(v)) => loaded.push(v),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err.or(Some(RuntimeError::Lifecycle {
                        model: model.to_string(),
                        reason: "lifecycle job dropped".to_string(),
                    }))
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loaded),
        }
    }

    /// Validate an unload, cancel still-queued loads it targets, swap
    /// the ready versions out of the serving snapshot (new requests 503
    /// immediately), detach their control loops, and enqueue the
    /// bounded drain as executor jobs.
    #[allow(clippy::type_complexity)]
    fn spawn_unload_jobs(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<(UnloadTicket, Vec<mpsc::Receiver<Result<u64, RuntimeError>>>), RuntimeError> {
        // Unknown models stay a 404 even when cancellation would match
        // nothing.
        if !self.shared.registry.has_model(model) {
            return Err(RuntimeError::UnknownModel(model.to_string()));
        }
        // An unload aimed at a load that never started is a pure
        // cancellation: the job's cancel hook reverts `Loading →
        // Unloaded` before we look at ready versions.
        let cancelled = self.executor.cancel_queued_loads(model, version);
        let targets = match self.shared.registry.begin_unload(model, version) {
            Ok(t) => t,
            Err(e) => {
                if cancelled.is_empty() {
                    return Err(e);
                }
                // Satisfied purely by cancellation.
                return Ok((UnloadTicket { unloading: Vec::new(), cancelled }, Vec::new()));
            }
        };
        let mut rxs = Vec::with_capacity(targets.len());
        for &v in &targets {
            let handle = self.shared.swap_out(model, v);
            if let Some(h) = &handle {
                self.shared.detach_loops(h);
            }
            let (tx, rx) = mpsc::channel();
            let work = {
                let shared = self.shared.clone();
                let model = model.to_string();
                Box::new(move || {
                    // As with loads: a panicking drain must not strand
                    // the version in `Unloading` — land it `Unloaded`
                    // best-effort so it stays reloadable.
                    let drained = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || shared.drain_and_finish(&model, v, handle),
                    ));
                    match drained {
                        Ok(()) => {
                            let _ = tx.send(Ok(v));
                        }
                        Err(_) => {
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || shared.registry.finish_unload(&model, v),
                            ));
                            let _ = tx.send(Err(RuntimeError::Lifecycle {
                                model: model.clone(),
                                reason: format!("unload of version {v} panicked mid-drain"),
                            }));
                        }
                    }
                }) as Box<dyn FnOnce() + Send>
            };
            // Unload jobs are never refused (the queue bound applies to
            // loads only). Cancelled only at shutdown: dropping the
            // closure drops the handle and sender, so the version's
            // engines unwind with the process and any waiter errors out.
            self.executor
                .submit(model, v, JobKind::Unload, work, Box::new(|| {}))
                .expect("unload jobs bypass the queue bound");
            rxs.push(rx);
        }
        Ok((UnloadTicket { unloading: targets, cancelled }, rxs))
    }

    /// Non-blocking unload (the `POST .../unload` 202 path): new
    /// requests 503 the moment this returns; the in-flight drain and
    /// engine teardown run on the executor. Queued loads of the targeted
    /// version are cancelled instead (reported in the ticket).
    pub fn unload_model_async(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<UnloadTicket, RuntimeError> {
        let (ticket, _rxs) = self.spawn_unload_jobs(model, version)?;
        Ok(ticket)
    }

    /// Blocking unload: same jobs, waits for the drains to finish. The
    /// returned ticket keeps drained versions (`unloading`, now fully
    /// unloaded) separate from cancelled queued loads (`cancelled`,
    /// which never served) — callers reporting "what was unloaded" must
    /// not conflate the two.
    pub fn unload_model_wait(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<UnloadTicket, RuntimeError> {
        let (ticket, rxs) = self.spawn_unload_jobs(model, version)?;
        let mut drained = Vec::with_capacity(rxs.len());
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(v)) => drained.push(v),
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    return Err(RuntimeError::Lifecycle {
                        model: model.to_string(),
                        reason: "lifecycle job dropped".to_string(),
                    })
                }
            }
        }
        drained.sort_unstable();
        Ok(UnloadTicket { unloading: drained, cancelled: ticket.cancelled })
    }

    /// Convenience wrapper over [`ServingSystem::unload_model_wait`]:
    /// every version transitioned out (drained + cancelled), sorted.
    pub fn unload_model(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Vec<u64>, RuntimeError> {
        let ticket = self.unload_model_wait(model, version)?;
        let mut done = ticket.cancelled;
        done.extend(ticket.unloading);
        done.sort_unstable();
        Ok(done)
    }

    /// Lifecycle jobs waiting for an executor worker (surfaced by
    /// `POST /v2/repository/index` and the `gf_lifecycle_queue_depth`
    /// gauge).
    pub fn lifecycle_queue_depth(&self) -> usize {
        self.executor.queue_depth()
    }

    /// Resolve a servable handle. Distinguishes a model that is not in
    /// the repository at all (`UnknownModel` → 404) from one with no
    /// ready version matching the request (`ModelUnavailable` → 503).
    fn resolve(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Arc<VersionHandle>, RuntimeError> {
        let snap = self.shared.snapshot.read().unwrap().clone();
        match snap.resolve(model, version) {
            Some(h) => Ok(h),
            None if self.shared.registry.has_model(model) => {
                Err(RuntimeError::ModelUnavailable { model: model.to_string() })
            }
            None => Err(RuntimeError::UnknownModel(model.to_string())),
        }
    }

    // -------------------------------------------------- introspection

    pub fn registry(&self) -> &ModelRegistry {
        &self.shared.registry
    }

    /// Every registered model name (loaded or not).
    pub fn model_names(&self) -> Vec<String> {
        self.shared.registry.model_names()
    }

    /// Number of models with at least one ready version.
    pub fn ready_models(&self) -> usize {
        self.shared.snapshot.read().unwrap().models.len()
    }

    /// The serving handle for a model version, if ready (None = default
    /// version).
    pub fn version_handle(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Option<Arc<VersionHandle>> {
        self.shared.snapshot.read().unwrap().resolve(model, version)
    }

    pub fn meter(&self) -> &EnergyMeter {
        &self.shared.meter
    }

    pub fn clock(&self) -> &SystemClock {
        &self.clock
    }

    /// Recent P95 latency (s).
    pub fn p95(&self) -> f64 {
        self.latency.lock().unwrap().p95()
    }

    /// The windowed-metrics aggregator feeding the control loops.
    pub fn metrics(&self) -> &WindowedMetrics {
        &self.shared.metrics
    }

    /// Names of the running control loops (empty when no plane).
    pub fn control_loop_names(&self) -> Vec<String> {
        self.shared.plane.as_ref().map(|p| p.loop_names()).unwrap_or_default()
    }

    /// Introspection snapshot of every control loop (name, law, output).
    pub fn control_loop_states(&self) -> Vec<crate::control::LoopState> {
        self.shared.plane.as_ref().map(|p| p.loop_states()).unwrap_or_default()
    }

    /// Scheduler queue capacity per batched path (the C(x) normaliser).
    pub fn queue_capacity(&self) -> usize {
        self.shared.cfg.queue_capacity
    }

    /// Whether a model's default version is servable on the batched path.
    pub fn has_batched_path(&self, model: &str) -> bool {
        self.version_handle(model, None).map(|h| h.has_batched()).unwrap_or(false)
    }

    /// Whether the background control plane is ticking.
    pub fn control_plane_running(&self) -> bool {
        self.shared.plane.as_ref().map(|p| p.running()).unwrap_or(false)
    }

    /// Recent arrival rate seen by the shared router.
    pub fn router_qps(&self) -> f64 {
        self.router.lock().unwrap().recent_qps()
    }

    /// The router's QPS threshold currently in force (+inf when pinned).
    pub fn router_qps_threshold(&self) -> f64 {
        self.router.lock().unwrap().qps_threshold()
    }

    /// Controller admission stats (None when open loop).
    pub fn controller_stats(&self) -> Option<crate::controller::admission::AdmissionStats> {
        self.controller.as_ref().map(|c| c.lock().unwrap().stats())
    }

    /// Restart the controller's τ(t) epoch at "now" — the paper's folding
    /// restarts when the landscape changes (deploys, model swaps); also
    /// lets benchmarks align τ0 with their first request.
    pub fn restart_controller_epoch(&self) {
        if let Some(c) = &self.controller {
            let now = self.clock.now();
            c.lock().unwrap().restart_epoch(now);
        }
    }

    /// Scheduler queue depth of a model's default-version batched path.
    pub fn queue_depth(&self, model: &str) -> usize {
        self.version_handle(model, None).map(|h| h.queue_depth()).unwrap_or(0)
    }

    // -------------------------------------------------------- serving

    /// Execute a request on an explicit path, bypassing the controller
    /// (the Table II benchmark mode).
    pub fn infer_on(&self, req: &Request, path: PathKind) -> Result<InferResult, RuntimeError> {
        let handle = self.resolve(&req.model, None)?;
        self.infer_on_handle(&handle, req, path)
    }

    fn infer_on_handle(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        path: PathKind,
    ) -> Result<InferResult, RuntimeError> {
        let t0 = self.clock.now();
        // Arrival is observed at entry, not completion: concurrent workers
        // finishing out of order must not scramble the rate window.
        self.shared.metrics.record_arrival(t0);
        let (out, stats) = match path {
            PathKind::Direct => {
                let input =
                    inputgen::batch_for(&handle.manifest, &[req.seed], self.shared.cfg.salt);
                handle.direct.infer(&req.model, input)?
            }
            PathKind::Batched => {
                let p = handle.batched.as_ref().ok_or_else(|| {
                    RuntimeError::InputMismatch(format!(
                        "model {:?} has no batched path",
                        req.model
                    ))
                })?;
                p.infer(req.seed)?
            }
            PathKind::CacheSkip => {
                return Err(RuntimeError::InputMismatch("cannot force cache path".into()))
            }
        };
        self.finish_exec(handle, req, path, t0, &out, &stats)
    }

    /// Shared post-execution accounting: latency histogram + windowed
    /// metrics, per-item energy attribution (plus the batched path's
    /// scheduler wait burned at idle power — the per-request energy
    /// premium Triton shows at batch=1 in Table II), and this handle's
    /// own energy window for its budget pacer.
    fn finish_exec(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        path: PathKind,
        t0: f64,
        out: &OutputBatch,
        stats: &ExecStats,
    ) -> Result<InferResult, RuntimeError> {
        let latency = self.clock.now() - t0;
        self.latency.lock().unwrap().record(latency);
        // Path-attributed tap: the router loop reads the direct p95, the
        // batch-delay loop the batched p95 (both also land in the blend).
        match path {
            PathKind::Direct => self.shared.metrics.record_latency_direct(latency),
            PathKind::Batched => self.shared.metrics.record_latency_batched(latency),
            _ => self.shared.metrics.record_latency(latency),
        }
        let flops_item = handle.manifest.flops_per_item(stats.bucket.max(1));
        let reading = self
            .shared
            .meter
            .record(flops_item, stats.exec_secs / stats.bucket.max(1) as f64);
        let now = self.clock.now();
        self.shared.metrics.record_joules(now, reading.joules);
        handle.energy.lock().unwrap().record(now, reading.joules);
        handle.energy_events.fetch_add(1, Ordering::Relaxed);
        if path == PathKind::Batched {
            self.shared.meter.record_idle((latency - stats.exec_secs).max(0.0));
        }
        Ok(InferResult {
            request_id: req.id,
            predicted: out.predicted(0),
            confidence: out.confidence(0),
            entropy: out.entropy[0],
            latency_secs: latency,
            exec_secs: stats.exec_secs,
            bucket: stats.bucket,
            joules: reading.joules,
            path,
            j: f64::NAN,
            tau: f64::NAN,
        })
    }

    /// The admission pass (Fig. 2 / Algorithm 1): screener pass for a
    /// cheap L(x) estimate, assemble CostInputs from the live feedback
    /// signals, compare J(x) against τ(t) + this model's energy-pacer
    /// bias. A Skip is answered (and fully accounted) here.
    fn admission_decision(
        &self,
        ctrl: &Arc<Mutex<AdmissionController>>,
        handle: &Arc<VersionHandle>,
        req: &Request,
        t0: f64,
    ) -> Result<AdmitOutcome, RuntimeError> {
        // 1. Cheap L(x) estimate: screener pass on its direct engine
        // (resolved from the live snapshot — an unloaded screener falls
        // back to the request's latent-confidence entropy).
        let screener = self.version_handle(models::SCREENER, None);
        let (scr_entropy, scr_pred, scr_conf, scr_exec, scr_flops) = match &screener {
            Some(s) if handle.manifest.input_kind == crate::runtime::InputKind::Tokens => {
                let input = inputgen::batch_for(&s.manifest, &[req.seed], self.shared.cfg.salt);
                let (o, st) = s.direct.infer(models::SCREENER, input)?;
                (
                    o.entropy[0] as f64,
                    o.predicted(0),
                    o.confidence(0),
                    st.exec_secs,
                    s.manifest.flops_per_item(1),
                )
            }
            // Vision path (or no screener loaded): use the latent-
            // confidence entropy the request carries.
            _ => (req.entropy(), req.label, req.confidence as f32, 0.0, 0.0),
        };

        // 2. Assemble CostInputs from the live feedback signals.
        // Spike reference = 2x nominal per-request joules: the steady
        // state sits at e_norm ~= 0.5 and a genuine energy spike drives
        // it to 0.
        let energy_ref =
            2.0 * self.shared.cfg.device.exec_energy(handle.manifest.flops_per_item(1));
        let x = CostInputs {
            entropy: scr_entropy,
            max_entropy: (handle.manifest.classes as f64).ln(),
            energy_ewma: self.shared.meter.ewma_joules(0.0),
            energy_ref,
            queue_depth: handle.queue_depth(),
            queue_capacity: self.shared.cfg.queue_capacity,
            p95_latency: self.p95(),
            slo_latency: self.shared.cfg.slo_latency,
        };

        // 3. Decide, biased by this model's energy-budget pacer.
        let bias = handle.energy_correction.get();
        let decision = ctrl.lock().unwrap().decide_biased(&x, t0, bias);
        match decision {
            Decision::Admit { j, tau } => Ok(AdmitOutcome::Execute { j, tau }),
            Decision::Skip { j, tau, .. } => {
                // Answer from cache / screener argmax (Algorithm 1 line
                // 9). Keys are version-aware: a reloaded version never
                // inherits its predecessor's answers.
                let sig = ResponseCache::signature(
                    &req.model,
                    handle.version,
                    req.seed,
                    self.shared.cfg.cache_clusters,
                );
                let cached = self.shared.cache.lock().unwrap().get(sig);
                let (label, conf) = match cached {
                    Some(c) => (c.label, c.confidence as f32),
                    None => (scr_pred, scr_conf),
                };
                let latency = self.clock.now() - t0;
                self.latency.lock().unwrap().record(latency);
                // Arrival recorded here (not at submit entry) so admitted
                // requests are not double-counted by the exec path's tap;
                // the recorded instant is still t0, and the rate window
                // clamps any cross-thread ordering races.
                self.shared.metrics.record_arrival(t0);
                self.shared.metrics.record_latency(latency);
                // Energy: only the screener pass.
                let reading = self.shared.meter.record(scr_flops, scr_exec);
                self.shared.metrics.record_joules(self.clock.now(), reading.joules);
                Ok(AdmitOutcome::Skip {
                    result: InferResult {
                        request_id: req.id,
                        predicted: label,
                        confidence: conf,
                        entropy: scr_entropy as f32,
                        latency_secs: latency,
                        exec_secs: scr_exec,
                        bucket: 0,
                        joules: reading.joules,
                        path: PathKind::CacheSkip,
                        j,
                        tau,
                    },
                })
            }
        }
    }

    /// The closed-loop entry point (Fig. 2): screener → J(x) vs τ(t) →
    /// route or answer from cache.
    pub fn submit(&self, req: &Request, prefer: PathKind) -> Result<InferResult, RuntimeError> {
        let handle = self.resolve(&req.model, None)?;
        self.submit_handle(&handle, req, prefer)
    }

    fn submit_handle(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        prefer: PathKind,
    ) -> Result<InferResult, RuntimeError> {
        let Some(ctrl) = &self.controller else {
            return self.infer_on_handle(handle, req, prefer);
        };
        let t0 = self.clock.now();
        match self.admission_decision(ctrl, handle, req, t0)? {
            AdmitOutcome::Execute { j, tau } => {
                let mut r = self.infer_on_handle(handle, req, prefer)?;
                r.j = j;
                r.tau = tau;
                // Populate the cache so future skips can answer — unless
                // this version was swapped out mid-request (a straggler
                // must not resurrect entries the unload invalidated).
                if !handle.retired.load(Ordering::SeqCst) {
                    let sig = ResponseCache::signature(
                        &req.model,
                        handle.version,
                        req.seed,
                        self.shared.cfg.cache_clusters,
                    );
                    self.shared.cache.lock().unwrap().put(
                        sig,
                        CachedResponse { label: r.predicted, confidence: r.confidence as f64 },
                    );
                }
                Ok(r)
            }
            AdmitOutcome::Skip { result } => Ok(result),
        }
    }

    /// Fully closed-loop entry point: the shared router (arrival-rate
    /// estimator + adaptive QPS threshold) picks the path, then the
    /// admission controller decides as in [`ServingSystem::submit`].
    pub fn submit_auto(&self, req: &Request) -> Result<InferResult, RuntimeError> {
        let path = self.router.lock().unwrap().route(self.clock.now());
        self.submit(req, path)
    }

    /// The v2-protocol single-request entry point: `submit`/`submit_auto`
    /// semantics plus per-request deadline, priority, and target version
    /// (one-item view of [`ServingSystem::submit_batch`]).
    pub fn submit_opts(
        &self,
        req: &Request,
        prefer: Option<PathKind>,
        opts: &SubmitOptions,
    ) -> Result<InferResult, RuntimeError> {
        let mut results = self.submit_batch(std::slice::from_ref(req), prefer, opts)?;
        results.pop().ok_or_else(|| RuntimeError::Xla("empty batch".into()))
    }

    /// The v2-protocol batch entry point. Semantics:
    ///
    /// * One routing decision and one deadline for the whole body (the
    ///   deadline bounds the client's wait, not each item's share).
    /// * `Priority::High` bypasses the admission controller; `Low` is
    ///   shed with `Backpressure` once the target queue passes ~80%
    ///   occupancy; `Normal` runs per-item admission (the screener runs
    ///   per item).
    /// * All-or-error: the first failure aborts and becomes the result.
    /// * **Coalescing:** a multi-item body on the batched path enqueues
    ///   every admitted item via `BatchedPath::submit` *before*
    ///   collecting any reply, so the dynamic batcher can fuse them
    ///   into one bucket instead of paying the queue delay per item.
    pub fn submit_batch(
        &self,
        reqs: &[Request],
        prefer: Option<PathKind>,
        opts: &SubmitOptions,
    ) -> Result<Vec<InferResult>, RuntimeError> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = self.clock.now();
        if let Some(d) = opts.deadline {
            if t0 >= d {
                return Err(deadline_error(opts, t0, t0));
            }
        }
        let model = &reqs[0].model;
        let handle = self.resolve(model, opts.version)?;

        let mut path = match prefer {
            Some(p) => p,
            None => self.router.lock().unwrap().route(t0),
        };
        // A model with no batcher cannot serve the batched path: pinning
        // "batched" there is a client error (not MODEL_NOT_FOUND — the
        // model exists and is loaded), and the model-blind auto router
        // falls back to direct.
        if path == PathKind::Batched && handle.batched.is_none() {
            if prefer.is_some() {
                return Err(RuntimeError::InputMismatch(format!(
                    "model {model:?} has no batched path"
                )));
            }
            path = PathKind::Direct;
        }
        if opts.priority == Priority::Low {
            // Low-priority shed: refuse before enqueueing once the queue
            // sits above 4/5 of capacity (cheap head-room guard).
            let depth = handle.queue_depth();
            if depth * 5 >= self.shared.cfg.queue_capacity * 4 {
                return Err(RuntimeError::Backpressure(model.clone()));
            }
        }
        let bypass_admission = opts.priority == Priority::High || self.controller.is_none();

        // Single item, direct path, or batcher-less model: the plain
        // sequential route.
        if reqs.len() < 2 || path != PathKind::Batched {
            let mut out = Vec::with_capacity(reqs.len());
            for req in reqs {
                if let Some(d) = opts.deadline {
                    let now = self.clock.now();
                    if now >= d {
                        return Err(deadline_error(opts, t0, now));
                    }
                }
                let r = if bypass_admission {
                    self.infer_on_handle(&handle, req, path)?
                } else {
                    self.submit_handle(&handle, req, path)?
                };
                out.push(r);
            }
            if let Some(d) = opts.deadline {
                let now = self.clock.now();
                if now > d {
                    return Err(deadline_error(opts, t0, now));
                }
            }
            return Ok(out);
        }

        let batched = handle.batched.as_ref().expect("batched path checked above");

        // Phase A — per-item admission (screener runs per item; skips
        // answer immediately from cache).
        enum ItemPlan {
            Skip(InferResult),
            Exec { j: f64, tau: f64 },
        }
        let mut plans = Vec::with_capacity(reqs.len());
        for req in reqs {
            // Nothing is enqueued yet, so a deadline that expires during
            // the per-item screener passes still refuses the whole body
            // for free (same contract as the sequential path).
            if let Some(d) = opts.deadline {
                let now = self.clock.now();
                if now >= d {
                    return Err(deadline_error(opts, t0, now));
                }
            }
            if bypass_admission {
                plans.push(ItemPlan::Exec { j: f64::NAN, tau: f64::NAN });
            } else {
                let ctrl = self.controller.as_ref().expect("checked above");
                match self.admission_decision(ctrl, &handle, req, self.clock.now())? {
                    AdmitOutcome::Execute { j, tau } => plans.push(ItemPlan::Exec { j, tau }),
                    AdmitOutcome::Skip { result } => plans.push(ItemPlan::Skip(result)),
                }
            }
        }

        // Phase B — enqueue every admitted item before collecting any
        // reply, so one body fuses into shared buckets. An enqueue
        // failure (backpressure) aborts the batch; receivers already
        // enqueued are dropped and their replies discarded by the
        // batcher (all-or-error contract).
        type Reply = mpsc::Receiver<Result<(OutputBatch, ExecStats), RuntimeError>>;
        let mut pending: Vec<Option<(f64, Reply)>> = Vec::with_capacity(reqs.len());
        for (req, plan) in reqs.iter().zip(&plans) {
            match plan {
                ItemPlan::Skip(_) => pending.push(None),
                ItemPlan::Exec { .. } => {
                    let t_item = self.clock.now();
                    self.shared.metrics.record_arrival(t_item);
                    let rx = batched.submit(req.seed)?;
                    pending.push(Some((t_item, rx)));
                }
            }
        }

        // Phase C — collect replies in request order and account each
        // item exactly as a lone batched execution would be.
        let mut out = Vec::with_capacity(reqs.len());
        for ((req, plan), slot) in reqs.iter().zip(plans).zip(pending) {
            match (plan, slot) {
                (ItemPlan::Skip(result), _) => out.push(result),
                (ItemPlan::Exec { j, tau }, Some((t_item, rx))) => {
                    let (ob, stats) =
                        rx.recv().map_err(|_| RuntimeError::Xla("reply dropped".into()))??;
                    let mut r =
                        self.finish_exec(&handle, req, PathKind::Batched, t_item, &ob, &stats)?;
                    r.j = j;
                    r.tau = tau;
                    if r.j.is_finite() && !handle.retired.load(Ordering::SeqCst) {
                        // Controller-admitted work populates the cache so
                        // future skips can answer (same as `submit`;
                        // retired versions must not re-populate what
                        // their unload invalidated).
                        let sig = ResponseCache::signature(
                            &req.model,
                            handle.version,
                            req.seed,
                            self.shared.cfg.cache_clusters,
                        );
                        self.shared.cache.lock().unwrap().put(
                            sig,
                            CachedResponse {
                                label: r.predicted,
                                confidence: r.confidence as f64,
                            },
                        );
                    }
                    out.push(r);
                }
                (ItemPlan::Exec { .. }, None) => {
                    unreachable!("exec plans always enqueue a receiver")
                }
            }
        }
        if let Some(d) = opts.deadline {
            let now = self.clock.now();
            if now > d {
                return Err(deadline_error(opts, t0, now));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::threshold::ThresholdSchedule;
    use crate::workload::stream::{RequestStream, StreamConfig};

    fn repo_root() -> Option<PathBuf> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    fn requests(n: usize, model: &str) -> Vec<Request> {
        let mut s = RequestStream::new(
            StreamConfig { model: model.to_string(), ..Default::default() },
            11,
        );
        (0..n).map(|i| s.next_request(i as f64 * 0.01)).collect()
    }

    #[test]
    fn open_loop_dual_path_works() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(3, models::DISTILBERT);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert_eq!(d.path, PathKind::Direct);
            assert!(d.latency_secs > 0.0);
            assert!(d.joules > 0.0);
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(b.path, PathKind::Batched);
            assert!((0..2).contains(&(d.predicted as i32)));
            assert_eq!(d.predicted, b.predicted, "paths agree on the answer");
        }
        assert!(sys.meter().total_joules() > 0.0);
        assert!(sys.p95() > 0.0);
    }

    #[test]
    fn default_mode_loads_every_model_at_boot() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        assert_eq!(sys.ready_models(), sys.model_names().len());
        let h = sys.version_handle(models::DISTILBERT, None).expect("loaded");
        assert_eq!(h.version(), 1, "flat layout serves as version 1");
        assert!(h.load_stats().load_secs > 0.0);
        assert!(h.load_stats().weight_bytes > 0);
        assert!(h.load_stats().est_load_joules > 0.0);
    }

    #[test]
    fn closed_loop_skips_and_admits() {
        let Some(root) = repo_root() else { return };
        // Strict constant τ: plenty of skips on confident requests.
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.95 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        let reqs = requests(20, models::DISTILBERT);
        let mut skipped = 0;
        for r in &reqs {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            if res.path == PathKind::CacheSkip {
                skipped += 1;
                assert_eq!(res.bucket, 0);
                assert!(res.j < res.tau);
            }
        }
        let stats = sys.controller_stats().unwrap();
        assert_eq!(stats.total(), 20);
        assert_eq!(stats.skipped, skipped);
        assert!(skipped > 0, "strict τ must skip something");
    }

    #[test]
    fn permissive_controller_admits_everything() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.0 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        for r in &requests(5, models::DISTILBERT) {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            assert_ne!(res.path, PathKind::CacheSkip);
            assert!(res.j >= res.tau);
        }
        assert_eq!(sys.controller_stats().unwrap().admitted, 5);
    }

    #[test]
    fn control_plane_boots_and_serves() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root)
            .with_controller(ControllerConfig {
                weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
                schedule: ThresholdSchedule::Constant { tau: 0.5 },
                respond_from_cache: true,
            })
            .with_control(
                crate::control::ControlPlaneConfig {
                    tick_secs: 0.005,
                    ..Default::default()
                }
                .with_adaptive_tau(0.5)
                .with_adaptive_batch_delay(0.25)
                .with_adaptive_router(0.25)
                .with_energy_budget(100.0),
            );
        let sys = ServingSystem::start(cfg).unwrap();
        assert!(sys.control_plane_running());
        let names = sys.control_loop_names();
        assert!(names.iter().any(|n| n == "tau_correction"), "{names:?}");
        assert!(names.iter().any(|n| n == "router_qps_threshold"), "{names:?}");
        // The energy budget is per batched path now (one pacer per
        // loaded model version), keyed energy_budget.<model>/<version>.
        assert!(
            names.iter().any(|n| n.starts_with("energy_budget.")),
            "{names:?}"
        );
        // batch_delay_us.<model>/<v> loops appear once per version whose
        // config sets a nonzero queue-delay window, so their presence
        // depends on the artifacts' config.pbtxt files — not asserted.

        for r in &requests(10, models::DISTILBERT) {
            let res = sys.submit_auto(r).unwrap();
            assert!(res.latency_secs >= 0.0);
        }
        assert!(sys.metrics().events() >= 10);
        assert!(sys.router_qps() > 0.0);
        // let the ticker observe the traffic at least once
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(sys.controller_stats().unwrap().total(), 10);
    }

    #[test]
    fn per_model_loops_detach_on_unload() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root).with_control(
            crate::control::ControlPlaneConfig { tick_secs: 0.005, ..Default::default() }
                .with_energy_budget(100.0),
        );
        let sys = ServingSystem::start(cfg).unwrap();
        let loop_name = format!("energy_budget.{}/1", models::DISTILBERT);
        assert!(sys.control_loop_names().contains(&loop_name));
        sys.unload_model(models::DISTILBERT, None).unwrap();
        assert!(!sys.control_loop_names().contains(&loop_name));
        sys.load_model(models::DISTILBERT, None).unwrap();
        assert!(sys.control_loop_names().contains(&loop_name));
    }

    #[test]
    fn unload_makes_model_unavailable_and_reload_restores() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(2, models::DISTILBERT);
        assert!(sys.infer_on(&reqs[0], PathKind::Direct).is_ok());

        let unloaded = sys.unload_model(models::DISTILBERT, None).unwrap();
        assert_eq!(unloaded, vec![1]);
        let err = sys.infer_on(&reqs[0], PathKind::Direct).unwrap_err();
        assert!(
            matches!(err, RuntimeError::ModelUnavailable { .. }),
            "unloaded model must 503, got {err}"
        );
        // A model that was never in the repository is still 404 material.
        let ghost = Request::external(7, "ghost", 1, sys.clock().now());
        assert!(matches!(
            sys.infer_on(&ghost, PathKind::Direct).unwrap_err(),
            RuntimeError::UnknownModel(_)
        ));

        let loaded = sys.load_model(models::DISTILBERT, None).unwrap();
        assert_eq!(loaded, vec![1]);
        let r = sys.infer_on(&reqs[1], PathKind::Direct).unwrap();
        assert!(r.latency_secs > 0.0);
    }

    #[test]
    fn submit_batch_coalesces_into_shared_buckets() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(16, models::DISTILBERT);
        let results = sys
            .submit_batch(&reqs, Some(PathKind::Batched), &SubmitOptions::default())
            .unwrap();
        assert_eq!(results.len(), 16);
        for (req, r) in reqs.iter().zip(&results) {
            assert_eq!(r.request_id, req.id, "results stay in request order");
            assert_eq!(r.path, PathKind::Batched);
        }
        // The regression this guards: 16 items enqueued before any reply
        // is collected must fuse into multi-item buckets, not execute as
        // 16 singletons.
        assert!(
            results.iter().any(|r| r.bucket >= 2),
            "no multi-item bucket formed: {:?}",
            results.iter().map(|r| r.bucket).collect::<Vec<_>>()
        );
    }

    #[test]
    fn submit_opts_honors_deadline_and_priority() {
        let Some(root) = repo_root() else { return };
        // Strict constant τ so Normal-priority requests mostly skip.
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.95 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        let reqs = requests(4, models::DISTILBERT);

        // Already-expired deadline: refused before any work.
        let expired = SubmitOptions {
            deadline: Some(0.0),
            ..SubmitOptions::default()
        };
        let err = sys.submit_opts(&reqs[0], Some(PathKind::Direct), &expired).unwrap_err();
        assert!(matches!(err, RuntimeError::DeadlineExceeded { .. }), "{err}");

        // High priority bypasses the admission skip even under strict τ.
        let high = SubmitOptions { priority: Priority::High, ..Default::default() };
        let r = sys.submit_opts(&reqs[1], Some(PathKind::Direct), &high).unwrap();
        assert_ne!(r.path, PathKind::CacheSkip);

        // A generous deadline passes through (auto-routed).
        let opts = SubmitOptions::with_timeout(sys.clock().now(), 30_000, Priority::Normal);
        assert!(sys.submit_opts(&reqs[2], None, &opts).is_ok());

        // Default options reproduce submit() semantics.
        let dflt = SubmitOptions::default();
        assert!(sys.submit_opts(&reqs[3], Some(PathKind::Direct), &dflt).is_ok());

        // Pinning an explicit version works and a missing one is a 503.
        let versioned = SubmitOptions { version: Some(1), ..Default::default() };
        assert!(sys.submit_opts(&reqs[3], Some(PathKind::Direct), &versioned).is_ok());
        let missing = SubmitOptions { version: Some(99), ..Default::default() };
        let err = sys
            .submit_opts(&reqs[3], Some(PathKind::Direct), &missing)
            .unwrap_err();
        assert!(matches!(err, RuntimeError::ModelUnavailable { .. }), "{err}");

        // Pinning "batched" on a model with no batcher is an input error
        // (the model exists — it must not read as MODEL_NOT_FOUND).
        if !sys.has_batched_path(models::SCREENER) {
            let req = Request::external(99, models::SCREENER, 1, sys.clock().now());
            let err = sys
                .submit_opts(&req, Some(PathKind::Batched), &SubmitOptions::default())
                .unwrap_err();
            assert!(matches!(err, RuntimeError::InputMismatch(_)), "{err}");
        }
    }

    #[test]
    fn no_control_config_means_no_plane() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        assert!(!sys.control_plane_running());
        assert!(sys.control_loop_names().is_empty());
    }

    #[test]
    fn resnet_serves_on_both_paths() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(2, models::RESNET);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert!((0..10).contains(&(d.predicted as i32)));
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(d.predicted, b.predicted);
        }
    }
}
