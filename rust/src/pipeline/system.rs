//! The closed-loop serving system (paper Fig. 2): controller in front of
//! the dual-path stack, with energy/latency feedback wired back into the
//! next admission decision.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::batching::policy::BatcherPolicy;
use crate::controller::cache::{CachedResponse, ResponseCache};
use crate::controller::cost::CostInputs;
use crate::controller::{AdmissionController, AdmissionPolicy, ControllerConfig, Decision};
use crate::energy::meter::{EnergyMeter, MeterMode};
use crate::energy::profile::DeviceProfile;
use crate::models;
use crate::models::inputgen;
use crate::router::PathKind;
use crate::runtime::engine::ExecMode;
use crate::runtime::repository::Repository;
use crate::runtime::RuntimeError;
use crate::stats::LatencyHistogram;
use crate::util::{Clock, SystemClock};
use crate::workload::stream::Request;

use super::batched::BatchedPath;
use super::direct::DirectPath;

/// System configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub repo_root: PathBuf,
    pub exec_mode: ExecMode,
    /// Device whose power profile attributes energy.
    pub device: DeviceProfile,
    pub meter_mode: MeterMode,
    /// None = open loop (no admission control).
    pub controller: Option<ControllerConfig>,
    /// Scheduler queue capacity per model (C(x) normaliser).
    pub queue_capacity: usize,
    /// Latency SLO for the congestion proxy (s).
    pub slo_latency: f64,
    /// Payload salt (must match trace generation).
    pub salt: u64,
    /// Response-cache capacity and seed-cluster count.
    pub cache_capacity: usize,
    pub cache_clusters: u64,
}

impl SystemConfig {
    pub fn new(repo_root: PathBuf) -> Self {
        SystemConfig {
            repo_root,
            exec_mode: ExecMode::DeviceBuffers,
            device: DeviceProfile::rtx4000_ada(),
            meter_mode: MeterMode::SimulatedFlops,
            controller: None,
            queue_capacity: 64,
            slo_latency: 0.25,
            salt: 0,
            cache_capacity: 4096,
            cache_clusters: 256,
        }
    }

    pub fn with_controller(mut self, cfg: ControllerConfig) -> Self {
        self.controller = Some(cfg);
        self
    }
}

/// Result of serving one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferResult {
    pub request_id: u64,
    pub predicted: u32,
    pub confidence: f32,
    pub entropy: f32,
    /// End-to-end seconds inside the system.
    pub latency_secs: f64,
    /// Engine execute seconds (shared across the fused batch).
    pub exec_secs: f64,
    /// Bucket the execution used (0 for cache answers).
    pub bucket: usize,
    /// Joules attributed to this request.
    pub joules: f64,
    pub path: PathKind,
    /// J(x) and τ(t) at decision time (NaN when open loop).
    pub j: f64,
    pub tau: f64,
}

/// The full serving system.
pub struct ServingSystem {
    repo: Repository,
    direct: DirectPath,
    batched: HashMap<String, BatchedPath>,
    meter: Arc<EnergyMeter>,
    latency: Mutex<LatencyHistogram>,
    controller: Option<Mutex<AdmissionController>>,
    cache: Mutex<ResponseCache>,
    clock: SystemClock,
    cfg: SystemConfig,
}

impl ServingSystem {
    /// Boot the system: scan the repository, start the direct path (all
    /// models on one engine) and one batched path per servable model
    /// (batcher policy + instance count from its config.pbtxt).
    pub fn start(cfg: SystemConfig) -> Result<Self, RuntimeError> {
        let repo = Repository::scan(&cfg.repo_root)?;
        repo.validate()?;

        let all_dirs: Vec<PathBuf> = repo.entries.values().map(|e| e.dir.clone()).collect();
        let direct = DirectPath::start(all_dirs, cfg.exec_mode)?;

        let mut batched = HashMap::new();
        for (name, entry) in &repo.entries {
            if name == models::SCREENER {
                continue; // the screener serves inline on the direct engine
            }
            let policy = entry
                .config
                .as_ref()
                .map(BatcherPolicy::from_config)
                .unwrap_or_else(|| BatcherPolicy::immediate(entry.manifest.max_bucket()));
            let instances = entry.config.as_ref().map(|c| c.total_instances()).unwrap_or(1);
            batched.insert(
                name.clone(),
                BatchedPath::start(
                    entry.dir.clone(),
                    policy,
                    instances,
                    cfg.queue_capacity,
                    cfg.exec_mode,
                    cfg.salt,
                )?,
            );
        }

        let meter = Arc::new(EnergyMeter::new(cfg.device.clone(), cfg.meter_mode, 16.0));
        let controller = cfg.controller.clone().map(|c| Mutex::new(AdmissionController::new(c)));
        Ok(ServingSystem {
            repo,
            direct,
            batched,
            meter,
            latency: Mutex::new(LatencyHistogram::for_latency()),
            controller,
            cache: Mutex::new(ResponseCache::new(cfg.cache_capacity)),
            clock: SystemClock::new(),
            cfg,
        })
    }

    pub fn repository(&self) -> &Repository {
        &self.repo
    }

    pub fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    pub fn clock(&self) -> &SystemClock {
        &self.clock
    }

    /// Recent P95 latency (s).
    pub fn p95(&self) -> f64 {
        self.latency.lock().unwrap().p95()
    }

    /// Controller admission stats (None when open loop).
    pub fn controller_stats(&self) -> Option<crate::controller::admission::AdmissionStats> {
        self.controller.as_ref().map(|c| c.lock().unwrap().stats())
    }

    /// Restart the controller's τ(t) epoch at "now" — the paper's folding
    /// restarts when the landscape changes (deploys, model swaps); also
    /// lets benchmarks align τ0 with their first request.
    pub fn restart_controller_epoch(&self) {
        if let Some(c) = &self.controller {
            let now = self.clock.now();
            c.lock().unwrap().restart_epoch(now);
        }
    }

    /// Scheduler queue depth of a model's batched path.
    pub fn queue_depth(&self, model: &str) -> usize {
        self.batched.get(model).map(|p| p.queue_depth()).unwrap_or(0)
    }

    /// Execute a request on an explicit path, bypassing the controller
    /// (the Table II benchmark mode).
    pub fn infer_on(&self, req: &Request, path: PathKind) -> Result<InferResult, RuntimeError> {
        let t0 = self.clock.now();
        let entry = self.repo.get(&req.model)?;
        let (out, stats) = match path {
            PathKind::Direct => {
                let input = inputgen::batch_for(&entry.manifest, &[req.seed], self.cfg.salt);
                self.direct.infer(&req.model, input)?
            }
            PathKind::Batched => {
                let p = self
                    .batched
                    .get(&req.model)
                    .ok_or_else(|| RuntimeError::UnknownModel(req.model.clone()))?;
                p.infer(req.seed)?
            }
            PathKind::CacheSkip => {
                return Err(RuntimeError::InputMismatch("cannot force cache path".into()))
            }
        };
        let latency = self.clock.now() - t0;
        self.latency.lock().unwrap().record(latency);
        // Energy attribution: per-item share of the executed bucket, plus
        // (batched path) the scheduler wait burned at idle power — this is
        // the per-request energy premium Triton shows at batch=1 in
        // Table II while the device sits idle inside the queue window.
        let flops_item = entry.manifest.flops_per_item(stats.bucket.max(1));
        let reading = self.meter.record(flops_item, stats.exec_secs / stats.bucket.max(1) as f64);
        if path == PathKind::Batched {
            self.meter.record_idle((latency - stats.exec_secs).max(0.0));
        }
        Ok(InferResult {
            request_id: req.id,
            predicted: out.predicted(0),
            confidence: out.confidence(0),
            entropy: out.entropy[0],
            latency_secs: latency,
            exec_secs: stats.exec_secs,
            bucket: stats.bucket,
            joules: reading.joules,
            path,
            j: f64::NAN,
            tau: f64::NAN,
        })
    }

    /// The closed-loop entry point (Fig. 2): screener → J(x) vs τ(t) →
    /// route or answer from cache.
    pub fn submit(&self, req: &Request, prefer: PathKind) -> Result<InferResult, RuntimeError> {
        let Some(ctrl) = &self.controller else {
            return self.infer_on(req, prefer);
        };
        let t0 = self.clock.now();

        // 1. Cheap L(x) estimate: screener pass on the direct engine.
        let entry = self.repo.get(&req.model)?;
        let scr_manifest = self.repo.get(models::SCREENER).ok().map(|e| e.manifest.clone());
        let (scr_entropy, scr_pred, scr_conf, scr_exec) = match &scr_manifest {
            Some(m) if entry.manifest.input_kind == crate::runtime::InputKind::Tokens => {
                let input = inputgen::batch_for(m, &[req.seed], self.cfg.salt);
                let (o, s) = self.direct.infer(models::SCREENER, input)?;
                (o.entropy[0] as f64, o.predicted(0), o.confidence(0), s.exec_secs)
            }
            // Vision path has no screener model: use the latent-confidence
            // entropy the request carries (cache-estimate stand-in).
            _ => (req.entropy(), req.label, req.confidence as f32, 0.0),
        };

        // 2. Assemble CostInputs from the live feedback signals.
        // Spike reference = 2x nominal per-request joules: the steady state
        // sits at e_norm ~= 0.5 and a genuine energy spike drives it to 0.
        let energy_ref = 2.0 * self.cfg.device.exec_energy(entry.manifest.flops_per_item(1));
        let x = CostInputs {
            entropy: scr_entropy,
            max_entropy: (entry.manifest.classes as f64).ln(),
            energy_ewma: self.meter.ewma_joules(0.0),
            energy_ref,
            queue_depth: self.queue_depth(&req.model),
            queue_capacity: self.cfg.queue_capacity,
            p95_latency: self.p95(),
            slo_latency: self.cfg.slo_latency,
        };

        // 3. Decide.
        let decision = ctrl.lock().unwrap().decide(&x, t0);
        match decision {
            Decision::Admit { j, tau } => {
                let mut r = self.infer_on(req, prefer)?;
                r.j = j;
                r.tau = tau;
                // populate cache so future skips can answer
                let sig =
                    ResponseCache::signature(&req.model, req.seed, self.cfg.cache_clusters);
                self.cache.lock().unwrap().put(
                    sig,
                    CachedResponse { label: r.predicted, confidence: r.confidence as f64 },
                );
                Ok(r)
            }
            Decision::Skip { j, tau, .. } => {
                // Answer from cache / screener argmax (Algorithm 1 line 9).
                let sig =
                    ResponseCache::signature(&req.model, req.seed, self.cfg.cache_clusters);
                let cached = self.cache.lock().unwrap().get(sig);
                let (label, conf) = match cached {
                    Some(c) => (c.label, c.confidence as f32),
                    None => (scr_pred, scr_conf),
                };
                let latency = self.clock.now() - t0;
                self.latency.lock().unwrap().record(latency);
                // Energy: only the screener pass.
                let scr_flops = scr_manifest.as_ref().map(|m| m.flops_per_item(1)).unwrap_or(0.0);
                let reading = self.meter.record(scr_flops, scr_exec);
                Ok(InferResult {
                    request_id: req.id,
                    predicted: label,
                    confidence: conf,
                    entropy: scr_entropy as f32,
                    latency_secs: latency,
                    exec_secs: scr_exec,
                    bucket: 0,
                    joules: reading.joules,
                    path: PathKind::CacheSkip,
                    j,
                    tau,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::threshold::ThresholdSchedule;
    use crate::workload::stream::{RequestStream, StreamConfig};

    fn repo_root() -> Option<PathBuf> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    fn requests(n: usize, model: &str) -> Vec<Request> {
        let mut s = RequestStream::new(
            StreamConfig { model: model.to_string(), ..Default::default() },
            11,
        );
        (0..n).map(|i| s.next_request(i as f64 * 0.01)).collect()
    }

    #[test]
    fn open_loop_dual_path_works() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(3, models::DISTILBERT);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert_eq!(d.path, PathKind::Direct);
            assert!(d.latency_secs > 0.0);
            assert!(d.joules > 0.0);
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(b.path, PathKind::Batched);
            assert!((0..2).contains(&(d.predicted as i32)));
            assert_eq!(d.predicted, b.predicted, "paths agree on the answer");
        }
        assert!(sys.meter().total_joules() > 0.0);
        assert!(sys.p95() > 0.0);
    }

    #[test]
    fn closed_loop_skips_and_admits() {
        let Some(root) = repo_root() else { return };
        // Strict constant τ: plenty of skips on confident requests.
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.95 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        let reqs = requests(20, models::DISTILBERT);
        let mut skipped = 0;
        for r in &reqs {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            if res.path == PathKind::CacheSkip {
                skipped += 1;
                assert_eq!(res.bucket, 0);
                assert!(res.j < res.tau);
            }
        }
        let stats = sys.controller_stats().unwrap();
        assert_eq!(stats.total(), 20);
        assert_eq!(stats.skipped, skipped);
        assert!(skipped > 0, "strict τ must skip something");
    }

    #[test]
    fn permissive_controller_admits_everything() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.0 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        for r in &requests(5, models::DISTILBERT) {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            assert_ne!(res.path, PathKind::CacheSkip);
            assert!(res.j >= res.tau);
        }
        assert_eq!(sys.controller_stats().unwrap().admitted, 5);
    }

    #[test]
    fn resnet_serves_on_both_paths() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(2, models::RESNET);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert!((0..10).contains(&(d.predicted as i32)));
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(d.predicted, b.predicted);
        }
    }
}
