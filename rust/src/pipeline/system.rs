//! The closed-loop serving system (paper Fig. 2): controller in front of
//! the dual-path stack, with energy/latency feedback wired back into the
//! next admission decision.
//!
//! Beyond the per-request loop, the system can boot a
//! [`ControlPlane`](crate::control::ControlPlane) from
//! [`ControlPlaneConfig`]: a background tick that reads the
//! [`WindowedMetrics`] aggregator (fed from the existing latency/energy
//! event sites) and drives the adaptive knobs — τ corrections, batcher
//! queue-delay windows, and the router's QPS threshold — through their
//! `Adaptive` handles.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::batching::policy::BatcherPolicy;
use crate::control::law::{Aimd, BudgetPacer, SetpointTracker};
use crate::control::{Adaptive, ControlLoop, ControlPlane, ControlPlaneConfig, WindowedMetrics};
use crate::controller::cache::{CachedResponse, ResponseCache};
use crate::controller::cost::CostInputs;
use crate::controller::{AdmissionController, AdmissionPolicy, ControllerConfig, Decision};
use crate::energy::meter::{EnergyMeter, MeterMode};
use crate::energy::profile::DeviceProfile;
use crate::models;
use crate::models::inputgen;
use crate::router::{PathKind, RoutePolicy, Router};
use crate::runtime::engine::ExecMode;
use crate::runtime::repository::Repository;
use crate::runtime::RuntimeError;
use crate::stats::LatencyHistogram;
use crate::util::{Clock, SystemClock};
use crate::workload::stream::{Priority, Request};

use super::batched::BatchedPath;
use super::direct::DirectPath;

/// System configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub repo_root: PathBuf,
    pub exec_mode: ExecMode,
    /// Device whose power profile attributes energy.
    pub device: DeviceProfile,
    pub meter_mode: MeterMode,
    /// None = open loop (no admission control).
    pub controller: Option<ControllerConfig>,
    /// Scheduler queue capacity per model (C(x) normaliser).
    pub queue_capacity: usize,
    /// Latency SLO for the congestion proxy (s).
    pub slo_latency: f64,
    /// Payload salt (must match trace generation).
    pub salt: u64,
    /// Response-cache capacity and seed-cluster count.
    pub cache_capacity: usize,
    pub cache_clusters: u64,
    /// Policy for [`ServingSystem::submit_auto`]'s shared router.
    pub route: RoutePolicy,
    /// None = no background control loops (all knobs stay static).
    pub control: Option<ControlPlaneConfig>,
}

impl SystemConfig {
    pub fn new(repo_root: PathBuf) -> Self {
        SystemConfig {
            repo_root,
            exec_mode: ExecMode::DeviceBuffers,
            device: DeviceProfile::rtx4000_ada(),
            meter_mode: MeterMode::SimulatedFlops,
            controller: None,
            queue_capacity: 64,
            slo_latency: 0.25,
            salt: 0,
            cache_capacity: 4096,
            cache_clusters: 256,
            route: RoutePolicy::adaptive(50.0),
            control: None,
        }
    }

    pub fn with_controller(mut self, cfg: ControllerConfig) -> Self {
        self.controller = Some(cfg);
        self
    }

    pub fn with_route(mut self, route: RoutePolicy) -> Self {
        self.route = route;
        self
    }

    pub fn with_control(mut self, cfg: ControlPlaneConfig) -> Self {
        self.control = Some(cfg);
        self
    }
}

/// Per-submission options the v2 protocol carries (deadline + priority).
/// The zero value (`Default`) reproduces plain `submit` semantics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SubmitOptions {
    /// Absolute deadline on the system clock ([`ServingSystem::clock`]
    /// seconds). Expired at entry → the request is refused without work;
    /// expired at completion → the result is discarded as
    /// [`RuntimeError::DeadlineExceeded`] (the client has given up).
    pub deadline: Option<f64>,
    /// Milliseconds the caller granted (kept for the error payload).
    pub timeout_ms: u64,
    pub priority: Priority,
}

impl SubmitOptions {
    /// Build from a relative timeout: deadline = now + timeout_ms.
    pub fn with_timeout(now: f64, timeout_ms: u64, priority: Priority) -> Self {
        SubmitOptions {
            deadline: Some(now + timeout_ms as f64 / 1e3),
            timeout_ms,
            priority,
        }
    }
}

/// Result of serving one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferResult {
    pub request_id: u64,
    pub predicted: u32,
    pub confidence: f32,
    pub entropy: f32,
    /// End-to-end seconds inside the system.
    pub latency_secs: f64,
    /// Engine execute seconds (shared across the fused batch).
    pub exec_secs: f64,
    /// Bucket the execution used (0 for cache answers).
    pub bucket: usize,
    /// Joules attributed to this request.
    pub joules: f64,
    pub path: PathKind,
    /// J(x) and τ(t) at decision time (NaN when open loop).
    pub j: f64,
    pub tau: f64,
}

/// The full serving system.
pub struct ServingSystem {
    /// Declared first so the ticker thread stops before paths shut down.
    plane: Option<ControlPlane>,
    repo: Repository,
    direct: DirectPath,
    batched: HashMap<String, BatchedPath>,
    meter: Arc<EnergyMeter>,
    latency: Mutex<LatencyHistogram>,
    controller: Option<Arc<Mutex<AdmissionController>>>,
    cache: Mutex<ResponseCache>,
    metrics: Arc<WindowedMetrics>,
    router: Mutex<Router>,
    clock: SystemClock,
    cfg: SystemConfig,
}

impl ServingSystem {
    /// Boot the system: scan the repository, start the direct path (all
    /// models on one engine) and one batched path per servable model
    /// (batcher policy + instance count from its config.pbtxt).
    pub fn start(cfg: SystemConfig) -> Result<Self, RuntimeError> {
        let repo = Repository::scan(&cfg.repo_root)?;
        repo.validate()?;

        let all_dirs: Vec<PathBuf> = repo.entries.values().map(|e| e.dir.clone()).collect();
        let direct = DirectPath::start(all_dirs, cfg.exec_mode)?;

        let mut batched = HashMap::new();
        let mut delay_handles: Vec<(String, Adaptive<u64>)> = Vec::new();
        for (name, entry) in &repo.entries {
            if name == models::SCREENER {
                continue; // the screener serves inline on the direct engine
            }
            let policy = entry
                .config
                .as_ref()
                .map(BatcherPolicy::from_config)
                .unwrap_or_else(|| BatcherPolicy::immediate(entry.manifest.max_bucket()));
            delay_handles.push((name.clone(), policy.delay_handle()));
            let instances = entry.config.as_ref().map(|c| c.total_instances()).unwrap_or(1);
            batched.insert(
                name.clone(),
                BatchedPath::start(
                    entry.dir.clone(),
                    policy,
                    instances,
                    cfg.queue_capacity,
                    cfg.exec_mode,
                    cfg.salt,
                )?,
            );
        }

        let meter = Arc::new(EnergyMeter::new(cfg.device.clone(), cfg.meter_mode, 16.0));
        let controller = cfg
            .controller
            .clone()
            .map(|c| Arc::new(Mutex::new(AdmissionController::new(c))));
        let metrics = Arc::new(WindowedMetrics::new(64, 256));
        let router = Router::new(cfg.route.clone());
        let plane = cfg.control.as_ref().and_then(|pc| {
            Self::wire_control_plane(pc, &controller, &metrics, &router, &delay_handles)
        });
        Ok(ServingSystem {
            plane,
            repo,
            direct,
            batched,
            meter,
            latency: Mutex::new(LatencyHistogram::for_latency()),
            controller,
            cache: Mutex::new(ResponseCache::new(cfg.cache_capacity)),
            metrics,
            router: Mutex::new(router),
            clock: SystemClock::new(),
            cfg,
        })
    }

    /// Build and start the background control loops (Observe → Decide →
    /// Act) requested by `pc`. Returns None when nothing is enabled.
    fn wire_control_plane(
        pc: &ControlPlaneConfig,
        controller: &Option<Arc<Mutex<AdmissionController>>>,
        metrics: &Arc<WindowedMetrics>,
        router: &Router,
        delay_handles: &[(String, Adaptive<u64>)],
    ) -> Option<ControlPlane> {
        if !pc.any_enabled() {
            return None;
        }
        let mut plane = ControlPlane::new();

        // Freshness gate shared by the latency/energy signals: windowed
        // metrics are count-bounded, so after traffic stops they would
        // replay the last regime's values forever. A signal only counts
        // as observed when new events landed since the previous tick.
        let fresh_p95 = |metrics: &Arc<WindowedMetrics>| {
            let m = metrics.clone();
            let mut last_events = 0u64;
            move || {
                let ev = m.events();
                if ev == last_events {
                    return f64::NAN; // stale window: hold the output
                }
                last_events = ev;
                let p95 = m.snapshot().p95_latency;
                if p95 > 0.0 {
                    p95
                } else {
                    f64::NAN
                }
            }
        };

        // Adaptive τ: windowed admission rate → τ correction.
        if let (Some(tc), Some(ctrl)) = (&pc.adaptive_tau, controller) {
            let handle = ctrl.lock().unwrap().rate_correction_handle();
            let ctrl = ctrl.clone();
            let mut last = (0u64, 0u64); // (admitted, total) at previous tick
            let signal = move || {
                let s = ctrl.lock().unwrap().stats();
                let (d_admitted, d_total) = (s.admitted - last.0, s.total() - last.1);
                if d_total == 0 {
                    return f64::NAN; // no decisions since the last tick
                }
                last = (s.admitted, s.total());
                d_admitted as f64 / d_total as f64
            };
            let law = SetpointTracker::new(
                0.0,
                tc.target_admit_rate,
                tc.gain,
                -tc.max_correction,
                tc.max_correction,
            );
            plane.add_loop(ControlLoop::new(
                "tau_correction",
                Box::new(law),
                Box::new(signal),
                Box::new(move |v| handle.set(v)),
            ));
        }

        // AIMD batch delay: windowed p95 vs SLO → queue-delay window µs.
        // One loop per model, seeded from *its own* config.pbtxt delay, so
        // per-model tuning survives: the probe ceiling is 4× the configured
        // window (capped by max_us), and models configured with no window
        // (immediate policies, delay 0) are left alone — adaptivity must
        // not introduce delay where the operator asked for none.
        if let Some(dc) = &pc.adaptive_batch_delay {
            for (model, handle) in delay_handles.iter().filter(|(_, h)| h.get() > 0) {
                let configured = handle.get();
                let max_us = dc.max_us.min(configured.saturating_mul(4)).max(dc.min_us);
                let initial = configured.clamp(dc.min_us, max_us);
                let law = Aimd::new(
                    initial as f64,
                    dc.slo_p95_secs,
                    dc.increase_us as f64,
                    dc.decrease,
                    dc.min_us as f64,
                    max_us as f64,
                );
                let h = handle.clone();
                let apply = move |v: f64| h.set(v.max(0.0).round() as u64);
                plane.add_loop(ControlLoop::new(
                    format!("batch_delay_us.{model}"),
                    Box::new(law),
                    Box::new(fresh_p95(metrics)),
                    Box::new(apply),
                ));
            }
        }

        // AIMD router threshold: SLO pressure shifts the direct/batched
        // split toward the batched path (threshold drops).
        if let Some(rc) = &pc.adaptive_router {
            // +inf threshold means a pinned RoutePolicy: nothing to tune.
            if router.qps_threshold().is_finite() {
                let initial = router.qps_threshold().clamp(rc.min_qps, rc.max_qps);
                let law = Aimd::new(
                    initial,
                    rc.slo_p95_secs,
                    rc.increase_qps,
                    rc.decrease,
                    rc.min_qps,
                    rc.max_qps,
                );
                let handle = router.qps_threshold_handle();
                plane.add_loop(ControlLoop::new(
                    "router_qps_threshold",
                    Box::new(law),
                    Box::new(fresh_p95(metrics)),
                    Box::new(move |v| handle.set(v)),
                ));
            }
        }

        // Energy-budget pacing: windowed watts over budget → positive τ
        // correction.
        if let (Some(ec), Some(ctrl)) = (&pc.energy_budget, controller) {
            let handle = ctrl.lock().unwrap().energy_correction_handle();
            let m = metrics.clone();
            let mut last_events = 0u64;
            // Stale window ⇒ no inference ran ⇒ attributed draw is ~0 W:
            // report that (decaying the correction) rather than replaying
            // the last burst's watts and ratcheting τ upward while idle.
            let signal = move || {
                let ev = m.events();
                if ev == last_events {
                    return 0.0;
                }
                last_events = ev;
                m.snapshot().watts
            };
            let law = BudgetPacer::new(ec.budget_watts, ec.gain, 0.0, ec.max_correction);
            plane.add_loop(ControlLoop::new(
                "energy_tau_correction",
                Box::new(law),
                Box::new(signal),
                Box::new(move |v| handle.set(v)),
            ));
        }

        if plane.is_empty() {
            return None;
        }
        plane.start(Duration::from_secs_f64(pc.tick_secs.max(1e-3)));
        Some(plane)
    }

    pub fn repository(&self) -> &Repository {
        &self.repo
    }

    pub fn meter(&self) -> &EnergyMeter {
        &self.meter
    }

    pub fn clock(&self) -> &SystemClock {
        &self.clock
    }

    /// Recent P95 latency (s).
    pub fn p95(&self) -> f64 {
        self.latency.lock().unwrap().p95()
    }

    /// The windowed-metrics aggregator feeding the control loops.
    pub fn metrics(&self) -> &WindowedMetrics {
        &self.metrics
    }

    /// Names of the running control loops (empty when no plane).
    pub fn control_loop_names(&self) -> Vec<String> {
        self.plane.as_ref().map(|p| p.loop_names()).unwrap_or_default()
    }

    /// Introspection snapshot of every control loop (name, law, output).
    pub fn control_loop_states(&self) -> Vec<crate::control::LoopState> {
        self.plane.as_ref().map(|p| p.loop_states()).unwrap_or_default()
    }

    /// Scheduler queue capacity per batched path (the C(x) normaliser).
    pub fn queue_capacity(&self) -> usize {
        self.cfg.queue_capacity
    }

    /// Whether a model is servable on the batched path (has a batcher).
    pub fn has_batched_path(&self, model: &str) -> bool {
        self.batched.contains_key(model)
    }

    /// Whether the background control plane is ticking.
    pub fn control_plane_running(&self) -> bool {
        self.plane.as_ref().map(|p| p.running()).unwrap_or(false)
    }

    /// Recent arrival rate seen by the shared router.
    pub fn router_qps(&self) -> f64 {
        self.router.lock().unwrap().recent_qps()
    }

    /// The router's QPS threshold currently in force (+inf when pinned).
    pub fn router_qps_threshold(&self) -> f64 {
        self.router.lock().unwrap().qps_threshold()
    }

    /// Controller admission stats (None when open loop).
    pub fn controller_stats(&self) -> Option<crate::controller::admission::AdmissionStats> {
        self.controller.as_ref().map(|c| c.lock().unwrap().stats())
    }

    /// Restart the controller's τ(t) epoch at "now" — the paper's folding
    /// restarts when the landscape changes (deploys, model swaps); also
    /// lets benchmarks align τ0 with their first request.
    pub fn restart_controller_epoch(&self) {
        if let Some(c) = &self.controller {
            let now = self.clock.now();
            c.lock().unwrap().restart_epoch(now);
        }
    }

    /// Scheduler queue depth of a model's batched path.
    pub fn queue_depth(&self, model: &str) -> usize {
        self.batched.get(model).map(|p| p.queue_depth()).unwrap_or(0)
    }

    /// Execute a request on an explicit path, bypassing the controller
    /// (the Table II benchmark mode).
    pub fn infer_on(&self, req: &Request, path: PathKind) -> Result<InferResult, RuntimeError> {
        let t0 = self.clock.now();
        // Arrival is observed at entry, not completion: concurrent workers
        // finishing out of order must not scramble the rate window.
        self.metrics.record_arrival(t0);
        let entry = self.repo.get(&req.model)?;
        let (out, stats) = match path {
            PathKind::Direct => {
                let input = inputgen::batch_for(&entry.manifest, &[req.seed], self.cfg.salt);
                self.direct.infer(&req.model, input)?
            }
            PathKind::Batched => {
                let p = self
                    .batched
                    .get(&req.model)
                    .ok_or_else(|| RuntimeError::UnknownModel(req.model.clone()))?;
                p.infer(req.seed)?
            }
            PathKind::CacheSkip => {
                return Err(RuntimeError::InputMismatch("cannot force cache path".into()))
            }
        };
        let latency = self.clock.now() - t0;
        self.latency.lock().unwrap().record(latency);
        self.metrics.record_latency(latency);
        // Energy attribution: per-item share of the executed bucket, plus
        // (batched path) the scheduler wait burned at idle power — this is
        // the per-request energy premium Triton shows at batch=1 in
        // Table II while the device sits idle inside the queue window.
        let flops_item = entry.manifest.flops_per_item(stats.bucket.max(1));
        let reading = self.meter.record(flops_item, stats.exec_secs / stats.bucket.max(1) as f64);
        self.metrics.record_joules(self.clock.now(), reading.joules);
        if path == PathKind::Batched {
            self.meter.record_idle((latency - stats.exec_secs).max(0.0));
        }
        Ok(InferResult {
            request_id: req.id,
            predicted: out.predicted(0),
            confidence: out.confidence(0),
            entropy: out.entropy[0],
            latency_secs: latency,
            exec_secs: stats.exec_secs,
            bucket: stats.bucket,
            joules: reading.joules,
            path,
            j: f64::NAN,
            tau: f64::NAN,
        })
    }

    /// The closed-loop entry point (Fig. 2): screener → J(x) vs τ(t) →
    /// route or answer from cache.
    pub fn submit(&self, req: &Request, prefer: PathKind) -> Result<InferResult, RuntimeError> {
        let Some(ctrl) = &self.controller else {
            return self.infer_on(req, prefer);
        };
        let t0 = self.clock.now();

        // 1. Cheap L(x) estimate: screener pass on the direct engine.
        let entry = self.repo.get(&req.model)?;
        let scr_manifest = self.repo.get(models::SCREENER).ok().map(|e| e.manifest.clone());
        let (scr_entropy, scr_pred, scr_conf, scr_exec) = match &scr_manifest {
            Some(m) if entry.manifest.input_kind == crate::runtime::InputKind::Tokens => {
                let input = inputgen::batch_for(m, &[req.seed], self.cfg.salt);
                let (o, s) = self.direct.infer(models::SCREENER, input)?;
                (o.entropy[0] as f64, o.predicted(0), o.confidence(0), s.exec_secs)
            }
            // Vision path has no screener model: use the latent-confidence
            // entropy the request carries (cache-estimate stand-in).
            _ => (req.entropy(), req.label, req.confidence as f32, 0.0),
        };

        // 2. Assemble CostInputs from the live feedback signals.
        // Spike reference = 2x nominal per-request joules: the steady state
        // sits at e_norm ~= 0.5 and a genuine energy spike drives it to 0.
        let energy_ref = 2.0 * self.cfg.device.exec_energy(entry.manifest.flops_per_item(1));
        let x = CostInputs {
            entropy: scr_entropy,
            max_entropy: (entry.manifest.classes as f64).ln(),
            energy_ewma: self.meter.ewma_joules(0.0),
            energy_ref,
            queue_depth: self.queue_depth(&req.model),
            queue_capacity: self.cfg.queue_capacity,
            p95_latency: self.p95(),
            slo_latency: self.cfg.slo_latency,
        };

        // 3. Decide.
        let decision = ctrl.lock().unwrap().decide(&x, t0);
        match decision {
            Decision::Admit { j, tau } => {
                let mut r = self.infer_on(req, prefer)?;
                r.j = j;
                r.tau = tau;
                // populate cache so future skips can answer
                let sig =
                    ResponseCache::signature(&req.model, req.seed, self.cfg.cache_clusters);
                self.cache.lock().unwrap().put(
                    sig,
                    CachedResponse { label: r.predicted, confidence: r.confidence as f64 },
                );
                Ok(r)
            }
            Decision::Skip { j, tau, .. } => {
                // Answer from cache / screener argmax (Algorithm 1 line 9).
                let sig =
                    ResponseCache::signature(&req.model, req.seed, self.cfg.cache_clusters);
                let cached = self.cache.lock().unwrap().get(sig);
                let (label, conf) = match cached {
                    Some(c) => (c.label, c.confidence as f32),
                    None => (scr_pred, scr_conf),
                };
                let latency = self.clock.now() - t0;
                self.latency.lock().unwrap().record(latency);
                // Arrival recorded here (not at submit entry) so admitted
                // requests are not double-counted by infer_on's tap; the
                // recorded instant is still t0, and the rate window clamps
                // any cross-thread ordering races.
                self.metrics.record_arrival(t0);
                self.metrics.record_latency(latency);
                // Energy: only the screener pass.
                let scr_flops = scr_manifest.as_ref().map(|m| m.flops_per_item(1)).unwrap_or(0.0);
                let reading = self.meter.record(scr_flops, scr_exec);
                self.metrics.record_joules(self.clock.now(), reading.joules);
                Ok(InferResult {
                    request_id: req.id,
                    predicted: label,
                    confidence: conf,
                    entropy: scr_entropy as f32,
                    latency_secs: latency,
                    exec_secs: scr_exec,
                    bucket: 0,
                    joules: reading.joules,
                    path: PathKind::CacheSkip,
                    j,
                    tau,
                })
            }
        }
    }

    /// Fully closed-loop entry point: the shared router (arrival-rate
    /// estimator + adaptive QPS threshold) picks the path, then the
    /// admission controller decides as in [`ServingSystem::submit`].
    pub fn submit_auto(&self, req: &Request) -> Result<InferResult, RuntimeError> {
        let path = self.router.lock().unwrap().route(self.clock.now());
        self.submit(req, path)
    }

    /// The v2-protocol entry point: `submit`/`submit_auto` semantics plus
    /// per-request deadline and priority.
    ///
    /// * `prefer = None` routes through the shared router (auto).
    /// * Deadline: checked before any work (an already-expired request is
    ///   refused for free) and again at completion — a result the caller
    ///   can no longer use is reported as `DeadlineExceeded`, and the
    ///   paper's accounting still charges the joules it burned.
    /// * Priority: `High` bypasses the admission controller (the request
    ///   is always executed); `Low` is shed with `Backpressure` once the
    ///   model's scheduler queue passes ~80% occupancy, before it can
    ///   displace normal work.
    pub fn submit_opts(
        &self,
        req: &Request,
        prefer: Option<PathKind>,
        opts: &SubmitOptions,
    ) -> Result<InferResult, RuntimeError> {
        let t0 = self.clock.now();
        // Elapsed is measured from when the budget started (deadline −
        // timeout), not from this call's entry: a later batch item that
        // arrives here already expired must not report "0 ms elapsed".
        let deadline_err = |now: f64| {
            let start = opts
                .deadline
                .map(|d| d - opts.timeout_ms as f64 / 1e3)
                .unwrap_or(t0);
            RuntimeError::DeadlineExceeded {
                elapsed_ms: ((now - start).max(0.0) * 1e3).round() as u64,
                timeout_ms: opts.timeout_ms,
            }
        };
        if let Some(d) = opts.deadline {
            if t0 >= d {
                return Err(deadline_err(t0));
            }
        }
        if opts.priority == Priority::Low {
            // Low-priority shed: refuse before enqueueing once the queue
            // sits above 4/5 of capacity (cheap head-room guard).
            let depth = self.queue_depth(&req.model);
            if depth * 5 >= self.cfg.queue_capacity * 4 {
                return Err(RuntimeError::Backpressure(req.model.clone()));
            }
        }
        let mut path = match prefer {
            Some(p) => p,
            None => self.router.lock().unwrap().route(t0),
        };
        // A model with no batcher cannot serve the batched path: pinning
        // "batched" there is a client error (not MODEL_NOT_FOUND — the
        // model exists), and the model-blind auto router falls back to
        // direct.
        if path == PathKind::Batched && !self.batched.contains_key(&req.model) {
            // A model missing from the repository entirely is still
            // UnknownModel, not a claim about its (nonexistent) paths.
            self.repo.get(&req.model)?;
            if prefer.is_some() {
                return Err(RuntimeError::InputMismatch(format!(
                    "model {:?} has no batched path",
                    req.model
                )));
            }
            path = PathKind::Direct;
        }
        let result = if opts.priority == Priority::High {
            // High priority bypasses the admission skip entirely.
            self.infer_on(req, path)
        } else {
            self.submit(req, path)
        };
        match (result, opts.deadline) {
            (Ok(r), Some(d)) => {
                let now = self.clock.now();
                if now > d {
                    Err(deadline_err(now))
                } else {
                    Ok(r)
                }
            }
            (r, _) => r,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::threshold::ThresholdSchedule;
    use crate::workload::stream::{RequestStream, StreamConfig};

    fn repo_root() -> Option<PathBuf> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    fn requests(n: usize, model: &str) -> Vec<Request> {
        let mut s = RequestStream::new(
            StreamConfig { model: model.to_string(), ..Default::default() },
            11,
        );
        (0..n).map(|i| s.next_request(i as f64 * 0.01)).collect()
    }

    #[test]
    fn open_loop_dual_path_works() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(3, models::DISTILBERT);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert_eq!(d.path, PathKind::Direct);
            assert!(d.latency_secs > 0.0);
            assert!(d.joules > 0.0);
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(b.path, PathKind::Batched);
            assert!((0..2).contains(&(d.predicted as i32)));
            assert_eq!(d.predicted, b.predicted, "paths agree on the answer");
        }
        assert!(sys.meter().total_joules() > 0.0);
        assert!(sys.p95() > 0.0);
    }

    #[test]
    fn closed_loop_skips_and_admits() {
        let Some(root) = repo_root() else { return };
        // Strict constant τ: plenty of skips on confident requests.
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.95 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        let reqs = requests(20, models::DISTILBERT);
        let mut skipped = 0;
        for r in &reqs {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            if res.path == PathKind::CacheSkip {
                skipped += 1;
                assert_eq!(res.bucket, 0);
                assert!(res.j < res.tau);
            }
        }
        let stats = sys.controller_stats().unwrap();
        assert_eq!(stats.total(), 20);
        assert_eq!(stats.skipped, skipped);
        assert!(skipped > 0, "strict τ must skip something");
    }

    #[test]
    fn permissive_controller_admits_everything() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.0 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        for r in &requests(5, models::DISTILBERT) {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            assert_ne!(res.path, PathKind::CacheSkip);
            assert!(res.j >= res.tau);
        }
        assert_eq!(sys.controller_stats().unwrap().admitted, 5);
    }

    #[test]
    fn control_plane_boots_and_serves() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root)
            .with_controller(ControllerConfig {
                weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
                schedule: ThresholdSchedule::Constant { tau: 0.5 },
                respond_from_cache: true,
            })
            .with_control(
                crate::control::ControlPlaneConfig {
                    tick_secs: 0.005,
                    ..Default::default()
                }
                .with_adaptive_tau(0.5)
                .with_adaptive_batch_delay(0.25)
                .with_adaptive_router(0.25)
                .with_energy_budget(100.0),
            );
        let sys = ServingSystem::start(cfg).unwrap();
        assert!(sys.control_plane_running());
        let names = sys.control_loop_names();
        assert!(names.iter().any(|n| n == "tau_correction"), "{names:?}");
        assert!(names.iter().any(|n| n == "router_qps_threshold"), "{names:?}");
        assert!(names.iter().any(|n| n == "energy_tau_correction"), "{names:?}");
        // batch_delay_us.<model> loops appear once per model whose config
        // sets a nonzero queue-delay window, so their presence depends on
        // the artifacts' config.pbtxt files — not asserted here.

        for r in &requests(10, models::DISTILBERT) {
            let res = sys.submit_auto(r).unwrap();
            assert!(res.latency_secs >= 0.0);
        }
        assert!(sys.metrics().events() >= 10);
        assert!(sys.router_qps() > 0.0);
        // let the ticker observe the traffic at least once
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(sys.controller_stats().unwrap().total(), 10);
    }

    #[test]
    fn submit_opts_honors_deadline_and_priority() {
        let Some(root) = repo_root() else { return };
        // Strict constant τ so Normal-priority requests mostly skip.
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.95 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        let reqs = requests(4, models::DISTILBERT);

        // Already-expired deadline: refused before any work.
        let expired = SubmitOptions {
            deadline: Some(0.0),
            timeout_ms: 0,
            priority: Priority::Normal,
        };
        let err = sys.submit_opts(&reqs[0], Some(PathKind::Direct), &expired).unwrap_err();
        assert!(matches!(err, RuntimeError::DeadlineExceeded { .. }), "{err}");

        // High priority bypasses the admission skip even under strict τ.
        let high = SubmitOptions { priority: Priority::High, ..Default::default() };
        let r = sys.submit_opts(&reqs[1], Some(PathKind::Direct), &high).unwrap();
        assert_ne!(r.path, PathKind::CacheSkip);

        // A generous deadline passes through (auto-routed).
        let opts = SubmitOptions::with_timeout(sys.clock().now(), 30_000, Priority::Normal);
        assert!(sys.submit_opts(&reqs[2], None, &opts).is_ok());

        // Default options reproduce submit() semantics.
        let dflt = SubmitOptions::default();
        assert!(sys.submit_opts(&reqs[3], Some(PathKind::Direct), &dflt).is_ok());

        // Pinning "batched" on a model with no batcher is an input error
        // (the model exists — it must not read as MODEL_NOT_FOUND).
        if !sys.has_batched_path(models::SCREENER) {
            let req = Request::external(99, models::SCREENER, 1, sys.clock().now());
            let err = sys
                .submit_opts(&req, Some(PathKind::Batched), &SubmitOptions::default())
                .unwrap_err();
            assert!(matches!(err, RuntimeError::InputMismatch(_)), "{err}");
        }
    }

    #[test]
    fn no_control_config_means_no_plane() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        assert!(!sys.control_plane_running());
        assert!(sys.control_loop_names().is_empty());
    }

    #[test]
    fn resnet_serves_on_both_paths() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(2, models::RESNET);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert!((0..10).contains(&(d.predicted as i32)));
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(d.predicted, b.predicted);
        }
    }
}
