//! The closed-loop serving system (paper Fig. 2): controller in front of
//! the dual-path stack, with energy/latency feedback wired back into the
//! next admission decision.
//!
//! Since the lifecycle redesign the system serves from an atomically
//! swapped **snapshot** of per-model, per-version handles instead of a
//! boot-time repository scan: [`crate::runtime::registry::ModelRegistry`]
//! owns the `Unloaded → Loading → Ready → Unloading` state machines and
//! this module owns the resources — each `Ready` version gets its own
//! direct engine and (screener excepted) batched path, attached by
//! [`ServingSystem::load_model`] and detached by
//! [`ServingSystem::unload_model`] without restarting the server. The
//! hot path resolves `Arc<VersionHandle>`s from the snapshot (one brief
//! uncontended read-lock, never held across inference); in-flight
//! requests keep their handle's engines alive through the `Arc` itself,
//! so an unload drains naturally — new requests see a typed
//! [`RuntimeError::ModelUnavailable`] (HTTP 503) the moment the swap
//! lands.
//!
//! Since the async-lifecycle redesign the engine work itself runs on a
//! [`LifecycleExecutor`]: `load_model_async` marks the target versions
//! `Loading` and returns immediately (HTTP 202) while executor threads
//! spawn the engines and swap the snapshot; `unload_model_async` swaps
//! the version out inline (new requests 503 at once) and hands the
//! bounded Arc-refcount drain to the executor. Same-model jobs
//! serialise, different models load concurrently, and an unload of a
//! version whose load is still *queued* cancels the job outright. The
//! synchronous `load_model` / `unload_model` wrappers enqueue the same
//! jobs and block on their completion (boot, `?wait=true`, tests).
//!
//! Beyond the per-request loop, the system can boot a
//! [`ControlPlane`](crate::control::ControlPlane) from
//! [`ControlPlaneConfig`]: a background tick that reads the
//! [`WindowedMetrics`] aggregator and drives the adaptive knobs — τ
//! corrections, batcher queue-delay windows, the router's QPS threshold,
//! and one energy-budget pacer **per loaded batched path**
//! (`energy_budget.<model>/<version>`), each attached and detached with
//! its version.
//!
//! Since the replica-set redesign a `Ready` version owns **N engine
//! replicas** ([`Replica`]: one direct engine + one batcher each)
//! instead of a single direct/batched pair. The hot path schedules over
//! them power-of-two-choices ([`p2c_indices`]) on per-replica in-flight
//! and queue-depth counters; the per-version
//! `replica_scaler.<model>/<version>` loop (a
//! [`ReplicaScaler`](crate::control::ReplicaScaler) law) moves a target
//! replica count with the windowed demand, batched-path p95 pressure,
//! and the energy-budget throttle, and acts through the
//! [`LifecycleExecutor`] (`JobKind::Scale`) so replica spawn/retire
//! inherits per-model serialization, cancellation, and panic
//! containment. Scale-to-zero retires the last replica after the idle
//! window; the next request **cold-starts** — it enqueues the spawn and
//! queues behind it instead of 503ing (`gf_cold_starts_total`, with the
//! wait recorded separately as `gf_cold_start_ms.<model>.<version>`).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock, RwLock, Weak};
use std::time::{Duration, Instant};

use crate::batching::policy::BatcherPolicy;
use crate::configsys::ModelConfig;
use crate::control::law::{Aimd, BudgetPacer, CarbonPacer, QuotaScaler, ReplicaScaler, SetpointTracker};
use crate::control::{
    Adaptive, ControlLoop, ControlPlane, ControlPlaneConfig, EnergyWindow, WindowedMetrics,
};
use crate::controller::cache::{CachedResponse, ResponseCache};
use crate::controller::cost::CostInputs;
use crate::controller::{AdmissionController, ControllerConfig, Decision};
use crate::energy::carbon::{CarbonIntensityTrace, CarbonLedger, WORLD_KG_CO2_PER_KWH};
use crate::energy::meter::{EnergyMeter, MeterMode};
use crate::energy::profile::DeviceProfile;
use crate::pipeline::coalesce::{
    CoalescedAnswer, Follower, FollowerVerdict, Join, ShardedResponseCache, SingleflightTable,
};
use crate::models;
use crate::models::inputgen;
use crate::qos::{QosConfig, QosLayer};
use crate::router::{PathKind, RoutePolicy, Router};
use crate::runtime::engine::{ExecMode, ExecStats};
use crate::runtime::lifecycle::{JobKind, JobSpec, LifecycleExecutor};
use crate::runtime::manifest::ModelManifest;
use crate::runtime::registry::{LoadStats, ModelRegistry, VersionInfo};
use crate::runtime::tensor::OutputBatch;
use crate::runtime::RuntimeError;
use crate::stats::LatencyHistogram;
use crate::util::{Clock, SystemClock};
use crate::workload::stream::{Priority, Request};

use super::batched::BatchedPath;
use super::direct::DirectPath;

/// How long an unload waits for in-flight requests to finish before
/// letting the last request thread tear the paths down on its own.
const UNLOAD_DRAIN_TIMEOUT: Duration = Duration::from_secs(5);

/// Lifecycle-executor sizing: enough workers that several models load
/// concurrently, a queue bound that refuses runaway operator scripts
/// with `BACKPRESSURE` instead of buffering them forever.
const LIFECYCLE_WORKERS: usize = 4;
const LIFECYCLE_QUEUE_CAP: usize = 64;

/// How long retiring one replica waits for its in-flight requests
/// before letting the last request thread tear the engines down on its
/// own (same contract as [`UNLOAD_DRAIN_TIMEOUT`], per replica).
const REPLICA_DRAIN_TIMEOUT: Duration = Duration::from_secs(5);

/// Upper bound a cold-start request waits for the spawn it triggered.
/// Generous: a cold start pays an engine compile, and timing out early
/// would turn a slow-but-succeeding spawn into a spurious 503.
const COLD_START_TIMEOUT: Duration = Duration::from_secs(30);

/// SplitMix64 finalizer — the replica scheduler's ticket hash: one
/// multiply-xor-shift cascade per pick, no RNG state beyond the ticket
/// counter itself.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Power-of-two-choices candidate pair for a replica set of size `n`
/// (`n ≥ 1`): hash the ticket once, derive two indices from the low and
/// high halves. Public so `benches/micro_hotpath.rs` and the perf gate
/// can measure the scheduler read without spinning up engines.
#[inline]
pub fn p2c_indices(ticket: u64, n: usize) -> (usize, usize) {
    let h = splitmix64(ticket);
    ((h as u32 as usize) % n, ((h >> 32) as usize) % n)
}

/// Model-control mode (Triton's `--model-control-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ModelControl {
    /// Load every model's policy versions at boot; the repository API
    /// can still swap versions afterwards.
    #[default]
    None,
    /// Start with nothing loaded; models serve only after an explicit
    /// `POST /v2/repository/models/{name}/load`.
    Explicit,
}

impl ModelControl {
    pub fn parse(s: &str) -> Option<ModelControl> {
        match s {
            "none" => Some(ModelControl::None),
            "explicit" => Some(ModelControl::Explicit),
            _ => None,
        }
    }
}

/// System configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub repo_root: PathBuf,
    pub exec_mode: ExecMode,
    /// Device whose power profile attributes energy.
    pub device: DeviceProfile,
    pub meter_mode: MeterMode,
    /// None = open loop (no admission control).
    pub controller: Option<ControllerConfig>,
    /// Scheduler queue capacity per model (C(x) normaliser).
    pub queue_capacity: usize,
    /// Latency SLO for the congestion proxy (s).
    pub slo_latency: f64,
    /// Payload salt (must match trace generation).
    pub salt: u64,
    /// Response-cache capacity and seed-cluster count.
    pub cache_capacity: usize,
    pub cache_clusters: u64,
    /// Policy for [`ServingSystem::submit_auto`]'s shared router.
    pub route: RoutePolicy,
    /// None = no background control loops (all knobs stay static).
    pub control: Option<ControlPlaneConfig>,
    /// Whether models load at boot or only via the repository API.
    pub model_control: ModelControl,
    /// Honour test hooks in the repository (the `slow_load_ms` file
    /// that stalls an engine spawn). Off by default so a stray file in
    /// a production repo can never slow real loads; lifecycle tests
    /// opt in.
    pub load_hooks: bool,
    /// Per-tenant QoS admission (GCRA quotas + retry budgets). Always
    /// on; the defaults are generous enough that single-tenant
    /// deployments never notice it.
    pub qos: QosConfig,
    /// Time-varying grid carbon intensity the carbon pacer observes.
    /// None with a carbon pacer enabled falls back to the world-average
    /// constant; without a pacer the trace is inert.
    pub carbon_trace: Option<CarbonIntensityTrace>,
}

impl SystemConfig {
    pub fn new(repo_root: PathBuf) -> Self {
        SystemConfig {
            repo_root,
            exec_mode: ExecMode::DeviceBuffers,
            device: DeviceProfile::rtx4000_ada(),
            meter_mode: MeterMode::SimulatedFlops,
            controller: None,
            queue_capacity: 64,
            slo_latency: 0.25,
            salt: 0,
            cache_capacity: 4096,
            cache_clusters: 256,
            route: RoutePolicy::adaptive(50.0),
            control: None,
            model_control: ModelControl::None,
            load_hooks: false,
            qos: QosConfig::default(),
            carbon_trace: None,
        }
    }

    pub fn with_controller(mut self, cfg: ControllerConfig) -> Self {
        self.controller = Some(cfg);
        self
    }

    pub fn with_route(mut self, route: RoutePolicy) -> Self {
        self.route = route;
        self
    }

    pub fn with_control(mut self, cfg: ControlPlaneConfig) -> Self {
        self.control = Some(cfg);
        self
    }

    pub fn with_model_control(mut self, mc: ModelControl) -> Self {
        self.model_control = mc;
        self
    }

    pub fn with_load_hooks(mut self) -> Self {
        self.load_hooks = true;
        self
    }

    pub fn with_qos(mut self, qos: QosConfig) -> Self {
        self.qos = qos;
        self
    }

    pub fn with_carbon_trace(mut self, trace: CarbonIntensityTrace) -> Self {
        self.carbon_trace = Some(trace);
        self
    }
}

/// Per-submission options the v2 protocol carries (deadline, priority,
/// target version). The zero value (`Default`) reproduces plain
/// `submit` semantics on the default (highest ready) version.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SubmitOptions {
    /// Absolute deadline on the system clock ([`ServingSystem::clock`]
    /// seconds). Expired at entry → the request is refused without work;
    /// expired at completion → the result is discarded as
    /// [`RuntimeError::DeadlineExceeded`] (the client has given up).
    pub deadline: Option<f64>,
    /// Milliseconds the caller granted (kept for the error payload).
    pub timeout_ms: u64,
    pub priority: Priority,
    /// Pin a specific model version (`/v2/models/{m}/versions/{v}/infer`);
    /// None = the highest ready version.
    pub version: Option<u64>,
}

impl SubmitOptions {
    /// Build from a relative timeout: deadline = now + timeout_ms.
    pub fn with_timeout(now: f64, timeout_ms: u64, priority: Priority) -> Self {
        SubmitOptions {
            deadline: Some(now + timeout_ms as f64 / 1e3),
            timeout_ms,
            priority,
            version: None,
        }
    }
}

/// Who actually produced a response's answer — `bucket: 0` alone cannot
/// distinguish a cache answer from a bucket-0 execution, and a coalesced
/// follower looks like neither. Serialized on the wire as the `served`
/// field (docs/API.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Served {
    /// A real engine execution ran for this request.
    Model,
    /// Admission skipped inference; answered from the response cache
    /// (or the screener's argmax on a cache miss).
    Cache,
    /// A concurrent duplicate: answered from the in-flight leader's
    /// result without executing (joules saved).
    Coalesced,
}

impl Served {
    pub fn as_str(&self) -> &'static str {
        match self {
            Served::Model => "model",
            Served::Cache => "cache",
            Served::Coalesced => "coalesced",
        }
    }
}

/// Result of serving one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferResult {
    pub request_id: u64,
    pub predicted: u32,
    pub confidence: f32,
    pub entropy: f32,
    /// End-to-end seconds inside the system.
    pub latency_secs: f64,
    /// Engine execute seconds (shared across the fused batch).
    pub exec_secs: f64,
    /// Bucket the execution used (0 for cache answers).
    pub bucket: usize,
    /// Joules attributed to this request.
    pub joules: f64,
    pub path: PathKind,
    /// J(x) and τ(t) at decision time (NaN when open loop).
    pub j: f64,
    pub tau: f64,
    /// Who produced the answer (engine / cache / coalesced leader).
    pub served: Served,
}

/// One engine replica: a direct engine plus (for batched-capable
/// models) its own dynamic batcher. A version's replica set holds N of
/// these; the scheduler spreads requests over them power-of-two-choices
/// on [`Replica::load`].
pub struct Replica {
    direct: DirectPath,
    batched: Option<BatchedPath>,
    /// Requests currently executing on this replica (either path).
    in_flight: AtomicUsize,
}

impl Replica {
    /// Scheduler load signal: in-flight executions plus queued batcher
    /// work. Two relaxed atomic reads — measured as `sched_read_ns` in
    /// the perf gate.
    fn load(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
            + self.batched.as_ref().map(|b| b.queue_depth()).unwrap_or(0)
    }
}

/// RAII in-flight marker: holding one pins the replica (the `Arc`
/// clone) and keeps its `in_flight` count honest across early returns
/// and panics.
struct InFlightGuard(Arc<Replica>);

impl InFlightGuard {
    fn new(replica: Arc<Replica>) -> Self {
        replica.in_flight.fetch_add(1, Ordering::Relaxed);
        InFlightGuard(replica)
    }

    fn replica(&self) -> &Replica {
        &self.0
    }
}

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One `Ready` model version's attached serving resources — since the
/// replica-set redesign, a **set of N [`Replica`]s** behind a
/// power-of-two-choices scheduler rather than a single engine pair.
/// In-flight requests hold an `Arc` clone (of the handle and of their
/// replica), so engines and batcher threads survive an unload until the
/// last request completes — that `Arc` refcount *is* the drain
/// mechanism.
///
/// A version can hold **zero** replicas (scale-to-zero): it stays in
/// the serving snapshot, and the next request cold-starts a replica
/// through the lifecycle executor instead of 503ing.
pub struct VersionHandle {
    model: String,
    version: u64,
    manifest: ModelManifest,
    config: Option<ModelConfig>,
    /// The replica set, snapshot-swapped whole (readers clone the `Arc`
    /// once per pick and never hold the lock across an inference).
    replicas: RwLock<Arc<Vec<Arc<Replica>>>>,
    /// Replica count the scaler (or an operator override) wants; the
    /// executor-serialized reconcile walks the set toward it.
    target_replicas: AtomicUsize,
    /// Monotonic pick counter feeding [`p2c_indices`].
    sched_ticket: AtomicU64,
    /// Whether this version's replicas carry a batcher (false only for
    /// the screener, which serves inline on its direct engine).
    batched_capable: bool,
    /// Batcher policy cloned into every replica. Clones share the
    /// `Adaptive` queue-delay cell, so one AIMD loop drives every
    /// replica's batcher window.
    policy: Option<BatcherPolicy>,
    /// Engine instances per replica batcher (from the model config).
    instances: usize,
    /// Version directory, kept so a reconcile can spawn new replicas.
    dir: PathBuf,
    /// Cold-start election: the one request that wins the CAS counts
    /// the cold start and enqueues the spawn; everyone else just waits.
    cold_spawn: AtomicBool,
    /// Bumped when a reconcile (or its cancellation) finishes. A
    /// cold-start waiter that sees two bumps without a replica knows
    /// its spawn genuinely failed.
    cold_gen: AtomicU64,
    /// Requests parked in a cold-start wait; counted into
    /// [`VersionHandle::in_flight`] so the scaler sees their demand.
    cold_waiting: AtomicUsize,
    stats: LoadStats,
    /// Batcher queue-delay handle, kept for control-loop attach.
    delay_handle: Option<Adaptive<u64>>,
    /// Per-model windowed energy (feeds the `energy_budget.<model>/<v>`
    /// pacer) and its freshness counter.
    energy: Mutex<EnergyWindow>,
    energy_events: AtomicU64,
    /// τ bias the per-model pacer writes; read per decision.
    energy_correction: Adaptive<f64>,
    /// Set when the version leaves the serving snapshot (unload).
    /// In-flight stragglers check it before writing the response cache:
    /// a request that outlives the drain timeout must not re-populate
    /// entries the unload just invalidated (a reload would inherit
    /// them).
    retired: AtomicBool,
}

impl VersionHandle {
    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    pub fn config(&self) -> Option<&ModelConfig> {
        self.config.as_ref()
    }

    pub fn load_stats(&self) -> LoadStats {
        self.stats
    }

    /// Whether this version's replicas carry a batched path. Note this
    /// is a property of the *version*, not of the current replica
    /// count: it stays true at zero replicas (the batcher comes back
    /// with the cold-started replica).
    pub fn has_batched(&self) -> bool {
        self.batched_capable
    }

    /// Ready replicas currently serving.
    pub fn replica_count(&self) -> usize {
        self.replicas.read().unwrap().len()
    }

    /// Replica count the scaler currently wants.
    pub fn target_replicas(&self) -> usize {
        self.target_replicas.load(Ordering::SeqCst)
    }

    /// Requests executing on (or cold-start-waiting for) this version.
    pub fn in_flight(&self) -> usize {
        let replicas = self.replicas.read().unwrap().clone();
        replicas.iter().map(|r| r.in_flight.load(Ordering::Relaxed)).sum::<usize>()
            + self.cold_waiting.load(Ordering::SeqCst)
    }

    /// Scheduler-queue depth summed over the replica set (0 for
    /// batcher-less models and at zero replicas).
    pub fn queue_depth(&self) -> usize {
        let replicas = self.replicas.read().unwrap().clone();
        replicas
            .iter()
            .map(|r| r.batched.as_ref().map(|b| b.queue_depth()).unwrap_or(0))
            .sum()
    }

    /// Power-of-two-choices pick: hash the ticket, probe two replicas,
    /// take the lighter. `None` at zero replicas (cold-start
    /// territory). The degenerate sizes skip the hash entirely.
    fn pick_replica(&self) -> Option<Arc<Replica>> {
        let replicas = self.replicas.read().unwrap().clone();
        match replicas.len() {
            0 => None,
            1 => Some(replicas[0].clone()),
            n => {
                let ticket = self.sched_ticket.fetch_add(1, Ordering::Relaxed);
                let (i, j) = p2c_indices(ticket, n);
                let pick =
                    if replicas[j].load() < replicas[i].load() { &replicas[j] } else { &replicas[i] };
                Some(pick.clone())
            }
        }
    }

    /// Clone-swap one replica in (reconcile only — executor-serialized
    /// per model, so no two writers race).
    fn push_replica(&self, replica: Arc<Replica>) {
        let mut guard = self.replicas.write().unwrap();
        let mut next = (**guard).clone();
        next.push(replica);
        *guard = Arc::new(next);
    }

    /// Clone-swap the newest replica out; the caller owns the drain.
    fn pop_replica(&self) -> Option<Arc<Replica>> {
        let mut guard = self.replicas.write().unwrap();
        let mut next = (**guard).clone();
        let r = next.pop()?;
        *guard = Arc::new(next);
        Some(r)
    }
}

/// Decrements `cold_waiting` on every exit path of a cold-start wait.
struct ColdWaitGuard<'a>(&'a VersionHandle);

impl Drop for ColdWaitGuard<'_> {
    fn drop(&mut self) {
        self.0.cold_waiting.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Immutable serving view: model → version → handle. Swapped whole on
/// every load/unload; readers clone the `Arc` once and never block a
/// writer during inference.
#[derive(Default, Clone)]
struct Snapshot {
    models: BTreeMap<String, BTreeMap<u64, Arc<VersionHandle>>>,
}

impl Snapshot {
    fn resolve(&self, model: &str, version: Option<u64>) -> Option<Arc<VersionHandle>> {
        let versions = self.models.get(model)?;
        match version {
            Some(v) => versions.get(&v).cloned(),
            // Default version = highest ready (Triton's "latest").
            None => versions.values().next_back().cloned(),
        }
    }
}

/// The deadline error, with elapsed measured from when the budget
/// started (deadline − timeout), not from the current call's entry: a
/// later batch item that arrives already expired must not report
/// "0 ms elapsed".
fn deadline_error(opts: &SubmitOptions, fallback_start: f64, now: f64) -> RuntimeError {
    let start = opts
        .deadline
        .map(|d| d - opts.timeout_ms as f64 / 1e3)
        .unwrap_or(fallback_start);
    RuntimeError::DeadlineExceeded {
        elapsed_ms: ((now - start).max(0.0) * 1e3).round() as u64,
        timeout_ms: opts.timeout_ms,
    }
}

/// Freshness-gated windowed-p95 signal: NaN (hold the loop output)
/// until new events landed since the previous tick — count-bounded
/// windows would otherwise replay the last regime forever after
/// traffic stops.
///
/// `events` picks the freshness counter and `p95` the quantile to read,
/// so each loop can watch the population it actually steers: the router
/// threshold moves traffic off the *direct* path, so it must see the
/// direct p95 (a blended signal lets the batched tail push the
/// threshold down, starving the direct path it was protecting); the
/// batch-delay loop shapes the *batched* path only.
fn fresh_p95_signal(
    metrics: &Arc<WindowedMetrics>,
    events: fn(&WindowedMetrics) -> u64,
    p95: fn(&WindowedMetrics) -> f64,
) -> Box<dyn FnMut() -> f64 + Send> {
    let m = metrics.clone();
    let mut last_events = 0u64;
    Box::new(move || {
        let ev = events(m.as_ref());
        if ev == last_events {
            return f64::NAN;
        }
        last_events = ev;
        let p95 = p95(m.as_ref());
        if p95 > 0.0 {
            p95
        } else {
            f64::NAN
        }
    })
}

/// Direct-path p95, fresh while direct completions keep landing.
fn fresh_p95_direct(metrics: &Arc<WindowedMetrics>) -> Box<dyn FnMut() -> f64 + Send> {
    fresh_p95_signal(metrics, WindowedMetrics::events_direct, |m| m.snapshot().p95_direct)
}

/// Batched-path p95, fresh while batched completions keep landing.
fn fresh_p95_batched(metrics: &Arc<WindowedMetrics>) -> Box<dyn FnMut() -> f64 + Send> {
    fresh_p95_signal(metrics, WindowedMetrics::events_batched, |m| m.snapshot().p95_batched)
}

/// Outcome of the per-request admission pass (screener → J(x) vs τ(t)).
enum AdmitOutcome {
    /// Execute on the serving path; carry (j, τ) for the result.
    Execute { j: f64, tau: f64 },
    /// Answered without inference (cache / screener argmax).
    Skip { result: InferResult },
}

/// Shared state of the carbon pacer: the control loop's apply side
/// writes the pressure/stretch cells; the admission and batching hot
/// paths read them (one relaxed load each); the signal side integrates
/// metered joules into the ledger at the current grid intensity.
struct CarbonRuntime {
    /// Pacer output in [0, 1]: 0 = clean grid, 1 = full deferral bias.
    pressure: Adaptive<f64>,
    /// Last sampled grid intensity (kg CO₂ / kWh).
    intensity: Adaptive<f64>,
    /// Batch-delay stretch factor (pressure × delay_weight), linked
    /// into every batched version's [`BatcherPolicy`].
    delay_stretch: Adaptive<f64>,
    /// Cumulative emissions + deferred-work credit. Mutex, not atomics:
    /// touched once per control tick and per skipped request, never on
    /// the execute hot path.
    ledger: Mutex<CarbonLedger>,
    /// Admission-τ bias at full pressure for deferrable (Low) work.
    tau_weight: f64,
}

impl CarbonRuntime {
    fn new(initial_intensity: f64, tau_weight: f64) -> Self {
        CarbonRuntime {
            pressure: Adaptive::new(0.0f64),
            intensity: Adaptive::new(initial_intensity),
            delay_stretch: Adaptive::new(0.0f64),
            ledger: Mutex::new(CarbonLedger::default()),
            tau_weight: tau_weight.max(0.0),
        }
    }

    /// Extra admission-τ bias for deferrable work: pressure-scaled,
    /// zero on a clean grid.
    fn tau_bias(&self) -> f64 {
        self.pressure.get() * self.tau_weight
    }
}

/// Snapshot of the carbon pacer's state for stats surfaces
/// (`/v2/admission/stats` `carbon` block, serve-bench reports).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CarbonStats {
    /// Last observed grid intensity (kg CO₂ / kWh).
    pub intensity_kg_per_kwh: f64,
    /// Pacer pressure in [0, 1].
    pub pressure: f64,
    /// Cumulative emissions attributed to metered energy (grams CO₂).
    pub co2_grams: f64,
    /// Emissions avoided by deferral-biased skips (grams CO₂).
    pub co2_deferred_grams: f64,
}

/// State the lifecycle executor's job closures need: everything a load
/// or unload touches, shared (`Arc`) between the request path and the
/// executor threads. Serving-path-only state (controller, router,
/// latency histogram, clock) stays on [`ServingSystem`] itself.
struct SystemShared {
    /// Declared first so the ticker thread stops before paths shut down.
    plane: Option<ControlPlane>,
    registry: ModelRegistry,
    snapshot: RwLock<Arc<Snapshot>>,
    meter: Arc<EnergyMeter>,
    cache: ShardedResponseCache,
    /// In-flight dedup: signature → leader flight. Joined on the
    /// execute path, retired with the version on unload.
    coalesce: SingleflightTable,
    metrics: Arc<WindowedMetrics>,
    /// Weak back-reference to the lifecycle executor so the scaler's
    /// apply side and cold starts can enqueue `JobKind::Scale` jobs.
    /// Weak, not `Arc`: the executor's job closures capture
    /// `Arc<SystemShared>`, so a strong reference here would cycle and
    /// leak the whole system. Set once in [`ServingSystem::start`].
    executor: OnceLock<Weak<LifecycleExecutor>>,
    /// Some iff the control plane runs a carbon pacer loop.
    carbon: Option<Arc<CarbonRuntime>>,
    cfg: SystemConfig,
}

/// The full serving system.
pub struct ServingSystem {
    /// Declared first: dropping the (sole strong) executor handle
    /// cancels queued jobs and joins the workers before the shared
    /// state they capture unwinds.
    executor: Arc<LifecycleExecutor>,
    shared: Arc<SystemShared>,
    latency: Mutex<LatencyHistogram>,
    controller: Option<Arc<Mutex<AdmissionController>>>,
    router: Mutex<Router>,
    clock: SystemClock,
    /// Per-tenant QoS gates (GCRA quotas, retry budgets); the gateway
    /// consults it before `submit_opts`, the `tenant_quota_scale`
    /// control loop writes its quota-scale cell.
    qos: Arc<QosLayer>,
}

impl ServingSystem {
    /// Boot the system: scan the repository into the registry, start the
    /// global control loops, then (unless `ModelControl::Explicit`) load
    /// every model's policy versions — concurrently, through the
    /// lifecycle executor, so boot costs ~the slowest model rather than
    /// the sum. A boot-time load failure aborts the start — a half-up
    /// default-mode server would silently 503.
    pub fn start(cfg: SystemConfig) -> Result<Self, RuntimeError> {
        let registry = ModelRegistry::scan(&cfg.repo_root)?;
        let meter = Arc::new(EnergyMeter::new(cfg.device.clone(), cfg.meter_mode, 16.0));
        let controller = cfg
            .controller
            .clone()
            .map(|c| Arc::new(Mutex::new(AdmissionController::new(c))));
        let metrics = Arc::new(WindowedMetrics::new(64, 256));
        let router = Router::new(cfg.route.clone());
        // The QoS layer exists before the control plane: the quota
        // loop's apply side captures it.
        let qos = Arc::new(QosLayer::new(cfg.qos.clone()));
        // Carbon runtime exists iff the control plane runs a pacer; a
        // carbon trace without a pacer is inert (nothing observes it).
        let carbon = cfg.control.as_ref().and_then(|pc| pc.carbon_pacer.as_ref()).map(|cc| {
            let initial = cfg
                .carbon_trace
                .as_ref()
                .map(|t| t.intensity_at(0.0))
                .unwrap_or(WORLD_KG_CO2_PER_KWH);
            Arc::new(CarbonRuntime::new(initial, cc.tau_weight))
        });
        let plane = cfg.control.as_ref().and_then(|pc| {
            Self::wire_global_loops(
                pc,
                &controller,
                &metrics,
                &router,
                &qos,
                &meter,
                &carbon,
                &cfg.carbon_trace,
            )
        });
        let shared = Arc::new(SystemShared {
            plane,
            registry,
            snapshot: RwLock::new(Arc::new(Snapshot::default())),
            meter,
            cache: ShardedResponseCache::new(cfg.cache_capacity),
            coalesce: SingleflightTable::new(),
            metrics,
            executor: OnceLock::new(),
            carbon,
            cfg,
        });
        let executor = Arc::new(LifecycleExecutor::start(LIFECYCLE_WORKERS, LIFECYCLE_QUEUE_CAP));
        let _ = shared.executor.set(Arc::downgrade(&executor));
        let sys = ServingSystem {
            executor,
            shared,
            latency: Mutex::new(LatencyHistogram::for_latency()),
            controller,
            router: Mutex::new(router),
            clock: SystemClock::new(),
            qos,
        };
        if sys.shared.cfg.model_control == ModelControl::None {
            // Fan every model's load onto the executor, then wait for
            // all of them — cross-model concurrency at boot. A
            // repository with more loadable versions than the job-queue
            // bound must still boot: on backpressure, drain what is in
            // flight to empty the queue, then retry the model (a lone
            // model with more versions than the whole queue is the one
            // shape that still fails).
            let mut pending = Vec::new();
            for name in sys.model_names() {
                let rxs = match sys.spawn_load_jobs(&name, None) {
                    Ok((_, rxs)) => rxs,
                    Err(RuntimeError::Backpressure(_)) => {
                        wait_boot_loads(std::mem::take(&mut pending))?;
                        let (_, rxs) = sys.spawn_load_jobs(&name, None)?;
                        rxs
                    }
                    Err(e) => return Err(e),
                };
                pending.push((name, rxs));
            }
            wait_boot_loads(pending)?;
        }
        Ok(sys)
    }

    /// Build and start the background control plane with the *global*
    /// loops (τ servo, router threshold). Per-model loops (batcher
    /// AIMD, energy-budget pacers) attach per loaded version — the
    /// plane ticks even while empty so later loads find it running.
    #[allow(clippy::too_many_arguments)]
    fn wire_global_loops(
        pc: &ControlPlaneConfig,
        controller: &Option<Arc<Mutex<AdmissionController>>>,
        metrics: &Arc<WindowedMetrics>,
        router: &Router,
        qos: &Arc<QosLayer>,
        meter: &Arc<EnergyMeter>,
        carbon: &Option<Arc<CarbonRuntime>>,
        carbon_trace: &Option<CarbonIntensityTrace>,
    ) -> Option<ControlPlane> {
        if !pc.any_enabled() {
            return None;
        }
        let mut plane = ControlPlane::new();

        // Adaptive τ: windowed admission rate → τ correction.
        if let (Some(tc), Some(ctrl)) = (&pc.adaptive_tau, controller) {
            let handle = ctrl.lock().unwrap().rate_correction_handle();
            let ctrl = ctrl.clone();
            let mut last = (0u64, 0u64); // (admitted, total) at previous tick
            let signal = move || {
                let s = ctrl.lock().unwrap().stats();
                let (d_admitted, d_total) = (s.admitted - last.0, s.total() - last.1);
                if d_total == 0 {
                    return f64::NAN; // no decisions since the last tick
                }
                last = (s.admitted, s.total());
                d_admitted as f64 / d_total as f64
            };
            let law = SetpointTracker::new(
                0.0,
                tc.target_admit_rate,
                tc.gain,
                -tc.max_correction,
                tc.max_correction,
            );
            plane.add_loop(ControlLoop::new(
                "tau_correction",
                Box::new(law),
                Box::new(signal),
                Box::new(move |v| handle.set(v)),
            ));
        }

        // AIMD router threshold: SLO pressure shifts the direct/batched
        // split toward the batched path (threshold drops).
        if let Some(rc) = &pc.adaptive_router {
            // +inf threshold means a pinned RoutePolicy: nothing to tune.
            if router.qps_threshold().is_finite() {
                let initial = router.qps_threshold().clamp(rc.min_qps, rc.max_qps);
                let law = Aimd::new(
                    initial,
                    rc.slo_p95_secs,
                    rc.increase_qps,
                    rc.decrease,
                    rc.min_qps,
                    rc.max_qps,
                );
                let handle = router.qps_threshold_handle();
                plane.add_loop(ControlLoop::new(
                    "router_qps_threshold",
                    Box::new(law),
                    fresh_p95_direct(metrics),
                    Box::new(move |v| handle.set(v)),
                ));
            }
        }

        // Tenant quota scaling: windowed power over budget shrinks every
        // tenant's GCRA rate multiplicatively; under-budget windows let
        // quotas recover toward the configured base. A stale window (no
        // new arrivals since the last tick) reports 0 W — like the
        // per-model pacers — so quotas recover while the system idles
        // instead of holding the last pressure reading forever.
        if let Some(qc) = &pc.quota_scaler {
            let law = QuotaScaler::new(qc.budget_watts, qc.gain, qc.min_scale);
            let m = metrics.clone();
            let mut last_events = 0u64;
            let signal = move || {
                let ev = m.events();
                if ev == last_events {
                    return 0.0;
                }
                last_events = ev;
                m.snapshot().watts
            };
            let q = qos.clone();
            plane.add_loop(ControlLoop::new(
                "tenant_quota_scale",
                Box::new(law),
                Box::new(signal),
                Box::new(move |v| q.set_quota_scale(v)),
            ));
        }

        // Carbon pacer: sampled grid intensity vs the clean-grid
        // threshold → deferral pressure in [0, 1]. The signal side also
        // integrates metered joules into the CO₂ ledger at the
        // intensity of the window they were spent in, so `gf_co2_total`
        // reflects *when* energy was drawn, not just how much.
        if let (Some(cc), Some(car)) = (&pc.carbon_pacer, carbon) {
            let trace = carbon_trace
                .clone()
                .unwrap_or_else(|| CarbonIntensityTrace::constant(WORLD_KG_CO2_PER_KWH));
            let m = metrics.clone();
            let meter = meter.clone();
            let car_sig = car.clone();
            let start = Instant::now();
            let mut last_joules = meter.total_joules();
            let signal = move || {
                let v = trace.intensity_at(start.elapsed().as_secs_f64());
                car_sig.intensity.set(v);
                m.record_carbon_intensity(v);
                let joules = meter.total_joules();
                let delta = joules - last_joules;
                last_joules = joules;
                let mut ledger = car_sig.ledger.lock().unwrap();
                ledger.record(delta, v);
                let reg = crate::telemetry::MetricsRegistry::global();
                reg.gauge("gf_carbon_intensity").set(v);
                reg.gauge("gf_co2_total").set(ledger.grams());
                v
            };
            let law = CarbonPacer::new(cc.threshold_kg_per_kwh, cc.gain);
            let car_apply = car.clone();
            let delay_weight = cc.delay_weight.max(0.0);
            plane.add_loop(ControlLoop::new(
                "carbon_pacer",
                Box::new(law),
                Box::new(signal),
                Box::new(move |p| {
                    car_apply.pressure.set(p);
                    car_apply.delay_stretch.set(p * delay_weight);
                }),
            ));
        }

        plane.start(Duration::from_secs_f64(pc.tick_secs.max(1e-3)));
        Some(plane)
    }
}

/// Lifecycle resource management: runs on executor threads (via the job
/// closures) and at boot. Everything here must be reachable through the
/// `Arc<SystemShared>` alone.
impl SystemShared {
    /// Attach the per-version control loops (batcher-delay AIMD, the
    /// per-model energy-budget pacer, the replica scaler) for a freshly
    /// loaded handle. Associated fn (not a method): the scaler's apply
    /// closure needs a `Weak<SystemShared>`, and `&Arc<Self>` is not a
    /// receiver type.
    fn attach_loops(shared: &Arc<SystemShared>, handle: &Arc<VersionHandle>) {
        let (Some(plane), Some(pc)) = (&shared.plane, &shared.cfg.control) else {
            return;
        };
        let key = format!("{}/{}", handle.model, handle.version);

        // AIMD batch delay, seeded from *this* version's configured
        // window (probe ceiling 4× the configured window, capped by
        // max_us); models configured with no window are left alone —
        // adaptivity must not introduce delay where the operator asked
        // for none.
        if let (Some(dc), Some(delay)) = (&pc.adaptive_batch_delay, &handle.delay_handle) {
            let configured = delay.get();
            if configured > 0 {
                let max_us = dc.max_us.min(configured.saturating_mul(4)).max(dc.min_us);
                let initial = configured.clamp(dc.min_us, max_us);
                let law = Aimd::new(
                    initial as f64,
                    dc.slo_p95_secs,
                    dc.increase_us as f64,
                    dc.decrease,
                    dc.min_us as f64,
                    max_us as f64,
                );
                let h = delay.clone();
                plane.add_loop(ControlLoop::new(
                    format!("batch_delay_us.{key}"),
                    Box::new(law),
                    fresh_p95_batched(&shared.metrics),
                    Box::new(move |v| h.set(v.max(0.0).round() as u64)),
                ));
            }
        }

        // One BudgetPacer per batched path (PR-4: replaces the single
        // global pacer): watches this model's windowed watts, writes
        // this model's τ bias. A stale window means the model ran
        // nothing ⇒ report ~0 W so the correction decays while idle.
        if let Some(ec) = &pc.energy_budget {
            if handle.batched_capable {
                let law = BudgetPacer::new(ec.budget_watts, ec.gain, 0.0, ec.max_correction);
                let sig = handle.clone();
                let mut last_events = 0u64;
                let signal = move || {
                    let ev = sig.energy_events.load(Ordering::Relaxed);
                    if ev == last_events {
                        return 0.0;
                    }
                    last_events = ev;
                    sig.energy.lock().unwrap().watts()
                };
                let out = handle.energy_correction.handle();
                plane.add_loop(ControlLoop::new(
                    format!("energy_budget.{key}"),
                    Box::new(law),
                    Box::new(signal),
                    Box::new(move |v| out.set(v)),
                ));
            }
        }

        // Replica scaler: windowed demand (in-flight + queued work, in
        // per-replica-capacity units), inflated by batched-path p95
        // pressure against the SLO and deflated by this model's
        // energy-budget throttle — a model over its power budget earns
        // fewer replicas, not more. The apply side acts *through the
        // lifecycle executor* (`JobKind::Scale`), so replica spawn and
        // retire inherit per-model serialization, cancellation, and
        // panic containment. The scaler captures only a `Weak` system
        // reference (the plane lives inside `SystemShared`; a strong
        // capture would cycle). Screener excluded: it serves the
        // admission pass inline, and scaling it to zero would silently
        // degrade every decision to the latent-entropy fallback.
        if let Some(rc) = &pc.replica_scaler {
            if handle.batched_capable {
                let law = ReplicaScaler::new(
                    1.0,
                    rc.max_replicas.max(1) as f64,
                    rc.up_threshold,
                    rc.down_threshold,
                    rc.idle_secs,
                );
                let sig = handle.clone();
                let metrics = shared.metrics.clone();
                let slo = shared.cfg.slo_latency;
                let per_cap = rc.per_replica_capacity.max(1e-9);
                let signal = move || {
                    let demand = (sig.in_flight() + sig.queue_depth()) as f64 / per_cap;
                    let p95 = metrics.snapshot().p95_batched;
                    let pressure =
                        if slo > 0.0 && p95 > slo { (p95 / slo).min(4.0) } else { 1.0 };
                    let throttle = 1.0 + sig.energy_correction.get().max(0.0);
                    demand * pressure / throttle
                };
                let weak = Arc::downgrade(shared);
                let h = handle.clone();
                let apply = move |out: f64| {
                    if let Some(shared) = weak.upgrade() {
                        SystemShared::request_scale(&shared, &h, out.round().max(0.0) as usize);
                    }
                };
                plane.add_loop(ControlLoop::new(
                    format!("replica_scaler.{key}"),
                    Box::new(law),
                    Box::new(signal),
                    Box::new(apply),
                ));
            }
        }
    }

    fn detach_loops(&self, handle: &VersionHandle) {
        if let Some(plane) = &self.plane {
            let key = format!("{}/{}", handle.model, handle.version);
            plane.remove_loop(&format!("batch_delay_us.{key}"));
            plane.remove_loop(&format!("energy_budget.{key}"));
            plane.remove_loop(&format!("replica_scaler.{key}"));
        }
    }

    /// Remove one version from the serving snapshot: the moment the swap
    /// lands, new requests get [`RuntimeError::ModelUnavailable`] (503).
    fn swap_out(&self, model: &str, version: u64) -> Option<Arc<VersionHandle>> {
        let mut guard = self.snapshot.write().unwrap();
        let mut next = (**guard).clone();
        let h = next.models.get_mut(model).and_then(|m| m.remove(&version));
        if next.models.get(model).is_some_and(|m| m.is_empty()) {
            next.models.remove(model);
        }
        *guard = Arc::new(next);
        if let Some(h) = &h {
            // From here on, in-flight stragglers must not write the
            // response cache — see `VersionHandle::retired`. Retirement
            // also fails any parked cold-start waiters and makes a
            // late-running reconcile bail out.
            h.retired.store(true, Ordering::SeqCst);
            // Retire the version's in-flight singleflight entries at the
            // same moment: parked followers wake with `Retired` (503)
            // instead of waiting on a leader pinned to dying engines,
            // and a reload's first arrival starts a fresh flight.
            self.coalesce.retire(ResponseCache::signatures_of(
                &h.model,
                h.version,
                self.cfg.cache_clusters,
            ));
            crate::telemetry::MetricsRegistry::global()
                .gauge(&format!("gf_replicas.{}.{}", h.model, h.version))
                .set(0.0);
        }
        h
    }

    /// The slow half of an unload (runs on an executor thread): wait —
    /// bounded — for in-flight requests to drain, drop the engines,
    /// complete the registry transition, and invalidate the dead
    /// version's response-cache entries so a reload starts cold.
    fn drain_and_finish(&self, model: &str, version: u64, handle: Option<Arc<VersionHandle>>) {
        if let Some(handle) = handle {
            // In-flight requests hold their own Arc clone; once the
            // count reaches 1 the engines are idle and this drop joins
            // their threads. Past the timeout the last request thread
            // pays the teardown instead — either way no new request can
            // reach the version (the snapshot swap already landed).
            let deadline = Instant::now() + UNLOAD_DRAIN_TIMEOUT;
            while Arc::strong_count(&handle) > 1 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            drop(handle);
        }
        self.registry.finish_unload(model, version);
        self.cache.invalidate(model, version, self.cfg.cache_clusters);
        // Belt-and-braces: `swap_out` already retired the singleflight
        // entries, but unload paths that never had a snapshot entry
        // (load-failure cleanup) still must not leave a stale flight.
        self.coalesce.retire(ResponseCache::signatures_of(model, version, self.cfg.cache_clusters));
    }

    /// Spin up one version's first replica and swap the version into
    /// the snapshot. Associated fn for the same reason as
    /// [`SystemShared::attach_loops`].
    fn attach_version(
        shared: &Arc<SystemShared>,
        model: &str,
        info: &VersionInfo,
    ) -> Result<(), RuntimeError> {
        let t0 = Instant::now();
        // Test/bench hook (opt-in via `SystemConfig::load_hooks`): a
        // `slow_load_ms` file in the version directory stalls the
        // engine spawn — how the lifecycle integration tests prove
        // loads never block the gateway without needing a genuinely
        // slow model. Ignored unless explicitly enabled, so a stray
        // file in a production repository can never slow real loads.
        if shared.cfg.load_hooks {
            if let Ok(text) = std::fs::read_to_string(info.dir.join("slow_load_ms")) {
                if let Ok(ms) = text.trim().parse::<u64>() {
                    std::thread::sleep(Duration::from_millis(ms.min(30_000)));
                }
            }
        }
        let manifest = ModelManifest::load(&info.dir)?;
        if manifest.name != model {
            return Err(RuntimeError::Manifest(format!(
                "{}: manifest name {:?} does not match model {:?}",
                info.dir.display(),
                manifest.name,
                model
            )));
        }
        let config = shared.registry.config(model)?;
        if let Some(c) = &config {
            // Shape/dtype discipline (the paper's §VII "practical
            // gotchas"), enforced at load so a bad config is a typed
            // 400, not a runtime surprise.
            c.validate().map_err(|e| RuntimeError::InvalidConfig {
                model: model.to_string(),
                reason: e.to_string(),
            })?;
            if manifest.bucket_for(c.max_batch_size).is_none() {
                return Err(RuntimeError::InvalidConfig {
                    model: model.to_string(),
                    reason: format!(
                        "config max_batch_size {} exceeds buckets {:?}",
                        c.max_batch_size, manifest.batch_buckets
                    ),
                });
            }
            if let Some(inp) = c.inputs.first() {
                if inp.dims != manifest.input_shape {
                    return Err(RuntimeError::InvalidConfig {
                        model: model.to_string(),
                        reason: format!(
                            "config dims {:?} != manifest {:?}",
                            inp.dims, manifest.input_shape
                        ),
                    });
                }
            }
        }

        // The screener serves inline on its direct engine; every other
        // model's replicas carry a batcher. Policy clones share one
        // Adaptive delay cell, so the AIMD loop keeps driving every
        // replica's window no matter how many the scaler spawns.
        let mut policy = if model == models::SCREENER {
            None
        } else {
            Some(
                config
                    .as_ref()
                    .map(BatcherPolicy::from_config)
                    .unwrap_or_else(|| BatcherPolicy::immediate(manifest.max_bucket())),
            )
        };
        // Carbon pacing stretches every batched queue's delay window by
        // the shared pressure cell (amortise flushes onto fewer, fuller
        // batches while the grid is dirty). Linked here, once per
        // version: replica clones share the cell for free.
        if let (Some(p), Some(car)) = (policy.as_mut(), &shared.carbon) {
            p.link_stretch(car.delay_stretch.handle());
        }
        let delay_handle = policy.as_ref().map(|p| p.delay_handle());
        let instances = config.as_ref().map(|c| c.total_instances()).unwrap_or(1);
        let first = shared.spawn_replica(&info.dir, policy.as_ref(), instances)?;

        let load_secs = t0.elapsed().as_secs_f64();
        let stats = LoadStats {
            load_secs,
            weight_bytes: manifest.weights_bytes() as u64,
            // Estimated compile + weight-transfer energy: full draw on
            // the metered device over the load interval.
            est_load_joules: shared.meter.profile().power_at(1.0) * load_secs,
        };
        let handle = Arc::new(VersionHandle {
            model: model.to_string(),
            version: info.version,
            manifest,
            config,
            replicas: RwLock::new(Arc::new(vec![Arc::new(first)])),
            target_replicas: AtomicUsize::new(1),
            sched_ticket: AtomicU64::new(0),
            batched_capable: policy.is_some(),
            policy,
            instances,
            dir: info.dir.clone(),
            cold_spawn: AtomicBool::new(false),
            cold_gen: AtomicU64::new(0),
            cold_waiting: AtomicUsize::new(0),
            stats,
            delay_handle,
            energy: Mutex::new(EnergyWindow::new(64)),
            energy_events: AtomicU64::new(0),
            energy_correction: Adaptive::new(0.0),
            retired: AtomicBool::new(false),
        });
        {
            let mut guard = shared.snapshot.write().unwrap();
            let mut next = (**guard).clone();
            next.models
                .entry(model.to_string())
                .or_default()
                .insert(info.version, handle.clone());
            *guard = Arc::new(next);
        }
        crate::telemetry::MetricsRegistry::global()
            .gauge(&format!("gf_replicas.{}.{}", model, info.version))
            .set(1.0);
        Self::attach_loops(shared, &handle);
        shared.registry.finish_load(model, info.version, Ok(stats));
        Ok(())
    }

    /// Spin up one engine replica (direct engine + batcher) for a
    /// version directory. Runs on executor threads at load and on every
    /// scale-up reconcile.
    fn spawn_replica(
        &self,
        dir: &std::path::Path,
        policy: Option<&BatcherPolicy>,
        instances: usize,
    ) -> Result<Replica, RuntimeError> {
        let direct = DirectPath::start(vec![dir.to_path_buf()], self.cfg.exec_mode)?;
        let batched = match policy {
            Some(p) => Some(BatchedPath::start(
                dir.to_path_buf(),
                p.clone(),
                instances,
                self.cfg.queue_capacity,
                self.cfg.exec_mode,
                self.cfg.salt,
            )?),
            None => None,
        };
        Ok(Replica { direct, batched, in_flight: AtomicUsize::new(0) })
    }

    /// Set a version's target replica count and (if anything changed)
    /// enqueue the executor-serialized reconcile that walks the set
    /// toward it. No-op on retired handles: an unload mid-flight wins.
    fn request_scale(shared: &Arc<SystemShared>, handle: &Arc<VersionHandle>, target: usize) {
        if handle.retired.load(Ordering::SeqCst) {
            return;
        }
        let prev = handle.target_replicas.swap(target, Ordering::SeqCst);
        if prev == target && handle.replica_count() == target {
            return;
        }
        if prev != target {
            crate::telemetry::MetricsRegistry::global()
                .counter("gf_replica_scale_events_total")
                .inc();
        }
        let _ = Self::submit_reconcile(shared, handle);
    }

    /// Enqueue one `JobKind::Scale` reconcile for this version. Scale
    /// jobs bypass the load-queue bound (see [`JobKind::Scale`]); false
    /// only when the executor is already gone (shutdown).
    fn submit_reconcile(shared: &Arc<SystemShared>, handle: &Arc<VersionHandle>) -> bool {
        let Some(exec) = shared.executor.get().and_then(Weak::upgrade) else {
            return false;
        };
        let work = {
            let shared = shared.clone();
            let h = handle.clone();
            Box::new(move || shared.reconcile_replicas(&h)) as Box<dyn FnOnce() + Send>
        };
        // A cancelled reconcile (shutdown drain) must still release any
        // cold-start election and bump the generation so parked waiters
        // fail fast instead of sleeping out the full timeout.
        let cancel = {
            let h = handle.clone();
            Box::new(move || {
                h.cold_spawn.store(false, Ordering::SeqCst);
                h.cold_gen.fetch_add(1, Ordering::SeqCst);
            }) as Box<dyn FnOnce() + Send>
        };
        exec.submit(&handle.model, handle.version, JobKind::Scale, work, cancel).is_ok()
    }

    /// Walk a version's replica set toward its target, one replica at a
    /// time, re-reading the target each step so a scaler reversal
    /// mid-walk is honoured. Runs on an executor worker under per-model
    /// serialization — it is the only writer of the replica vector.
    fn reconcile_replicas(&self, handle: &Arc<VersionHandle>) {
        let registry = crate::telemetry::MetricsRegistry::global();
        let gauge = registry.gauge(&format!("gf_replicas.{}.{}", handle.model, handle.version));
        loop {
            if handle.retired.load(Ordering::SeqCst) {
                break;
            }
            let cur = handle.replica_count();
            let target = handle.target_replicas.load(Ordering::SeqCst);
            if cur < target {
                match self.spawn_replica(&handle.dir, handle.policy.as_ref(), handle.instances) {
                    Ok(r) => handle.push_replica(Arc::new(r)),
                    Err(_) => {
                        // Leave the target standing: the next scaler
                        // tick (or cold-start retry) re-enqueues.
                        registry.counter("gf_replica_spawn_failures_total").inc();
                        break;
                    }
                }
            } else if cur > target {
                match handle.pop_replica() {
                    Some(r) => drain_replica(r),
                    None => break,
                }
            } else {
                break;
            }
            gauge.set(handle.replica_count() as f64);
        }
        if !handle.retired.load(Ordering::SeqCst) {
            gauge.set(handle.replica_count() as f64);
        }
        // Release the cold-start election and publish "a reconcile
        // finished" to any parked waiters.
        handle.cold_spawn.store(false, Ordering::SeqCst);
        handle.cold_gen.fetch_add(1, Ordering::SeqCst);
    }

    /// Hot-path replica acquisition: power-of-two-choices pick, or — at
    /// zero replicas — the cold-start wait. Returns the RAII in-flight
    /// guard the caller holds across the engine call.
    fn acquire_replica(
        shared: &Arc<SystemShared>,
        handle: &Arc<VersionHandle>,
    ) -> Result<InFlightGuard, RuntimeError> {
        if let Some(r) = handle.pick_replica() {
            return Ok(InFlightGuard::new(r));
        }
        Self::cold_start_wait(shared, handle)
    }

    /// Scale-to-zero wake-up: the first request elects itself spawner
    /// (CAS on `cold_spawn`), counts the cold start, raises the target
    /// floor to one, and enqueues a reconcile; every concurrent request
    /// parks and polls for the replica instead of 503ing. Waiters give
    /// up on retirement (unload wins), on two reconcile generations
    /// passing with no replica (the spawn genuinely failed — one bump
    /// may predate our failed pick, two cannot), or on the cold-start
    /// timeout.
    fn cold_start_wait(
        shared: &Arc<SystemShared>,
        handle: &Arc<VersionHandle>,
    ) -> Result<InFlightGuard, RuntimeError> {
        let unavailable = || RuntimeError::ModelUnavailable { model: handle.model.clone() };
        if handle.retired.load(Ordering::SeqCst) {
            return Err(unavailable());
        }
        let t0 = Instant::now();
        handle.cold_waiting.fetch_add(1, Ordering::SeqCst);
        let _parked = ColdWaitGuard(handle);
        let gen0 = handle.cold_gen.load(Ordering::SeqCst);
        if handle
            .cold_spawn
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            // Won the election — but a reconcile may have landed a
            // replica between our failed pick and the CAS.
            if let Some(r) = handle.pick_replica() {
                handle.cold_spawn.store(false, Ordering::SeqCst);
                return Ok(InFlightGuard::new(r));
            }
            crate::telemetry::MetricsRegistry::global().counter("gf_cold_starts_total").inc();
            handle.target_replicas.fetch_max(1, Ordering::SeqCst);
            if !Self::submit_reconcile(shared, handle) {
                handle.cold_spawn.store(false, Ordering::SeqCst);
                return Err(unavailable());
            }
        } else {
            // Raise the floor too: a concurrent scale-to-zero apply
            // must not land underneath the winner's reconcile.
            handle.target_replicas.fetch_max(1, Ordering::SeqCst);
        }
        loop {
            if let Some(r) = handle.pick_replica() {
                crate::telemetry::MetricsRegistry::global()
                    .gauge(&format!("gf_cold_start_ms.{}.{}", handle.model, handle.version))
                    .set(t0.elapsed().as_secs_f64() * 1e3);
                return Ok(InFlightGuard::new(r));
            }
            if handle.retired.load(Ordering::SeqCst) {
                return Err(unavailable());
            }
            if handle.cold_gen.load(Ordering::SeqCst) >= gen0 + 2 {
                return Err(unavailable());
            }
            if t0.elapsed() > COLD_START_TIMEOUT {
                return Err(unavailable());
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// Bounded per-replica drain (scale-down): wait for in-flight holders
/// to release their `Arc` clones, then drop the engines; past the
/// timeout the last request thread pays the teardown instead.
fn drain_replica(replica: Arc<Replica>) {
    let deadline = Instant::now() + REPLICA_DRAIN_TIMEOUT;
    while Arc::strong_count(&replica) > 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    drop(replica);
}

/// Wait for a batch of boot-time load jobs; the first failure aborts
/// the boot (a half-up default-mode server would silently 503).
#[allow(clippy::type_complexity)]
fn wait_boot_loads(
    pending: Vec<(String, Vec<mpsc::Receiver<Result<u64, RuntimeError>>>)>,
) -> Result<(), RuntimeError> {
    for (name, rxs) in pending {
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    return Err(RuntimeError::Lifecycle {
                        model: name.clone(),
                        reason: "boot load job dropped".to_string(),
                    })
                }
            }
        }
    }
    Ok(())
}

/// Outcome of an asynchronous unload request (the 202/200 payload).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct UnloadTicket {
    /// Versions transitioned to `Unloading`, draining on the executor.
    pub unloading: Vec<u64>,
    /// Still-queued load jobs this request cancelled outright
    /// (`Loading → Unloaded`, nothing ever ran).
    pub cancelled: Vec<u64>,
}

impl ServingSystem {
    // ------------------------------------------------------ lifecycle

    /// Validate a load and enqueue one executor job per target version.
    /// Fast path only: repository rescan + state flips to `Loading`; the
    /// engine spawn happens on the executor. Returns the targeted
    /// versions plus one completion receiver per job (each yields the
    /// version on success or the typed attach error).
    #[allow(clippy::type_complexity)]
    fn spawn_load_jobs(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<(Vec<u64>, Vec<mpsc::Receiver<Result<u64, RuntimeError>>>), RuntimeError> {
        let targets = self.shared.registry.begin_load(model, version)?;
        let mut versions = Vec::with_capacity(targets.len());
        let mut rxs = Vec::with_capacity(targets.len());
        let mut specs = Vec::with_capacity(targets.len());
        for info in &targets {
            let (tx, rx) = mpsc::channel();
            let tx_cancel = tx.clone();
            let v = info.version;
            let work = {
                let shared = self.shared.clone();
                let model = model.to_string();
                let info = info.clone();
                Box::new(move || {
                    // A panicking attach must still land the version in
                    // a *terminal* registry state — left as `Loading` it
                    // would read as "busy" to every later load/unload.
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || SystemShared::attach_version(&shared, &model, &info),
                    ));
                    match outcome {
                        Ok(Ok(())) => {
                            let _ = tx.send(Ok(info.version));
                        }
                        Ok(Err(e)) => {
                            shared.registry.finish_load(&model, info.version, Err(e.to_string()));
                            let _ = tx.send(Err(e));
                        }
                        Err(_) => {
                            shared.registry.finish_load(
                                &model,
                                info.version,
                                Err("load job panicked".to_string()),
                            );
                            let _ = tx.send(Err(RuntimeError::Lifecycle {
                                model: model.clone(),
                                reason: format!("load of version {} panicked", info.version),
                            }));
                        }
                    }
                }) as Box<dyn FnOnce() + Send>
            };
            // A cancelled job reverts `Loading → Unloaded` and fails any
            // synchronous waiter with a typed error.
            let cancel = {
                let shared = self.shared.clone();
                let model = model.to_string();
                Box::new(move || {
                    shared.registry.abort_load(&model, v);
                    let _ = tx_cancel.send(Err(RuntimeError::Lifecycle {
                        model,
                        reason: format!("load of version {v} cancelled before it started"),
                    }));
                }) as Box<dyn FnOnce() + Send>
            };
            specs.push(JobSpec { version: v, kind: JobKind::Load, work, cancel });
            versions.push(v);
            rxs.push(rx);
        }
        // All-or-nothing enqueue: a full queue reverts *every* target to
        // `Unloaded` (no half-accepted multi-version load whose stranded
        // siblings would read as "busy" to a retry).
        if let Err(e) = self.executor.submit_all(model, specs) {
            for info in &targets {
                self.shared.registry.abort_load(model, info.version);
            }
            return Err(e);
        }
        Ok((versions, rxs))
    }

    /// Non-blocking load (the `POST /v2/repository/models/{m}/load` 202
    /// path): validates, flips the target versions to `Loading`, and
    /// returns them immediately — the engine spawn runs on the lifecycle
    /// executor. Poll `/v2/repository/index` or `GET /v2/models/{m}` for
    /// the outcome (`READY` / `FAILED{reason}`). Validation errors
    /// (unknown model/version, malformed config, busy version) are still
    /// synchronous; a full executor queue is `Backpressure` (429).
    pub fn load_model_async(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Vec<u64>, RuntimeError> {
        let (versions, _rxs) = self.spawn_load_jobs(model, version)?;
        Ok(versions)
    }

    /// Blocking load: enqueues the same executor jobs as
    /// [`ServingSystem::load_model_async`] and waits for all of them
    /// (boot, `?wait=true`, CLI `--wait`, tests). Every targeted version
    /// is attempted; the first failure's typed error is returned after
    /// the rest settle (siblings are independent — one broken version no
    /// longer abandons the others mid-request).
    pub fn load_model(&self, model: &str, version: Option<u64>) -> Result<Vec<u64>, RuntimeError> {
        let (_versions, rxs) = self.spawn_load_jobs(model, version)?;
        let mut loaded = Vec::with_capacity(rxs.len());
        let mut first_err = None;
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(v)) => loaded.push(v),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err.or(Some(RuntimeError::Lifecycle {
                        model: model.to_string(),
                        reason: "lifecycle job dropped".to_string(),
                    }))
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loaded),
        }
    }

    /// Validate an unload, cancel still-queued loads it targets, swap
    /// the ready versions out of the serving snapshot (new requests 503
    /// immediately), detach their control loops, and enqueue the
    /// bounded drain as executor jobs.
    #[allow(clippy::type_complexity)]
    fn spawn_unload_jobs(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<(UnloadTicket, Vec<mpsc::Receiver<Result<u64, RuntimeError>>>), RuntimeError> {
        // Unknown models stay a 404 even when cancellation would match
        // nothing.
        if !self.shared.registry.has_model(model) {
            return Err(RuntimeError::UnknownModel(model.to_string()));
        }
        // An unload aimed at a load that never started is a pure
        // cancellation: the job's cancel hook reverts `Loading →
        // Unloaded` before we look at ready versions.
        let cancelled = self.executor.cancel_queued_loads(model, version);
        let targets = match self.shared.registry.begin_unload(model, version) {
            Ok(t) => t,
            Err(e) => {
                if cancelled.is_empty() {
                    return Err(e);
                }
                // Satisfied purely by cancellation.
                return Ok((UnloadTicket { unloading: Vec::new(), cancelled }, Vec::new()));
            }
        };
        let mut rxs = Vec::with_capacity(targets.len());
        for &v in &targets {
            let handle = self.shared.swap_out(model, v);
            if let Some(h) = &handle {
                self.shared.detach_loops(h);
            }
            let (tx, rx) = mpsc::channel();
            let work = {
                let shared = self.shared.clone();
                let model = model.to_string();
                Box::new(move || {
                    // As with loads: a panicking drain must not strand
                    // the version in `Unloading` — land it `Unloaded`
                    // best-effort so it stays reloadable.
                    let drained = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || shared.drain_and_finish(&model, v, handle),
                    ));
                    match drained {
                        Ok(()) => {
                            let _ = tx.send(Ok(v));
                        }
                        Err(_) => {
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || shared.registry.finish_unload(&model, v),
                            ));
                            let _ = tx.send(Err(RuntimeError::Lifecycle {
                                model: model.clone(),
                                reason: format!("unload of version {v} panicked mid-drain"),
                            }));
                        }
                    }
                }) as Box<dyn FnOnce() + Send>
            };
            // Unload jobs are never refused (the queue bound applies to
            // loads only). Cancelled only at shutdown: dropping the
            // closure drops the handle and sender, so the version's
            // engines unwind with the process and any waiter errors out.
            self.executor
                .submit(model, v, JobKind::Unload, work, Box::new(|| {}))
                .expect("unload jobs bypass the queue bound");
            rxs.push(rx);
        }
        Ok((UnloadTicket { unloading: targets, cancelled }, rxs))
    }

    /// Non-blocking unload (the `POST .../unload` 202 path): new
    /// requests 503 the moment this returns; the in-flight drain and
    /// engine teardown run on the executor. Queued loads of the targeted
    /// version are cancelled instead (reported in the ticket).
    pub fn unload_model_async(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<UnloadTicket, RuntimeError> {
        let (ticket, _rxs) = self.spawn_unload_jobs(model, version)?;
        Ok(ticket)
    }

    /// Blocking unload: same jobs, waits for the drains to finish. The
    /// returned ticket keeps drained versions (`unloading`, now fully
    /// unloaded) separate from cancelled queued loads (`cancelled`,
    /// which never served) — callers reporting "what was unloaded" must
    /// not conflate the two.
    pub fn unload_model_wait(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<UnloadTicket, RuntimeError> {
        let (ticket, rxs) = self.spawn_unload_jobs(model, version)?;
        let mut drained = Vec::with_capacity(rxs.len());
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(v)) => drained.push(v),
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    return Err(RuntimeError::Lifecycle {
                        model: model.to_string(),
                        reason: "lifecycle job dropped".to_string(),
                    })
                }
            }
        }
        drained.sort_unstable();
        Ok(UnloadTicket { unloading: drained, cancelled: ticket.cancelled })
    }

    /// Convenience wrapper over [`ServingSystem::unload_model_wait`]:
    /// every version transitioned out (drained + cancelled), sorted.
    pub fn unload_model(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Vec<u64>, RuntimeError> {
        let ticket = self.unload_model_wait(model, version)?;
        let mut done = ticket.cancelled;
        done.extend(ticket.unloading);
        done.sort_unstable();
        Ok(done)
    }

    /// Lifecycle jobs waiting for an executor worker (surfaced by
    /// `POST /v2/repository/index` and the `gf_lifecycle_queue_depth`
    /// gauge).
    pub fn lifecycle_queue_depth(&self) -> usize {
        self.executor.queue_depth()
    }

    /// Resolve a servable handle. Distinguishes a model that is not in
    /// the repository at all (`UnknownModel` → 404) from one with no
    /// ready version matching the request (`ModelUnavailable` → 503).
    fn resolve(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Arc<VersionHandle>, RuntimeError> {
        let snap = self.shared.snapshot.read().unwrap().clone();
        match snap.resolve(model, version) {
            Some(h) => Ok(h),
            None if self.shared.registry.has_model(model) => {
                Err(RuntimeError::ModelUnavailable { model: model.to_string() })
            }
            None => Err(RuntimeError::UnknownModel(model.to_string())),
        }
    }

    // -------------------------------------------------- introspection

    pub fn registry(&self) -> &ModelRegistry {
        &self.shared.registry
    }

    /// Every registered model name (loaded or not).
    pub fn model_names(&self) -> Vec<String> {
        self.shared.registry.model_names()
    }

    /// Number of models with at least one ready version.
    pub fn ready_models(&self) -> usize {
        self.shared.snapshot.read().unwrap().models.len()
    }

    /// The serving handle for a model version, if ready (None = default
    /// version).
    pub fn version_handle(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Option<Arc<VersionHandle>> {
        self.shared.snapshot.read().unwrap().resolve(model, version)
    }

    pub fn meter(&self) -> &EnergyMeter {
        &self.shared.meter
    }

    /// Per-system response-cache totals (hits/misses/evictions/len) —
    /// the `/v2/admission/stats` cache block reads these rather than
    /// the process-global registry, which tests sharing one process
    /// would cross-pollute.
    pub fn cache_stats(&self) -> crate::pipeline::coalesce::CacheStats {
        self.shared.cache.stats()
    }

    /// Per-system singleflight totals (coalesced followers, live
    /// entries, engine executions).
    pub fn coalesce_stats(&self) -> crate::pipeline::coalesce::CoalesceStats {
        self.shared.coalesce.stats()
    }

    pub fn clock(&self) -> &SystemClock {
        &self.clock
    }

    /// The per-tenant QoS admission layer (GCRA quotas + retry
    /// budgets); the gateway consults it before submitting.
    pub fn qos(&self) -> &Arc<QosLayer> {
        &self.qos
    }

    /// Recent P95 latency (s).
    pub fn p95(&self) -> f64 {
        self.latency.lock().unwrap().p95()
    }

    /// The windowed-metrics aggregator feeding the control loops.
    pub fn metrics(&self) -> &WindowedMetrics {
        &self.shared.metrics
    }

    /// Names of the running control loops (empty when no plane).
    pub fn control_loop_names(&self) -> Vec<String> {
        self.shared.plane.as_ref().map(|p| p.loop_names()).unwrap_or_default()
    }

    /// Introspection snapshot of every control loop (name, law, output).
    pub fn control_loop_states(&self) -> Vec<crate::control::LoopState> {
        self.shared.plane.as_ref().map(|p| p.loop_states()).unwrap_or_default()
    }

    /// Scheduler queue capacity per batched path (the C(x) normaliser).
    pub fn queue_capacity(&self) -> usize {
        self.shared.cfg.queue_capacity
    }

    /// Whether a model's default version is servable on the batched path.
    pub fn has_batched_path(&self, model: &str) -> bool {
        self.version_handle(model, None).map(|h| h.has_batched()).unwrap_or(false)
    }

    /// Whether the background control plane is ticking.
    pub fn control_plane_running(&self) -> bool {
        self.shared.plane.as_ref().map(|p| p.running()).unwrap_or(false)
    }

    /// Recent arrival rate seen by the shared router.
    pub fn router_qps(&self) -> f64 {
        self.router.lock().unwrap().recent_qps()
    }

    /// The router's QPS threshold currently in force (+inf when pinned).
    pub fn router_qps_threshold(&self) -> f64 {
        self.router.lock().unwrap().qps_threshold()
    }

    /// Controller admission stats (None when open loop).
    pub fn controller_stats(&self) -> Option<crate::controller::admission::AdmissionStats> {
        self.controller.as_ref().map(|c| c.lock().unwrap().stats())
    }

    /// Carbon pacer state (None unless a carbon pacer is configured):
    /// last grid intensity, deferral pressure, and the CO₂ ledger.
    pub fn carbon_stats(&self) -> Option<CarbonStats> {
        self.shared.carbon.as_ref().map(|car| {
            let ledger = car.ledger.lock().unwrap();
            CarbonStats {
                intensity_kg_per_kwh: car.intensity.get(),
                pressure: car.pressure.get(),
                co2_grams: ledger.grams(),
                co2_deferred_grams: ledger.deferred_grams(),
            }
        })
    }

    /// Restart the controller's τ(t) epoch at "now" — the paper's folding
    /// restarts when the landscape changes (deploys, model swaps); also
    /// lets benchmarks align τ0 with their first request.
    pub fn restart_controller_epoch(&self) {
        if let Some(c) = &self.controller {
            let now = self.clock.now();
            c.lock().unwrap().restart_epoch(now);
        }
    }

    /// Scheduler queue depth of a model's default-version batched path.
    pub fn queue_depth(&self, model: &str) -> usize {
        self.version_handle(model, None).map(|h| h.queue_depth()).unwrap_or(0)
    }

    /// `(ready, target, in_flight)` replica counts of a model version
    /// (the `GET /v2/models/{m}` `replicas` object); None when the
    /// version is not in the serving snapshot.
    pub fn replica_counts(&self, model: &str, version: Option<u64>) -> Option<(usize, usize, usize)> {
        self.version_handle(model, version)
            .map(|h| (h.replica_count(), h.target_replicas(), h.in_flight()))
    }

    /// Operator override: set a version's target replica count directly
    /// (tests, CLI, emergency pinning). Goes through the same
    /// executor-serialized reconcile the scaler uses — and the scaler,
    /// if attached, will keep adjusting it on later ticks. Target 0 is
    /// scale-to-zero: the version stays resolvable and the next request
    /// cold-starts.
    pub fn scale_replicas(
        &self,
        model: &str,
        version: Option<u64>,
        target: usize,
    ) -> Result<(), RuntimeError> {
        let handle = self.resolve(model, version)?;
        SystemShared::request_scale(&self.shared, &handle, target);
        Ok(())
    }

    // -------------------------------------------------------- serving

    /// Execute a request on an explicit path, bypassing the controller
    /// (the Table II benchmark mode).
    pub fn infer_on(&self, req: &Request, path: PathKind) -> Result<InferResult, RuntimeError> {
        let handle = self.resolve(&req.model, None)?;
        self.infer_on_handle(&handle, req, path)
    }

    fn infer_on_handle(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        path: PathKind,
    ) -> Result<InferResult, RuntimeError> {
        let t0 = self.clock.now();
        // Arrival is observed at entry, not completion: concurrent workers
        // finishing out of order must not scramble the rate window.
        self.shared.metrics.record_arrival(t0);
        let (out, stats) = match path {
            PathKind::Direct => {
                let guard = SystemShared::acquire_replica(&self.shared, handle)?;
                let input =
                    inputgen::batch_for(&handle.manifest, &[req.seed], self.shared.cfg.salt);
                guard.replica().direct.infer(&req.model, input)?
            }
            PathKind::Batched => {
                if !handle.has_batched() {
                    return Err(RuntimeError::InputMismatch(format!(
                        "model {:?} has no batched path",
                        req.model
                    )));
                }
                let guard = SystemShared::acquire_replica(&self.shared, handle)?;
                let p = guard
                    .replica()
                    .batched
                    .as_ref()
                    .expect("batched-capable replicas carry a batcher");
                p.infer(req.seed)?
            }
            PathKind::CacheSkip => {
                return Err(RuntimeError::InputMismatch("cannot force cache path".into()))
            }
        };
        self.finish_exec(handle, req, path, t0, &out, &stats)
    }

    /// Shared post-execution accounting: latency histogram + windowed
    /// metrics, per-item energy attribution (plus the batched path's
    /// scheduler wait burned at idle power — the per-request energy
    /// premium Triton shows at batch=1 in Table II), and this handle's
    /// own energy window for its budget pacer.
    fn finish_exec(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        path: PathKind,
        t0: f64,
        out: &OutputBatch,
        stats: &ExecStats,
    ) -> Result<InferResult, RuntimeError> {
        let latency = self.clock.now() - t0;
        self.latency.lock().unwrap().record(latency);
        // Path-attributed tap: the router loop reads the direct p95, the
        // batch-delay loop the batched p95 (both also land in the blend).
        match path {
            PathKind::Direct => self.shared.metrics.record_latency_direct(latency),
            PathKind::Batched => self.shared.metrics.record_latency_batched(latency),
            _ => self.shared.metrics.record_latency(latency),
        }
        let flops_item = handle.manifest.flops_per_item(stats.bucket.max(1));
        let reading = self
            .shared
            .meter
            .record(flops_item, stats.exec_secs / stats.bucket.max(1) as f64);
        let now = self.clock.now();
        self.shared.metrics.record_joules(now, reading.joules);
        handle.energy.lock().unwrap().record(now, reading.joules);
        handle.energy_events.fetch_add(1, Ordering::Relaxed);
        if path == PathKind::Batched {
            self.shared.meter.record_idle((latency - stats.exec_secs).max(0.0));
        }
        self.shared.coalesce.note_execution();
        Ok(InferResult {
            request_id: req.id,
            predicted: out.predicted(0),
            confidence: out.confidence(0),
            entropy: out.entropy[0],
            latency_secs: latency,
            exec_secs: stats.exec_secs,
            bucket: stats.bucket,
            joules: reading.joules,
            path,
            j: f64::NAN,
            tau: f64::NAN,
            served: Served::Model,
        })
    }

    /// The admission pass (Fig. 2 / Algorithm 1): screener pass for a
    /// cheap L(x) estimate, assemble CostInputs from the live feedback
    /// signals, compare J(x) against τ(t) + this model's energy-pacer
    /// bias. A Skip is answered (and fully accounted) here.
    fn admission_decision(
        &self,
        ctrl: &Arc<Mutex<AdmissionController>>,
        handle: &Arc<VersionHandle>,
        req: &Request,
        t0: f64,
        deferrable: bool,
    ) -> Result<AdmitOutcome, RuntimeError> {
        // 1. Cheap L(x) estimate: screener pass on its direct engine
        // (resolved from the live snapshot — an unloaded screener falls
        // back to the request's latent-confidence entropy).
        let screener = self.version_handle(models::SCREENER, None);
        // The screener must stay cheap: it keeps a pinned replica set
        // (the scaler skips batcher-less versions), but if it were ever
        // at zero replicas a cold-start stall here would tax every
        // admission decision — fall back to the latent entropy instead.
        let screener_pick = match &screener {
            Some(s) if handle.manifest.input_kind == crate::runtime::InputKind::Tokens => {
                s.pick_replica().map(|r| (s.clone(), InFlightGuard::new(r)))
            }
            // Vision path (or no screener loaded): latent fallback.
            _ => None,
        };
        let (scr_entropy, scr_pred, scr_conf, scr_exec, scr_flops) = match &screener_pick {
            Some((s, guard)) => {
                let input = inputgen::batch_for(&s.manifest, &[req.seed], self.shared.cfg.salt);
                let (o, st) = guard.replica().direct.infer(models::SCREENER, input)?;
                (
                    o.entropy[0] as f64,
                    o.predicted(0),
                    o.confidence(0),
                    st.exec_secs,
                    s.manifest.flops_per_item(1),
                )
            }
            // Latent-confidence entropy the request carries.
            None => (req.entropy(), req.label, req.confidence as f32, 0.0, 0.0),
        };
        drop(screener_pick);

        // 2. Assemble CostInputs from the live feedback signals.
        // Spike reference = 2x nominal per-request joules: the steady
        // state sits at e_norm ~= 0.5 and a genuine energy spike drives
        // it to 0.
        let energy_ref =
            2.0 * self.shared.cfg.device.exec_energy(handle.manifest.flops_per_item(1));
        let x = CostInputs {
            entropy: scr_entropy,
            max_entropy: (handle.manifest.classes as f64).ln(),
            energy_ewma: self.shared.meter.ewma_joules(0.0),
            energy_ref,
            queue_depth: handle.queue_depth(),
            queue_capacity: self.shared.cfg.queue_capacity,
            p95_latency: self.p95(),
            slo_latency: self.shared.cfg.slo_latency,
        };

        // 3. Decide, biased by this model's energy-budget pacer plus —
        // for deferrable (Low-priority) work only — the carbon pacer's
        // pressure: on a dirty grid deferrable requests face a tighter
        // effective τ and skew toward the cheap cache/screener answer.
        let carbon_bias = match (&self.shared.carbon, deferrable) {
            (Some(car), true) => car.tau_bias(),
            _ => 0.0,
        };
        let bias = handle.energy_correction.get() + carbon_bias;
        let decision = ctrl.lock().unwrap().decide_biased(&x, t0, bias);
        match decision {
            Decision::Admit { j, tau } => Ok(AdmitOutcome::Execute { j, tau }),
            Decision::Skip { j, tau, .. } => {
                // Answer from cache / screener argmax (Algorithm 1 line
                // 9). Keys are version-aware: a reloaded version never
                // inherits its predecessor's answers.
                let sig = ResponseCache::signature(
                    &req.model,
                    handle.version,
                    req.seed,
                    self.shared.cfg.cache_clusters,
                );
                let cached = self.shared.cache.get(sig);
                let (label, conf) = match cached {
                    Some(c) => (c.label, c.confidence as f32),
                    None => (scr_pred, scr_conf),
                };
                let latency = self.clock.now() - t0;
                self.latency.lock().unwrap().record(latency);
                // Arrival recorded here (not at submit entry) so admitted
                // requests are not double-counted by the exec path's tap;
                // the recorded instant is still t0, and the rate window
                // clamps any cross-thread ordering races.
                self.shared.metrics.record_arrival(t0);
                self.shared.metrics.record_latency(latency);
                // Energy: only the screener pass.
                let reading = self.shared.meter.record(scr_flops, scr_exec);
                self.shared.metrics.record_joules(self.clock.now(), reading.joules);
                // Carbon-biased skip: credit the emissions the skipped
                // execution would have produced at the current grid
                // intensity (nominal per-request joules = energy_ref/2,
                // net of the screener energy actually spent).
                if carbon_bias > 0.0 {
                    if let Some(car) = &self.shared.carbon {
                        let avoided = (energy_ref / 2.0 - reading.joules).max(0.0);
                        let intensity = car.intensity.get();
                        let mut ledger = car.ledger.lock().unwrap();
                        ledger.record_deferred(avoided, intensity);
                        crate::telemetry::MetricsRegistry::global()
                            .gauge("gf_co2_deferred_grams")
                            .set(ledger.deferred_grams());
                    }
                }
                Ok(AdmitOutcome::Skip {
                    result: InferResult {
                        request_id: req.id,
                        predicted: label,
                        confidence: conf,
                        entropy: scr_entropy as f32,
                        latency_secs: latency,
                        exec_secs: scr_exec,
                        bucket: 0,
                        joules: reading.joules,
                        path: PathKind::CacheSkip,
                        j,
                        tau,
                        served: Served::Cache,
                    },
                })
            }
        }
    }

    /// The closed-loop entry point (Fig. 2): screener → J(x) vs τ(t) →
    /// route or answer from cache.
    pub fn submit(&self, req: &Request, prefer: PathKind) -> Result<InferResult, RuntimeError> {
        let handle = self.resolve(&req.model, None)?;
        self.submit_handle(&handle, req, prefer, None)
    }

    fn submit_handle(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        prefer: PathKind,
        opts: Option<&SubmitOptions>,
    ) -> Result<InferResult, RuntimeError> {
        let t0 = self.clock.now();
        let Some(ctrl) = &self.controller else {
            return self.execute_coalesced(handle, req, prefer, f64::NAN, f64::NAN, opts, t0);
        };
        let deferrable = opts.is_some_and(|o| o.priority == Priority::Low);
        match self.admission_decision(ctrl, handle, req, t0, deferrable)? {
            AdmitOutcome::Execute { j, tau } => {
                self.execute_coalesced(handle, req, prefer, j, tau, opts, t0)
            }
            AdmitOutcome::Skip { result } => Ok(result),
        }
    }

    /// Run one admitted request through the singleflight table: the
    /// first arrival for a signature executes (leader) and publishes
    /// its result; concurrent duplicates park as followers and share
    /// it. Cache-population semantics are the leader's and unchanged
    /// from the pre-coalescing code: controller-admitted work (finite
    /// `j`) populates the cache unless the version was retired
    /// mid-request.
    #[allow(clippy::too_many_arguments)]
    fn execute_coalesced(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        prefer: PathKind,
        j: f64,
        tau: f64,
        opts: Option<&SubmitOptions>,
        t0: f64,
    ) -> Result<InferResult, RuntimeError> {
        // Replica-dispatch checkpoint: admission may have taken long
        // enough (screener pass, controller lock) that the deadline
        // lapsed; drop before engines or the singleflight table see it.
        if let Some(o) = opts {
            if let Some(d) = o.deadline {
                let now = self.clock.now();
                if now >= d {
                    return Err(self.abandon_expired(handle, o, t0, now));
                }
            }
        }
        let sig = ResponseCache::signature(
            &req.model,
            handle.version,
            req.seed,
            self.shared.cfg.cache_clusters,
        );
        match self.shared.coalesce.join(sig) {
            Join::Leader(guard) => match self.infer_on_handle(handle, req, prefer) {
                Ok(mut r) => {
                    r.j = j;
                    r.tau = tau;
                    guard.complete(CoalescedAnswer {
                        predicted: r.predicted,
                        confidence: r.confidence,
                        entropy: r.entropy,
                        exec_secs: r.exec_secs,
                        bucket: r.bucket,
                        path: r.path,
                    });
                    // Populate the cache so future skips can answer —
                    // unless this version was swapped out mid-request (a
                    // straggler must not resurrect entries the unload
                    // invalidated).
                    if r.j.is_finite() && !handle.retired.load(Ordering::SeqCst) {
                        self.shared.cache.put(
                            sig,
                            CachedResponse { label: r.predicted, confidence: r.confidence as f64 },
                        );
                    }
                    Ok(r)
                }
                Err(e) => {
                    guard.fail(&e);
                    Err(e)
                }
            },
            Join::Follower(follower) => {
                self.wait_follower(handle, req, follower, j, tau, opts, t0)
            }
        }
    }

    /// Park on an in-flight leader and account the outcome. A `Ready`
    /// wake-up is an engine execution that never ran: the avoided
    /// joules — the version's per-request energy profile estimate — are
    /// credited to the meter's saved ledger and `gf_joules_saved_total`.
    /// A deadline expiry detaches this follower only; the leader (and
    /// any other follower) keeps running.
    #[allow(clippy::too_many_arguments)]
    fn wait_follower(
        &self,
        handle: &Arc<VersionHandle>,
        req: &Request,
        follower: Follower,
        j: f64,
        tau: f64,
        opts: Option<&SubmitOptions>,
        t0: f64,
    ) -> Result<InferResult, RuntimeError> {
        let timeout = opts
            .and_then(|o| o.deadline)
            .map(|d| Duration::from_secs_f64((d - self.clock.now()).max(0.0)));
        match follower.wait(timeout) {
            FollowerVerdict::Ready(a) => {
                let latency = self.clock.now() - t0;
                self.latency.lock().unwrap().record(latency);
                self.shared.metrics.record_arrival(t0);
                self.shared.metrics.record_latency(latency);
                let saved =
                    self.shared.cfg.device.exec_energy(handle.manifest.flops_per_item(1));
                self.shared.meter.record_saved(saved);
                let reg = crate::telemetry::MetricsRegistry::global();
                reg.gauge("gf_joules_saved_total").set(self.shared.meter.total_joules_saved());
                self.shared.coalesce.note_coalesced();
                Ok(InferResult {
                    request_id: req.id,
                    predicted: a.predicted,
                    confidence: a.confidence,
                    entropy: a.entropy,
                    latency_secs: latency,
                    exec_secs: a.exec_secs,
                    bucket: a.bucket,
                    // The leader's energy was spent and attributed once;
                    // this answer's marginal energy is ~zero.
                    joules: 0.0,
                    path: a.path,
                    j,
                    tau,
                    served: Served::Coalesced,
                })
            }
            FollowerVerdict::Failed(e) => Err(e),
            FollowerVerdict::Retired => {
                Err(RuntimeError::ModelUnavailable { model: req.model.clone() })
            }
            FollowerVerdict::TimedOut => {
                // The follower abandons its wait; the leader keeps
                // running, so no energy was avoided — count the
                // abandonment without a saved-joules credit.
                crate::telemetry::MetricsRegistry::global()
                    .counter("gf_deadline_abandoned_total")
                    .inc();
                let now = self.clock.now();
                let fallback = SubmitOptions::default();
                Err(deadline_error(opts.unwrap_or(&fallback), t0, now))
            }
        }
    }

    /// Fully closed-loop entry point: the shared router (arrival-rate
    /// estimator + adaptive QPS threshold) picks the path, then the
    /// admission controller decides as in [`ServingSystem::submit`].
    pub fn submit_auto(&self, req: &Request) -> Result<InferResult, RuntimeError> {
        let path = self.router.lock().unwrap().route(self.clock.now());
        self.submit(req, path)
    }

    /// The v2-protocol single-request entry point: `submit`/`submit_auto`
    /// semantics plus per-request deadline, priority, and target version
    /// (one-item view of [`ServingSystem::submit_batch`]).
    pub fn submit_opts(
        &self,
        req: &Request,
        prefer: Option<PathKind>,
        opts: &SubmitOptions,
    ) -> Result<InferResult, RuntimeError> {
        let mut results = self.submit_batch(std::slice::from_ref(req), prefer, opts)?;
        results.pop().ok_or_else(|| RuntimeError::Xla("empty batch".into()))
    }

    /// A propagated deadline expired *before* the expensive hand-off:
    /// account the abandoned work and build the typed error. The
    /// execution energy the drop avoided — the version's per-request
    /// profile estimate, the same figure a coalesced follower credits —
    /// goes to the meter's saved ledger and `gf_joules_saved_total`, so
    /// work a caller abandoned upstream shows up in the energy audit
    /// instead of silently burning joules (`gf_deadline_abandoned_total`
    /// counts the drops).
    fn abandon_expired(
        &self,
        handle: &Arc<VersionHandle>,
        opts: &SubmitOptions,
        t0: f64,
        now: f64,
    ) -> RuntimeError {
        let saved = self.shared.cfg.device.exec_energy(handle.manifest.flops_per_item(1));
        self.shared.meter.record_saved(saved);
        let reg = crate::telemetry::MetricsRegistry::global();
        reg.gauge("gf_joules_saved_total").set(self.shared.meter.total_joules_saved());
        reg.counter("gf_deadline_abandoned_total").inc();
        deadline_error(opts, t0, now)
    }

    /// The v2-protocol batch entry point. Semantics:
    ///
    /// * One routing decision and one deadline for the whole body (the
    ///   deadline bounds the client's wait, not each item's share).
    /// * `Priority::High` bypasses the admission controller; `Low` is
    ///   shed with `Backpressure` once the target queue passes ~80%
    ///   occupancy; `Normal` runs per-item admission (the screener runs
    ///   per item).
    /// * All-or-error: the first failure aborts and becomes the result.
    /// * **Coalescing:** a multi-item body on the batched path enqueues
    ///   every admitted item via `BatchedPath::submit` *before*
    ///   collecting any reply, so the dynamic batcher can fuse them
    ///   into one bucket instead of paying the queue delay per item.
    pub fn submit_batch(
        &self,
        reqs: &[Request],
        prefer: Option<PathKind>,
        opts: &SubmitOptions,
    ) -> Result<Vec<InferResult>, RuntimeError> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = self.clock.now();
        let model = &reqs[0].model;
        if let Some(d) = opts.deadline {
            if t0 >= d {
                // Arrived already expired (the client abandoned it
                // upstream): refuse without work, crediting the avoided
                // execution when the model resolves. Resolution errors
                // stay masked by the deadline, as before.
                if let Ok(h) = self.resolve(model, opts.version) {
                    return Err(self.abandon_expired(&h, opts, t0, t0));
                }
                return Err(deadline_error(opts, t0, t0));
            }
        }
        let handle = self.resolve(model, opts.version)?;

        let mut path = match prefer {
            Some(p) => p,
            None => self.router.lock().unwrap().route(t0),
        };
        // A model with no batcher cannot serve the batched path: pinning
        // "batched" there is a client error (not MODEL_NOT_FOUND — the
        // model exists and is loaded), and the model-blind auto router
        // falls back to direct.
        if path == PathKind::Batched && !handle.has_batched() {
            if prefer.is_some() {
                return Err(RuntimeError::InputMismatch(format!(
                    "model {model:?} has no batched path"
                )));
            }
            path = PathKind::Direct;
        }
        if opts.priority == Priority::Low {
            // Low-priority shed: refuse before enqueueing once the queue
            // sits above 4/5 of capacity (cheap head-room guard).
            let depth = handle.queue_depth();
            if depth * 5 >= self.shared.cfg.queue_capacity * 4 {
                return Err(RuntimeError::Backpressure(model.clone()));
            }
        }
        let bypass_admission = opts.priority == Priority::High || self.controller.is_none();

        // Single item, direct path, or batcher-less model: the plain
        // sequential route.
        if reqs.len() < 2 || path != PathKind::Batched {
            let mut out = Vec::with_capacity(reqs.len());
            for req in reqs {
                if let Some(d) = opts.deadline {
                    let now = self.clock.now();
                    if now >= d {
                        return Err(self.abandon_expired(&handle, opts, t0, now));
                    }
                }
                let r = if bypass_admission {
                    let now = self.clock.now();
                    self.execute_coalesced(&handle, req, path, f64::NAN, f64::NAN, Some(opts), now)?
                } else {
                    self.submit_handle(&handle, req, path, Some(opts))?
                };
                out.push(r);
            }
            if let Some(d) = opts.deadline {
                let now = self.clock.now();
                if now > d {
                    return Err(deadline_error(opts, t0, now));
                }
            }
            return Ok(out);
        }

        // Pin one replica for the whole body: coalescing only works if
        // every admitted item lands on the *same* batcher. The guard
        // keeps the replica alive (and its in-flight count honest)
        // across Phases A–C; at zero replicas this is the cold start.
        let batch_guard = SystemShared::acquire_replica(&self.shared, &handle)?;
        let batched = batch_guard
            .replica()
            .batched
            .as_ref()
            .expect("batched-capable replicas carry a batcher");

        // Phase A — per-item admission (screener runs per item; skips
        // answer immediately from cache).
        enum ItemPlan {
            Skip(InferResult),
            Exec { j: f64, tau: f64 },
        }
        let mut plans = Vec::with_capacity(reqs.len());
        for req in reqs {
            // Nothing is enqueued yet, so a deadline that expires during
            // the per-item screener passes still refuses the whole body
            // for free (same contract as the sequential path).
            if let Some(d) = opts.deadline {
                let now = self.clock.now();
                if now >= d {
                    return Err(self.abandon_expired(&handle, opts, t0, now));
                }
            }
            if bypass_admission {
                plans.push(ItemPlan::Exec { j: f64::NAN, tau: f64::NAN });
            } else {
                let ctrl = self.controller.as_ref().expect("checked above");
                let deferrable = opts.priority == Priority::Low;
                match self.admission_decision(ctrl, &handle, req, self.clock.now(), deferrable)? {
                    AdmitOutcome::Execute { j, tau } => plans.push(ItemPlan::Exec { j, tau }),
                    AdmitOutcome::Skip { result } => plans.push(ItemPlan::Skip(result)),
                }
            }
        }

        // Phase B — enqueue every admitted item before collecting any
        // reply, so one body fuses into shared buckets. Each item joins
        // the singleflight table first: only leaders enqueue engine
        // work; duplicates (within this body or racing another client)
        // park as followers and share the leader's bucket result. An
        // enqueue failure (backpressure) aborts the batch; receivers
        // already enqueued are dropped and their replies discarded by
        // the batcher, and the dropped leader guards publish the typed
        // failure to any follower (all-or-error contract).
        type Reply = mpsc::Receiver<Result<(OutputBatch, ExecStats), RuntimeError>>;
        enum Slot<'a> {
            Skip,
            Lead { t_item: f64, rx: Reply, guard: crate::pipeline::coalesce::LeaderGuard<'a> },
            Follow { t_item: f64, follower: Follower },
        }
        let mut pending: Vec<Slot> = Vec::with_capacity(reqs.len());
        for (req, plan) in reqs.iter().zip(&plans) {
            match plan {
                ItemPlan::Skip(_) => pending.push(Slot::Skip),
                ItemPlan::Exec { .. } => {
                    let t_item = self.clock.now();
                    // Last check before engine work is enqueued: an item
                    // whose deadline expired while earlier items joined
                    // the batcher must not buy a bucket slot. Receivers
                    // already enqueued are dropped (the batcher discards
                    // their replies) and the dropped leader guards
                    // publish the failure to any follower.
                    if let Some(d) = opts.deadline {
                        if t_item >= d {
                            return Err(self.abandon_expired(&handle, opts, t0, t_item));
                        }
                    }
                    let sig = ResponseCache::signature(
                        &req.model,
                        handle.version,
                        req.seed,
                        self.shared.cfg.cache_clusters,
                    );
                    match self.shared.coalesce.join(sig) {
                        Join::Leader(guard) => {
                            // Followers record their arrival in
                            // `wait_follower`; leaders here.
                            self.shared.metrics.record_arrival(t_item);
                            match batched.submit(req.seed) {
                                Ok(rx) => pending.push(Slot::Lead { t_item, rx, guard }),
                                Err(e) => {
                                    guard.fail(&e);
                                    return Err(e);
                                }
                            }
                        }
                        Join::Follower(follower) => {
                            pending.push(Slot::Follow { t_item, follower })
                        }
                    }
                }
            }
        }

        // Phase C — collect replies in request order and account each
        // item exactly as a lone batched execution would be. A body's
        // internal duplicates always see their leader earlier in the
        // vector (join order), so its result is published before the
        // follower's wait.
        let mut out = Vec::with_capacity(reqs.len());
        for ((req, plan), slot) in reqs.iter().zip(plans).zip(pending) {
            match (plan, slot) {
                (ItemPlan::Skip(result), _) => out.push(result),
                (ItemPlan::Exec { j, tau }, Slot::Lead { t_item, rx, guard }) => {
                    let exec = rx
                        .recv()
                        .map_err(|_| RuntimeError::Xla("reply dropped".into()))
                        .and_then(|r| r);
                    let (ob, stats) = match exec {
                        Ok(v) => v,
                        Err(e) => {
                            guard.fail(&e);
                            return Err(e);
                        }
                    };
                    let mut r = match self
                        .finish_exec(&handle, req, PathKind::Batched, t_item, &ob, &stats)
                    {
                        Ok(r) => r,
                        Err(e) => {
                            guard.fail(&e);
                            return Err(e);
                        }
                    };
                    r.j = j;
                    r.tau = tau;
                    guard.complete(CoalescedAnswer {
                        predicted: r.predicted,
                        confidence: r.confidence,
                        entropy: r.entropy,
                        exec_secs: r.exec_secs,
                        bucket: r.bucket,
                        path: r.path,
                    });
                    if r.j.is_finite() && !handle.retired.load(Ordering::SeqCst) {
                        // Controller-admitted work populates the cache so
                        // future skips can answer (same as `submit`;
                        // retired versions must not re-populate what
                        // their unload invalidated).
                        let sig = ResponseCache::signature(
                            &req.model,
                            handle.version,
                            req.seed,
                            self.shared.cfg.cache_clusters,
                        );
                        self.shared.cache.put(
                            sig,
                            CachedResponse {
                                label: r.predicted,
                                confidence: r.confidence as f64,
                            },
                        );
                    }
                    out.push(r);
                }
                (ItemPlan::Exec { j, tau }, Slot::Follow { t_item, follower }) => {
                    out.push(self.wait_follower(
                        &handle,
                        req,
                        follower,
                        j,
                        tau,
                        Some(opts),
                        t_item,
                    )?);
                }
                (ItemPlan::Exec { .. }, Slot::Skip) => {
                    unreachable!("exec plans always join the singleflight table")
                }
            }
        }
        if let Some(d) = opts.deadline {
            let now = self.clock.now();
            if now > d {
                return Err(deadline_error(opts, t0, now));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::threshold::ThresholdSchedule;
    use crate::workload::stream::{RequestStream, StreamConfig};

    fn repo_root() -> Option<PathBuf> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    fn requests(n: usize, model: &str) -> Vec<Request> {
        let mut s = RequestStream::new(
            StreamConfig { model: model.to_string(), ..Default::default() },
            11,
        );
        (0..n).map(|i| s.next_request(i as f64 * 0.01)).collect()
    }

    #[test]
    fn open_loop_dual_path_works() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(3, models::DISTILBERT);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert_eq!(d.path, PathKind::Direct);
            assert!(d.latency_secs > 0.0);
            assert!(d.joules > 0.0);
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(b.path, PathKind::Batched);
            assert!((0..2).contains(&(d.predicted as i32)));
            assert_eq!(d.predicted, b.predicted, "paths agree on the answer");
        }
        assert!(sys.meter().total_joules() > 0.0);
        assert!(sys.p95() > 0.0);
    }

    #[test]
    fn default_mode_loads_every_model_at_boot() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        assert_eq!(sys.ready_models(), sys.model_names().len());
        let h = sys.version_handle(models::DISTILBERT, None).expect("loaded");
        assert_eq!(h.version(), 1, "flat layout serves as version 1");
        assert!(h.load_stats().load_secs > 0.0);
        assert!(h.load_stats().weight_bytes > 0);
        assert!(h.load_stats().est_load_joules > 0.0);
    }

    #[test]
    fn closed_loop_skips_and_admits() {
        let Some(root) = repo_root() else { return };
        // Strict constant τ: plenty of skips on confident requests.
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.95 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        let reqs = requests(20, models::DISTILBERT);
        let mut skipped = 0;
        for r in &reqs {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            if res.path == PathKind::CacheSkip {
                skipped += 1;
                assert_eq!(res.bucket, 0);
                assert!(res.j < res.tau);
            }
        }
        let stats = sys.controller_stats().unwrap();
        assert_eq!(stats.total(), 20);
        assert_eq!(stats.skipped, skipped);
        assert!(skipped > 0, "strict τ must skip something");
    }

    #[test]
    fn permissive_controller_admits_everything() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.0 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        for r in &requests(5, models::DISTILBERT) {
            let res = sys.submit(r, PathKind::Direct).unwrap();
            assert_ne!(res.path, PathKind::CacheSkip);
            assert!(res.j >= res.tau);
        }
        assert_eq!(sys.controller_stats().unwrap().admitted, 5);
    }

    #[test]
    fn control_plane_boots_and_serves() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root)
            .with_controller(ControllerConfig {
                weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
                schedule: ThresholdSchedule::Constant { tau: 0.5 },
                respond_from_cache: true,
            })
            .with_control(
                crate::control::ControlPlaneConfig {
                    tick_secs: 0.005,
                    ..Default::default()
                }
                .with_adaptive_tau(0.5)
                .with_adaptive_batch_delay(0.25)
                .with_adaptive_router(0.25)
                .with_energy_budget(100.0)
                .with_replica_scaler(4, 30.0),
            );
        let sys = ServingSystem::start(cfg).unwrap();
        assert!(sys.control_plane_running());
        let names = sys.control_loop_names();
        assert!(names.iter().any(|n| n == "tau_correction"), "{names:?}");
        assert!(names.iter().any(|n| n == "router_qps_threshold"), "{names:?}");
        // The energy budget is per batched path now (one pacer per
        // loaded model version), keyed energy_budget.<model>/<version>.
        assert!(
            names.iter().any(|n| n.starts_with("energy_budget.")),
            "{names:?}"
        );
        // One replica scaler per batched-capable version; the screener
        // (batcher-less) must not get one.
        assert!(
            names.iter().any(|n| n.starts_with("replica_scaler.")),
            "{names:?}"
        );
        assert!(
            !names.iter().any(|n| n.contains(&format!("replica_scaler.{}", models::SCREENER))),
            "{names:?}"
        );
        // batch_delay_us.<model>/<v> loops appear once per version whose
        // config sets a nonzero queue-delay window, so their presence
        // depends on the artifacts' config.pbtxt files — not asserted.

        for r in &requests(10, models::DISTILBERT) {
            let res = sys.submit_auto(r).unwrap();
            assert!(res.latency_secs >= 0.0);
        }
        assert!(sys.metrics().events() >= 10);
        assert!(sys.router_qps() > 0.0);
        // let the ticker observe the traffic at least once
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(sys.controller_stats().unwrap().total(), 10);
    }

    #[test]
    fn per_model_loops_detach_on_unload() {
        let Some(root) = repo_root() else { return };
        let cfg = SystemConfig::new(root).with_control(
            crate::control::ControlPlaneConfig { tick_secs: 0.005, ..Default::default() }
                .with_energy_budget(100.0),
        );
        let sys = ServingSystem::start(cfg).unwrap();
        let loop_name = format!("energy_budget.{}/1", models::DISTILBERT);
        assert!(sys.control_loop_names().contains(&loop_name));
        sys.unload_model(models::DISTILBERT, None).unwrap();
        assert!(!sys.control_loop_names().contains(&loop_name));
        sys.load_model(models::DISTILBERT, None).unwrap();
        assert!(sys.control_loop_names().contains(&loop_name));
    }

    #[test]
    fn unload_makes_model_unavailable_and_reload_restores() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(2, models::DISTILBERT);
        assert!(sys.infer_on(&reqs[0], PathKind::Direct).is_ok());

        let unloaded = sys.unload_model(models::DISTILBERT, None).unwrap();
        assert_eq!(unloaded, vec![1]);
        let err = sys.infer_on(&reqs[0], PathKind::Direct).unwrap_err();
        assert!(
            matches!(err, RuntimeError::ModelUnavailable { .. }),
            "unloaded model must 503, got {err}"
        );
        // A model that was never in the repository is still 404 material.
        let ghost = Request::external(7, "ghost", 1, sys.clock().now());
        assert!(matches!(
            sys.infer_on(&ghost, PathKind::Direct).unwrap_err(),
            RuntimeError::UnknownModel(_)
        ));

        let loaded = sys.load_model(models::DISTILBERT, None).unwrap();
        assert_eq!(loaded, vec![1]);
        let r = sys.infer_on(&reqs[1], PathKind::Direct).unwrap();
        assert!(r.latency_secs > 0.0);
    }

    #[test]
    fn submit_batch_coalesces_into_shared_buckets() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(16, models::DISTILBERT);
        let results = sys
            .submit_batch(&reqs, Some(PathKind::Batched), &SubmitOptions::default())
            .unwrap();
        assert_eq!(results.len(), 16);
        for (req, r) in reqs.iter().zip(&results) {
            assert_eq!(r.request_id, req.id, "results stay in request order");
            assert_eq!(r.path, PathKind::Batched);
        }
        // The regression this guards: 16 items enqueued before any reply
        // is collected must fuse into multi-item buckets, not execute as
        // 16 singletons.
        assert!(
            results.iter().any(|r| r.bucket >= 2),
            "no multi-item bucket formed: {:?}",
            results.iter().map(|r| r.bucket).collect::<Vec<_>>()
        );
    }

    #[test]
    fn submit_opts_honors_deadline_and_priority() {
        let Some(root) = repo_root() else { return };
        // Strict constant τ so Normal-priority requests mostly skip.
        let cfg = SystemConfig::new(root).with_controller(ControllerConfig {
            weights: crate::controller::cost::WeightPolicy::Balanced.weights(),
            schedule: ThresholdSchedule::Constant { tau: 0.95 },
            respond_from_cache: true,
        });
        let sys = ServingSystem::start(cfg).unwrap();
        let reqs = requests(4, models::DISTILBERT);

        // Already-expired deadline: refused before any work.
        let expired = SubmitOptions {
            deadline: Some(0.0),
            ..SubmitOptions::default()
        };
        let err = sys.submit_opts(&reqs[0], Some(PathKind::Direct), &expired).unwrap_err();
        assert!(matches!(err, RuntimeError::DeadlineExceeded { .. }), "{err}");

        // High priority bypasses the admission skip even under strict τ.
        let high = SubmitOptions { priority: Priority::High, ..Default::default() };
        let r = sys.submit_opts(&reqs[1], Some(PathKind::Direct), &high).unwrap();
        assert_ne!(r.path, PathKind::CacheSkip);

        // A generous deadline passes through (auto-routed).
        let opts = SubmitOptions::with_timeout(sys.clock().now(), 30_000, Priority::Normal);
        assert!(sys.submit_opts(&reqs[2], None, &opts).is_ok());

        // Default options reproduce submit() semantics.
        let dflt = SubmitOptions::default();
        assert!(sys.submit_opts(&reqs[3], Some(PathKind::Direct), &dflt).is_ok());

        // Pinning an explicit version works and a missing one is a 503.
        let versioned = SubmitOptions { version: Some(1), ..Default::default() };
        assert!(sys.submit_opts(&reqs[3], Some(PathKind::Direct), &versioned).is_ok());
        let missing = SubmitOptions { version: Some(99), ..Default::default() };
        let err = sys
            .submit_opts(&reqs[3], Some(PathKind::Direct), &missing)
            .unwrap_err();
        assert!(matches!(err, RuntimeError::ModelUnavailable { .. }), "{err}");

        // Pinning "batched" on a model with no batcher is an input error
        // (the model exists — it must not read as MODEL_NOT_FOUND).
        if !sys.has_batched_path(models::SCREENER) {
            let req = Request::external(99, models::SCREENER, 1, sys.clock().now());
            let err = sys
                .submit_opts(&req, Some(PathKind::Batched), &SubmitOptions::default())
                .unwrap_err();
            assert!(matches!(err, RuntimeError::InputMismatch(_)), "{err}");
        }
    }

    #[test]
    fn no_control_config_means_no_plane() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        assert!(!sys.control_plane_running());
        assert!(sys.control_loop_names().is_empty());
    }

    #[test]
    fn resnet_serves_on_both_paths() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reqs = requests(2, models::RESNET);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            assert!((0..10).contains(&(d.predicted as i32)));
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(d.predicted, b.predicted);
        }
    }

    #[test]
    fn p2c_indices_are_deterministic_and_in_range() {
        for n in 1..=7usize {
            for t in 0..500u64 {
                let (i, j) = p2c_indices(t, n);
                assert!(i < n && j < n, "({i},{j}) out of range for n={n}");
                assert_eq!((i, j), p2c_indices(t, n), "same ticket, same pair");
            }
        }
        // Over many tickets both probes must spread across the set —
        // a scheduler that always probes replica 0 is no scheduler.
        let n = 4;
        let mut seen = [false; 4];
        for t in 0..64u64 {
            let (i, j) = p2c_indices(t, n);
            seen[i] = true;
            seen[j] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn operator_scale_up_and_down_converges() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let (ready, target, _) = sys.replica_counts(models::DISTILBERT, None).unwrap();
        assert_eq!((ready, target), (1, 1), "versions boot with one replica");

        sys.scale_replicas(models::DISTILBERT, None, 3).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        while sys.replica_counts(models::DISTILBERT, None).unwrap().0 != 3 {
            assert!(Instant::now() < deadline, "scale-up never converged");
            std::thread::sleep(Duration::from_millis(5));
        }
        // All three replicas serve; p2c keeps answers identical.
        let reqs = requests(6, models::DISTILBERT);
        for r in &reqs {
            let d = sys.infer_on(r, PathKind::Direct).unwrap();
            let b = sys.infer_on(r, PathKind::Batched).unwrap();
            assert_eq!(d.predicted, b.predicted);
        }

        sys.scale_replicas(models::DISTILBERT, None, 1).unwrap();
        while sys.replica_counts(models::DISTILBERT, None).unwrap().0 != 1 {
            assert!(Instant::now() < deadline, "scale-down never converged");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(sys.infer_on(&reqs[0], PathKind::Direct).is_ok());
    }

    #[test]
    fn scale_to_zero_cold_starts_on_next_request() {
        let Some(root) = repo_root() else { return };
        let sys = ServingSystem::start(SystemConfig::new(root)).unwrap();
        let reg = crate::telemetry::MetricsRegistry::global();
        let cold0 = reg.counter_value("gf_cold_starts_total").unwrap_or(0);

        sys.scale_replicas(models::RESNET, None, 0).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        while sys.replica_counts(models::RESNET, None).unwrap().0 != 0 {
            assert!(Instant::now() < deadline, "scale-to-zero never converged");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Still resolvable — scale-to-zero is not an unload.
        assert!(sys.version_handle(models::RESNET, None).is_some());

        // The next request cold-starts instead of 503ing, and exactly
        // one cold start is counted for it.
        let r = requests(1, models::RESNET).pop().unwrap();
        let res = sys.infer_on(&r, PathKind::Direct).unwrap();
        assert!(res.latency_secs > 0.0);
        assert_eq!(
            reg.counter_value("gf_cold_starts_total").unwrap_or(0) - cold0,
            1,
            "one cold start for the wake-up request"
        );
        assert!(sys.replica_counts(models::RESNET, None).unwrap().0 >= 1);
    }
}
