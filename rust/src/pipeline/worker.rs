//! Engine worker threads and instance pools (Triton instance-group
//! semantics: N independent execution contexts per model).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::runtime::engine::{ExecMode, ExecStats};
use crate::runtime::tensor::{InputBatch, OutputBatch};
use crate::runtime::{Engine, RuntimeError};

/// One unit of work for an engine worker.
pub struct Job {
    pub model: String,
    pub input: InputBatch,
    /// Reply channel (bounded 1: the worker never blocks on send).
    pub reply: mpsc::SyncSender<Result<(OutputBatch, ExecStats), RuntimeError>>,
}

enum Msg {
    Work(Job),
    Shutdown,
}

/// Handle to one worker thread owning a PJRT engine.
struct Worker {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    /// Spawn a worker that builds its own engine (Engine is not Send) and
    /// loads the given model directories.
    fn spawn(model_dirs: Vec<PathBuf>, mode: ExecMode) -> Result<Worker, RuntimeError> {
        let (tx, rx) = mpsc::channel::<Msg>();
        // Report engine construction errors back synchronously.
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<(), RuntimeError>>(1);
        let handle = std::thread::Builder::new()
            .name("gf-engine-worker".to_string())
            .spawn(move || {
                let mut engine = match Engine::cpu(mode) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for dir in &model_dirs {
                    if let Err(e) = engine.load_model(dir) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Work(job) => {
                            let res = engine.execute(&job.model, &job.input);
                            let _ = job.reply.send(res);
                        }
                        Msg::Shutdown => break,
                    }
                }
            })
            .expect("spawn worker");
        ready_rx.recv().map_err(|_| RuntimeError::Xla("worker died during init".into()))??;
        Ok(Worker { tx, handle: Some(handle) })
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Round-robin pool of engine workers (one Triton instance group).
pub struct InstancePool {
    workers: Vec<Worker>,
    next: AtomicUsize,
}

impl InstancePool {
    /// Spawn `count` workers, each loading `model_dirs`.
    pub fn new(
        model_dirs: Vec<PathBuf>,
        count: usize,
        mode: ExecMode,
    ) -> Result<InstancePool, RuntimeError> {
        assert!(count >= 1);
        let mut workers = Vec::with_capacity(count);
        for _ in 0..count {
            workers.push(Worker::spawn(model_dirs.clone(), mode)?);
        }
        Ok(InstancePool { workers, next: AtomicUsize::new(0) })
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch a job to the next instance (round-robin) without waiting.
    pub fn dispatch(&self, job: Job) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len();
        self.workers[i].tx.send(Msg::Work(job)).expect("worker alive");
    }

    /// Dispatch and block for the result (the direct-path call).
    pub fn execute(
        &self,
        model: &str,
        input: InputBatch,
    ) -> Result<(OutputBatch, ExecStats), RuntimeError> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.dispatch(Job { model: model.to_string(), input, reply });
        rx.recv().map_err(|_| RuntimeError::Xla("worker dropped reply".into()))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::inputgen;
    use std::path::Path;

    fn repo_root() -> Option<PathBuf> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    #[test]
    fn pool_executes_jobs() {
        let Some(root) = repo_root() else { return };
        let pool =
            InstancePool::new(vec![root.join("screener")], 1, ExecMode::Literals).unwrap();
        let man = crate::runtime::ModelManifest::load(&root.join("screener")).unwrap();
        let input = inputgen::tokens_for(&man, &[1], 0);
        let (out, stats) = pool.execute("screener", input).unwrap();
        assert_eq!(out.batch, 1);
        assert_eq!(stats.bucket, 1);
    }

    #[test]
    fn pool_round_robins_across_instances() {
        let Some(root) = repo_root() else { return };
        let pool =
            InstancePool::new(vec![root.join("screener")], 2, ExecMode::Literals).unwrap();
        assert_eq!(pool.size(), 2);
        let man = crate::runtime::ModelManifest::load(&root.join("screener")).unwrap();
        // Concurrent callers from multiple threads.
        std::thread::scope(|s| {
            for k in 0..4 {
                let pool = &pool;
                let man = &man;
                s.spawn(move || {
                    let input = inputgen::tokens_for(man, &[k], 0);
                    let (out, _) = pool.execute("screener", input).unwrap();
                    assert_eq!(out.batch, 1);
                });
            }
        });
    }

    #[test]
    fn unknown_model_error_propagates() {
        let Some(root) = repo_root() else { return };
        let pool =
            InstancePool::new(vec![root.join("screener")], 1, ExecMode::Literals).unwrap();
        let input = InputBatch::Tokens { data: vec![0; 32], batch: 1, per_item: 32 };
        assert!(pool.execute("missing", input).is_err());
    }

    #[test]
    fn bad_model_dir_fails_spawn() {
        assert!(InstancePool::new(
            vec![PathBuf::from("/nonexistent/model")],
            1,
            ExecMode::Literals
        )
        .is_err());
    }
}
