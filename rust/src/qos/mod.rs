//! Per-tenant QoS admission: GCRA rate limits, retry budgets, and the
//! header surface for deadline propagation.
//!
//! This layer sits between the HTTP gateway and
//! [`crate::pipeline::ServingSystem`]: every inference request is
//! attributed to a tenant (the `X-Tenant-Id` header, or the `default`
//! tenant when absent) and must clear two per-tenant gates *before* it
//! reaches the energy-aware admission controller:
//!
//! 1. **GCRA rate limit** — each tenant owns a Generic Cell Rate
//!    Algorithm limiter in its virtual-scheduling form. The limiter
//!    keeps a single float, the *theoretical arrival time* (TAT). With
//!    rate `r` requests/s the emission interval is `T = 1/r` and the
//!    burst tolerance is `τ = (burst − 1)·T`. An arrival at time `t`
//!    conforms iff `max(TAT, t) − t ≤ τ`; on admit the TAT advances by
//!    `T` per admitted item. Over any window of `W` seconds this admits
//!    at most `r·W + burst` items — the bound the property tests pin.
//!    Non-conforming arrivals are shed with `RATE_LIMITED`/429 and a
//!    `Retry-After` hint derived from the TAT overshoot.
//!
//!    The per-tenant rate is an [`Adaptive<u32>`] cell: the
//!    `QuotaScaler` control law (see [`crate::control::law`]) shrinks
//!    every tenant's quota multiplicatively when the global power draw
//!    is over budget and lets it recover toward the configured base
//!    rate when pressure clears.
//!
//! 2. **Retry budget** — clients mark retries with `X-Retry-Attempt`.
//!    A windowed ledger per tenant admits a retry only while
//!    `retries + 1 ≤ fraction × successes` over the trailing window,
//!    so retry storms decay geometrically instead of amplifying energy
//!    spend. Over-budget retries are shed with
//!    `RETRY_BUDGET_EXHAUSTED`/429 before they can reach the admission
//!    controller or burn engine joules.
//!
//! Deadline propagation itself (the `X-Request-Deadline` header) is
//! parsed here ([`parse_deadline_unix_ms`]) but enforced in the
//! pipeline: the gateway converts the absolute unix-millis deadline
//! into the serving system's monotonic clock domain and the pipeline
//! checks it at every expensive hand-off, crediting the avoided
//! execution energy to the saved-joules ledger.
//!
//! All decision state is time-explicit (`now` is a parameter, never
//! sampled internally), so the deterministic tenancy sim
//! ([`crate::sim::tenancy`]) and the property tests drive the very same
//! code that serves live traffic.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::control::Adaptive;
use crate::telemetry::registry::Counter;
use crate::telemetry::MetricsRegistry;

/// Header naming the tenant a request is accounted to.
pub const TENANT_HEADER: &str = "X-Tenant-Id";
/// Header marking a request as the N-th retry of an earlier attempt.
pub const RETRY_HEADER: &str = "X-Retry-Attempt";
/// Header carrying an absolute request deadline in unix milliseconds.
pub const DEADLINE_HEADER: &str = "X-Request-Deadline";
/// Tenant used when a request carries no `X-Tenant-Id` header.
pub const DEFAULT_TENANT: &str = "default";

/// Longest accepted tenant id, in bytes.
pub const MAX_TENANT_ID_LEN: usize = 64;

/// Static configuration for the QoS layer.
///
/// Defaults are deliberately generous: single-tenant deployments (and
/// every pre-existing test and bench) run under the `default` tenant
/// and must never be shed by a limiter they did not opt into.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Base GCRA rate for every tenant, requests per second.
    pub default_rate_rps: u32,
    /// GCRA burst tolerance, in requests (≥ 1).
    pub default_burst: u32,
    /// Retries admitted per success over the trailing window
    /// (`0.1` = one retry per ten successes).
    pub retry_fraction: f64,
    /// Width of the retry-ledger window, seconds.
    pub retry_window_secs: f64,
    /// Hard cap on distinct tenants; excess ids share the `default`
    /// tenant's quota so a header-spraying client cannot grow the
    /// table (or the metrics namespace) without bound.
    pub max_tenants: usize,
    /// Shard count for the tenant table (power of two recommended).
    pub shards: usize,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            default_rate_rps: 250_000,
            default_burst: 50_000,
            retry_fraction: 0.1,
            retry_window_secs: 10.0,
            max_tenants: 64,
            shards: 8,
        }
    }
}

/// Outcome of a QoS admission decision.
#[derive(Debug, Clone, PartialEq)]
pub enum QosVerdict {
    /// The request may proceed to the admission controller.
    Admit,
    /// The tenant is over its GCRA quota; retry after the given
    /// number of seconds (the TAT overshoot).
    RateLimited {
        /// Seconds until the earliest conforming arrival.
        retry_after_secs: f64,
    },
    /// The tenant's retry budget is exhausted; the retry is shed
    /// before touching admission.
    RetryBudgetExhausted,
}

/// GCRA limiter state in virtual-scheduling form: one float, the
/// theoretical arrival time of the next conforming cell.
#[derive(Debug, Clone, Default)]
pub struct Gcra {
    tat: f64,
}

impl Gcra {
    /// Fresh limiter; the first arrival always conforms.
    pub fn new() -> Self {
        Gcra { tat: 0.0 }
    }

    /// Decide `items` arrivals at time `now` (seconds) against
    /// `rate_rps`/`burst`. `Ok(())` admits and advances the TAT;
    /// `Err(wait)` rejects with the seconds until the batch would
    /// conform. Admitting never exceeds `rate × W + burst` items over
    /// any window of `W` seconds.
    pub fn decide(&mut self, now: f64, rate_rps: u32, burst: u32, items: u32) -> Result<(), f64> {
        let items = items.max(1);
        let t = 1.0 / f64::from(rate_rps.max(1));
        let tolerance = f64::from(burst.max(1) - 1) * t;
        let base = self.tat.max(now);
        // All `items` cells conform iff the last one is within tolerance.
        let offset = (base - now) + f64::from(items - 1) * t;
        if offset > tolerance + 1e-9 {
            Err(offset - tolerance)
        } else {
            self.tat = base + f64::from(items) * t;
            Ok(())
        }
    }

    /// Current theoretical arrival time (test/introspection hook).
    pub fn tat(&self) -> f64 {
        self.tat
    }
}

const LEDGER_BUCKETS_MIN: usize = 1;

#[derive(Debug, Clone, Copy, Default)]
struct LedgerBucket {
    /// `second + 1` of the bucket's data; 0 = empty.
    epoch1: u64,
    successes: u64,
    retries: u64,
}

/// Windowed per-tenant retry ledger: ring of one-second buckets
/// tracking successes and admitted retries over the trailing window.
///
/// A retry is admissible only while
/// `retries + 1 ≤ fraction × successes` over the window, so after each
/// admission the invariant `retries ≤ fraction × successes` holds for
/// any interleaving of events — with zero recent successes no retries
/// are admitted at all.
#[derive(Debug, Clone)]
pub struct RetryLedger {
    buckets: Vec<LedgerBucket>,
}

impl RetryLedger {
    /// Ledger with a trailing window of `window_secs` (rounded up to
    /// whole seconds, minimum one).
    pub fn new(window_secs: f64) -> Self {
        let n = (window_secs.max(1.0).ceil() as usize).max(LEDGER_BUCKETS_MIN);
        RetryLedger { buckets: vec![LedgerBucket::default(); n] }
    }

    fn second(now: f64) -> u64 {
        now.max(0.0).floor() as u64
    }

    fn bucket_mut(&mut self, now: f64) -> &mut LedgerBucket {
        let sec = Self::second(now);
        let n = self.buckets.len() as u64;
        let b = &mut self.buckets[(sec % n) as usize];
        if b.epoch1 != sec + 1 {
            *b = LedgerBucket { epoch1: sec + 1, successes: 0, retries: 0 };
        }
        b
    }

    /// `(successes, retries)` within the trailing window ending at `now`.
    pub fn totals(&self, now: f64) -> (u64, u64) {
        let sec = Self::second(now);
        let n = self.buckets.len() as u64;
        let mut s = 0;
        let mut r = 0;
        for b in &self.buckets {
            if b.epoch1 != 0 && b.epoch1 - 1 + n > sec && b.epoch1 - 1 <= sec {
                s += b.successes;
                r += b.retries;
            }
        }
        (s, r)
    }

    /// Would one more retry stay within `fraction × successes`?
    pub fn would_allow_retry(&self, now: f64, fraction: f64) -> bool {
        let (successes, retries) = self.totals(now);
        (retries + 1) as f64 <= fraction * successes as f64
    }

    /// Record an admitted retry.
    pub fn note_retry(&mut self, now: f64) {
        self.bucket_mut(now).retries += 1;
    }

    /// Record `items` successfully served items.
    pub fn note_success(&mut self, now: f64, items: u64) {
        self.bucket_mut(now).successes += items;
    }
}

#[derive(Debug)]
struct TenantState {
    gcra: Gcra,
    retry: RetryLedger,
}

/// One tenant: quota cell, limiter state, and accounting.
#[derive(Debug)]
pub struct Tenant {
    name: String,
    base_rate_rps: u32,
    rate_rps: Adaptive<u32>,
    burst: u32,
    state: Mutex<TenantState>,
    admitted: AtomicU64,
    shed_rate_limited: AtomicU64,
    shed_retry_budget: AtomicU64,
    successes: AtomicU64,
    retries_admitted: AtomicU64,
    admitted_counter: Arc<Counter>,
    shed_counter: Arc<Counter>,
}

impl Tenant {
    fn new(name: &str, cfg: &QosConfig, scale: f64) -> Self {
        let reg = MetricsRegistry::global();
        Tenant {
            name: name.to_string(),
            base_rate_rps: cfg.default_rate_rps,
            rate_rps: Adaptive::new(scaled_rate(cfg.default_rate_rps, scale)),
            burst: cfg.default_burst.max(1),
            state: Mutex::new(TenantState {
                gcra: Gcra::new(),
                retry: RetryLedger::new(cfg.retry_window_secs),
            }),
            admitted: AtomicU64::new(0),
            shed_rate_limited: AtomicU64::new(0),
            shed_retry_budget: AtomicU64::new(0),
            successes: AtomicU64::new(0),
            retries_admitted: AtomicU64::new(0),
            admitted_counter: reg.counter(&format!("gf_tenant_admitted_total.{name}")),
            shed_counter: reg.counter(&format!("gf_tenant_shed_total.{name}")),
        }
    }

    /// Tenant name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Current (possibly scaled-down) GCRA rate in requests/s.
    pub fn rate_rps(&self) -> u32 {
        self.rate_rps.get()
    }

    fn decide(&self, items: u32, retry_attempt: u32, now: f64, fraction: f64) -> QosVerdict {
        let is_retry = retry_attempt > 0;
        let mut st = self.state.lock().unwrap();
        if is_retry && !st.retry.would_allow_retry(now, fraction) {
            drop(st);
            self.shed_retry_budget.fetch_add(1, Ordering::Relaxed);
            self.shed_counter.inc();
            return QosVerdict::RetryBudgetExhausted;
        }
        match st.gcra.decide(now, self.rate_rps.get(), self.burst, items) {
            Ok(()) => {
                if is_retry {
                    st.retry.note_retry(now);
                    self.retries_admitted.fetch_add(1, Ordering::Relaxed);
                }
                drop(st);
                self.admitted.fetch_add(u64::from(items.max(1)), Ordering::Relaxed);
                self.admitted_counter.add(u64::from(items.max(1)));
                QosVerdict::Admit
            }
            Err(wait) => {
                drop(st);
                self.shed_rate_limited.fetch_add(1, Ordering::Relaxed);
                self.shed_counter.inc();
                QosVerdict::RateLimited { retry_after_secs: wait }
            }
        }
    }

    fn stats(&self) -> TenantStats {
        TenantStats {
            name: self.name.clone(),
            base_rate_rps: self.base_rate_rps,
            rate_rps: self.rate_rps.get(),
            burst: self.burst,
            admitted: self.admitted.load(Ordering::Relaxed),
            shed_rate_limited: self.shed_rate_limited.load(Ordering::Relaxed),
            shed_retry_budget: self.shed_retry_budget.load(Ordering::Relaxed),
            successes: self.successes.load(Ordering::Relaxed),
            retries_admitted: self.retries_admitted.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time accounting snapshot for one tenant (the
/// `/v2/tenants` payload).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    /// Tenant name.
    pub name: String,
    /// Configured base GCRA rate, requests/s.
    pub base_rate_rps: u32,
    /// Effective (quota-scaled) GCRA rate, requests/s.
    pub rate_rps: u32,
    /// GCRA burst tolerance, requests.
    pub burst: u32,
    /// Items admitted past the QoS gates.
    pub admitted: u64,
    /// Requests shed by the GCRA limiter.
    pub shed_rate_limited: u64,
    /// Retries shed by the retry budget.
    pub shed_retry_budget: u64,
    /// Items recorded as successfully served.
    pub successes: u64,
    /// Retries admitted within budget.
    pub retries_admitted: u64,
}

type Shard = RwLock<HashMap<String, Arc<Tenant>>>;

/// The per-tenant QoS admission layer: a sharded tenant table plus the
/// global quota-scale cell the `QuotaScaler` control loop writes.
#[derive(Debug)]
pub struct QosLayer {
    cfg: QosConfig,
    shards: Vec<Shard>,
    scale: Adaptive<f64>,
    retry_shed_counter: Arc<Counter>,
}

impl QosLayer {
    /// Build the layer and pre-register the `default` tenant.
    pub fn new(cfg: QosConfig) -> Self {
        let shards = (0..cfg.shards.max(1)).map(|_| RwLock::new(HashMap::new())).collect();
        let layer = QosLayer {
            cfg,
            shards,
            scale: Adaptive::new(1.0),
            retry_shed_counter: MetricsRegistry::global().counter("gf_retry_shed_total"),
        };
        layer.tenant(DEFAULT_TENANT);
        layer
    }

    /// Layer configuration.
    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    fn shard_index(&self, name: &str) -> usize {
        // FNV-1a over the tenant name; local so `qos` stays free of
        // pipeline dependencies.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.as_bytes() {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h as usize) % self.shards.len()
    }

    /// Number of distinct tenants currently tracked.
    pub fn tenant_count(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// Resolve (creating on first sight) the tenant for `name`. When
    /// the table is at `max_tenants`, unknown names share the
    /// `default` tenant.
    pub fn tenant(&self, name: &str) -> Arc<Tenant> {
        let idx = self.shard_index(name);
        if let Some(t) = self.shards[idx].read().unwrap().get(name) {
            return Arc::clone(t);
        }
        let mut w = self.shards[idx].write().unwrap();
        if let Some(t) = w.get(name) {
            return Arc::clone(t);
        }
        if name != DEFAULT_TENANT {
            let others: usize = self
                .shards
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != idx)
                .map(|(_, s)| s.read().unwrap().len())
                .sum();
            if others + w.len() >= self.cfg.max_tenants {
                drop(w);
                return self.tenant(DEFAULT_TENANT);
            }
        }
        let t = Arc::new(Tenant::new(name, &self.cfg, self.scale.get()));
        w.insert(name.to_string(), Arc::clone(&t));
        t
    }

    /// Run the QoS gates for `items` arrivals attributed to
    /// `tenant_id` at time `now` (seconds on the caller's clock).
    /// `retry_attempt > 0` marks the request as a retry and charges
    /// the retry budget.
    pub fn decide(&self, tenant_id: &str, items: u32, retry_attempt: u32, now: f64) -> QosVerdict {
        let tenant = self.tenant(tenant_id);
        let verdict = tenant.decide(items, retry_attempt, now, self.cfg.retry_fraction);
        if verdict == QosVerdict::RetryBudgetExhausted {
            self.retry_shed_counter.inc();
        }
        verdict
    }

    /// Record `items` successfully served items for `tenant_id`,
    /// growing its retry budget.
    pub fn record_success(&self, tenant_id: &str, items: u64, now: f64) {
        let t = self.tenant(tenant_id);
        t.state.lock().unwrap().retry.note_success(now, items);
        t.successes.fetch_add(items, Ordering::Relaxed);
    }

    /// Current global quota scale in `(0, 1]`.
    pub fn quota_scale(&self) -> f64 {
        self.scale.get()
    }

    /// Apply a new quota scale: every tenant's effective rate becomes
    /// `base_rate × scale` (floored at one request/s). Called by the
    /// `tenant_quota_scale` control loop.
    pub fn set_quota_scale(&self, scale: f64) {
        let scale = if scale.is_finite() { scale.clamp(0.01, 1.0) } else { 1.0 };
        self.scale.set(scale);
        for shard in &self.shards {
            for t in shard.read().unwrap().values() {
                t.rate_rps.set(scaled_rate(t.base_rate_rps, scale));
            }
        }
    }

    /// Stats for every tenant, sorted by name for deterministic output.
    pub fn tenants(&self) -> Vec<TenantStats> {
        let mut out: Vec<TenantStats> = self
            .shards
            .iter()
            .flat_map(|s| s.read().unwrap().values().map(|t| t.stats()).collect::<Vec<_>>())
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }
}

fn scaled_rate(base: u32, scale: f64) -> u32 {
    ((f64::from(base) * scale).round() as u32).max(1)
}

/// Validate a tenant id: non-empty, at most [`MAX_TENANT_ID_LEN`]
/// bytes, characters in `[A-Za-z0-9_.-]`.
pub fn validate_tenant_id(v: &str) -> Result<(), String> {
    if v.is_empty() {
        return Err("tenant id must be non-empty".to_string());
    }
    if v.len() > MAX_TENANT_ID_LEN {
        return Err(format!("tenant id exceeds {MAX_TENANT_ID_LEN} bytes"));
    }
    if !v.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'.' || b == b'-') {
        return Err("tenant id may only contain [A-Za-z0-9_.-]".to_string());
    }
    Ok(())
}

/// Parse the `X-Retry-Attempt` header: a non-negative decimal integer.
pub fn parse_retry_attempt(v: &str) -> Result<u32, String> {
    v.trim().parse::<u32>().map_err(|_| {
        format!("{RETRY_HEADER} must be a non-negative integer, got {v:?}")
    })
}

/// Parse the `X-Request-Deadline` header: an absolute unix timestamp
/// in milliseconds.
pub fn parse_deadline_unix_ms(v: &str) -> Result<u64, String> {
    v.trim().parse::<u64>().map_err(|_| {
        format!("{DEADLINE_HEADER} must be an absolute unix timestamp in milliseconds, got {v:?}")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcra_admits_burst_then_paces() {
        let mut g = Gcra::new();
        // rate 10 rps, burst 3: three instantaneous admits, then shed.
        for i in 0..3 {
            assert!(g.decide(0.0, 10, 3, 1).is_ok(), "burst admit {i}");
        }
        let wait = g.decide(0.0, 10, 3, 1).expect_err("fourth instantaneous arrival sheds");
        assert!(wait > 0.0 && wait <= 0.1 + 1e-9, "wait {wait} within one emission interval");
        // After waiting out the hint the arrival conforms.
        assert!(g.decide(wait + 1e-6, 10, 3, 1).is_ok());
    }

    #[test]
    fn gcra_steady_rate_always_conforms() {
        let mut g = Gcra::new();
        for i in 0..1000 {
            let now = f64::from(i) * 0.1;
            assert!(g.decide(now, 10, 1, 1).is_ok(), "paced arrival {i}");
        }
    }

    #[test]
    fn gcra_batch_charges_every_item() {
        let mut g = Gcra::new();
        assert!(g.decide(0.0, 100, 10, 10).is_ok(), "burst-sized batch admits");
        assert!(g.decide(0.0, 100, 10, 1).is_err(), "burst fully consumed");
        let mut g2 = Gcra::new();
        assert!(g2.decide(0.0, 100, 10, 11).is_err(), "batch larger than burst sheds");
    }

    #[test]
    fn retry_ledger_caps_retries_at_fraction_of_successes() {
        let mut l = RetryLedger::new(10.0);
        assert!(!l.would_allow_retry(0.0, 0.5), "no successes, no retries");
        l.note_success(0.0, 10);
        let mut admitted = 0;
        while l.would_allow_retry(0.5, 0.5) {
            l.note_retry(0.5);
            admitted += 1;
            assert!(admitted <= 5, "runaway ledger");
        }
        assert_eq!(admitted, 5, "0.5 × 10 successes = 5 retries");
    }

    #[test]
    fn retry_ledger_window_expires_old_traffic() {
        let mut l = RetryLedger::new(2.0);
        l.note_success(0.0, 100);
        assert!(l.would_allow_retry(1.0, 0.1), "window still covers the successes");
        assert!(!l.would_allow_retry(10.0, 0.1), "successes aged out");
    }

    #[test]
    fn layer_decides_and_accounts_per_tenant() {
        let cfg = QosConfig { default_rate_rps: 5, default_burst: 2, ..QosConfig::default() };
        let layer = QosLayer::new(cfg);
        assert_eq!(layer.decide("acme", 1, 0, 0.0), QosVerdict::Admit);
        assert_eq!(layer.decide("acme", 1, 0, 0.0), QosVerdict::Admit);
        match layer.decide("acme", 1, 0, 0.0) {
            QosVerdict::RateLimited { retry_after_secs } => assert!(retry_after_secs > 0.0),
            v => panic!("expected rate limit, got {v:?}"),
        }
        // A different tenant has its own bucket.
        assert_eq!(layer.decide("globex", 1, 0, 0.0), QosVerdict::Admit);
        let stats = layer.tenants();
        let acme = stats.iter().find(|t| t.name == "acme").expect("acme tracked");
        assert_eq!(acme.admitted, 2);
        assert_eq!(acme.shed_rate_limited, 1);
    }

    #[test]
    fn layer_sheds_retries_without_budget() {
        let layer = QosLayer::new(QosConfig::default());
        assert_eq!(
            layer.decide("acme", 1, 1, 0.0),
            QosVerdict::RetryBudgetExhausted,
            "no successes yet, retry must shed"
        );
        layer.record_success("acme", 100, 0.0);
        assert_eq!(layer.decide("acme", 1, 1, 0.5), QosVerdict::Admit, "budget accrued");
    }

    #[test]
    fn quota_scale_rescales_every_tenant() {
        let cfg = QosConfig { default_rate_rps: 1000, ..QosConfig::default() };
        let layer = QosLayer::new(cfg);
        layer.tenant("acme");
        layer.set_quota_scale(0.25);
        assert_eq!(layer.tenant("acme").rate_rps(), 250);
        assert_eq!(layer.tenant(DEFAULT_TENANT).rate_rps(), 250);
        // New tenants inherit the live scale.
        assert_eq!(layer.tenant("late").rate_rps(), 250);
        layer.set_quota_scale(1.0);
        assert_eq!(layer.tenant("acme").rate_rps(), 1000);
    }

    #[test]
    fn tenant_table_caps_and_falls_back_to_default() {
        let cfg = QosConfig { max_tenants: 3, ..QosConfig::default() };
        let layer = QosLayer::new(cfg);
        layer.tenant("a");
        layer.tenant("b");
        assert_eq!(layer.tenant_count(), 3, "default + a + b");
        let overflow = layer.tenant("c");
        assert_eq!(overflow.name(), DEFAULT_TENANT, "table full, shares default quota");
        assert_eq!(layer.tenant_count(), 3);
    }

    #[test]
    fn header_parsers_accept_valid_and_reject_garbage() {
        assert!(validate_tenant_id("acme-prod_7.eu").is_ok());
        assert!(validate_tenant_id("").is_err());
        assert!(validate_tenant_id("sp ace").is_err());
        assert!(validate_tenant_id(&"x".repeat(MAX_TENANT_ID_LEN + 1)).is_err());
        assert_eq!(parse_retry_attempt("2"), Ok(2));
        assert!(parse_retry_attempt("-1").is_err());
        assert!(parse_retry_attempt("two").is_err());
        assert_eq!(parse_deadline_unix_ms("1754640000000"), Ok(1_754_640_000_000));
        assert!(parse_deadline_unix_ms("soon").is_err());
        assert!(parse_deadline_unix_ms("1.5e3").is_err());
    }
}
