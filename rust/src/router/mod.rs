//! Dual-path routing (§VII "When Triton wins / When FastAPI+ORT wins").
//!
//! The router picks Path A (direct, low-latency) or Path B (batched,
//! throughput) per request. Policies encode the paper's discussion:
//! sporadic traffic and tight SLOs at tiny batches → direct; sustained
//! QPS where batching amortises → batched.

/// Which serving path executes a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathKind {
    /// FastAPI + ORT analog: immediate single-request execution.
    Direct,
    /// Triton analog: dynamic-batching scheduler.
    Batched,
    /// Answered by the response cache (controller skip).
    CacheSkip,
}

impl PathKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            PathKind::Direct => "direct",
            PathKind::Batched => "batched",
            PathKind::CacheSkip => "cache",
        }
    }
}

/// Routing policy.
#[derive(Debug, Clone)]
pub enum RoutePolicy {
    /// Pin everything to one path (the Table II per-framework rows).
    Always(PathKind),
    /// Load-adaptive: batched when the recent arrival rate crosses
    /// `qps_threshold` (batching amortises), direct otherwise.
    Adaptive { qps_threshold: f64 },
}

/// Router with a small arrival-rate estimator.
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    /// Recent arrival instants (ring of the last N).
    recent: std::collections::VecDeque<f64>,
    window: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Router { policy, recent: std::collections::VecDeque::new(), window: 32 }
    }

    /// Estimate recent arrival rate (req/s) from the observation window.
    pub fn recent_qps(&self) -> f64 {
        if self.recent.len() < 2 {
            return 0.0;
        }
        let span = self.recent.back().unwrap() - self.recent.front().unwrap();
        if span <= 0.0 {
            return f64::INFINITY;
        }
        (self.recent.len() - 1) as f64 / span
    }

    /// Route a request arriving at time `t`.
    pub fn route(&mut self, t: f64) -> PathKind {
        self.recent.push_back(t);
        if self.recent.len() > self.window {
            self.recent.pop_front();
        }
        match &self.policy {
            RoutePolicy::Always(p) => *p,
            RoutePolicy::Adaptive { qps_threshold } => {
                if self.recent_qps() >= *qps_threshold {
                    PathKind::Batched
                } else {
                    PathKind::Direct
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_policy_is_constant() {
        let mut r = Router::new(RoutePolicy::Always(PathKind::Direct));
        for i in 0..10 {
            assert_eq!(r.route(i as f64), PathKind::Direct);
        }
    }

    #[test]
    fn adaptive_picks_direct_at_low_qps() {
        let mut r = Router::new(RoutePolicy::Adaptive { qps_threshold: 50.0 });
        // 1 req/s
        for i in 0..10 {
            assert_eq!(r.route(i as f64), PathKind::Direct);
        }
        assert!((r.recent_qps() - 1.0).abs() < 0.2);
    }

    #[test]
    fn adaptive_switches_to_batched_under_load() {
        let mut r = Router::new(RoutePolicy::Adaptive { qps_threshold: 50.0 });
        let mut last = PathKind::Direct;
        // 1000 req/s burst
        for i in 0..64 {
            last = r.route(i as f64 * 0.001);
        }
        assert_eq!(last, PathKind::Batched);
        assert!(r.recent_qps() > 500.0);
    }

    #[test]
    fn adaptive_recovers_when_load_drops() {
        let mut r = Router::new(RoutePolicy::Adaptive { qps_threshold: 50.0 });
        for i in 0..64 {
            r.route(i as f64 * 0.001);
        }
        // now sporadic again: window refills with slow arrivals
        let mut last = PathKind::Batched;
        for i in 0..64 {
            last = r.route(1.0 + i as f64);
        }
        assert_eq!(last, PathKind::Direct);
    }

    #[test]
    fn path_names() {
        assert_eq!(PathKind::Direct.as_str(), "direct");
        assert_eq!(PathKind::Batched.as_str(), "batched");
        assert_eq!(PathKind::CacheSkip.as_str(), "cache");
    }
}
