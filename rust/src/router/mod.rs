//! Dual-path routing (§VII "When Triton wins / When FastAPI+ORT wins").
//!
//! The router picks Path A (direct, low-latency) or Path B (batched,
//! throughput) per request. Policies encode the paper's discussion:
//! sporadic traffic and tight SLOs at tiny batches → direct; sustained
//! QPS where batching amortises → batched.
//!
//! The arrival estimator is a shared [`RateWindow`] (configurable window,
//! no private ring buffer), and the adaptive policy's QPS threshold is an
//! [`Adaptive<f64>`] handle, so the control plane can retune the
//! direct/batched split at runtime (see [`crate::control`]).

use crate::control::{Adaptive, RateWindow};

/// Which serving path executes a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathKind {
    /// FastAPI + ORT analog: immediate single-request execution.
    Direct,
    /// Triton analog: dynamic-batching scheduler.
    Batched,
    /// Answered by the response cache (controller skip).
    CacheSkip,
}

impl PathKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            PathKind::Direct => "direct",
            PathKind::Batched => "batched",
            PathKind::CacheSkip => "cache",
        }
    }

    /// Parse a client-requestable path name. `CacheSkip` is an outcome,
    /// not a request, so only "direct" and "batched" parse.
    pub fn parse(s: &str) -> Option<PathKind> {
        match s {
            "direct" => Some(PathKind::Direct),
            "batched" => Some(PathKind::Batched),
            _ => None,
        }
    }
}

/// Default arrival-estimator window (the previously hard-wired ring size).
pub const DEFAULT_ARRIVAL_WINDOW: usize = 32;

/// Routing policy.
#[derive(Debug, Clone)]
pub enum RoutePolicy {
    /// Pin everything to one path (the Table II per-framework rows).
    Always(PathKind),
    /// Load-adaptive: batched when the recent arrival rate crosses
    /// `qps_threshold` (batching amortises), direct otherwise. `window`
    /// sizes the arrival estimator: small = reactive, large = smooth.
    Adaptive { qps_threshold: f64, window: usize },
}

impl RoutePolicy {
    /// Adaptive policy at the default estimator window.
    pub fn adaptive(qps_threshold: f64) -> Self {
        RoutePolicy::Adaptive { qps_threshold, window: DEFAULT_ARRIVAL_WINDOW }
    }
}

/// Router over a shared arrival-rate window with a live-updatable
/// threshold. `Clone` clones the estimator state but *shares* the
/// threshold cell (both routers follow the same control loop).
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    arrivals: RateWindow,
    qps_threshold: Adaptive<f64>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        let (window, threshold) = match &policy {
            RoutePolicy::Always(_) => (DEFAULT_ARRIVAL_WINDOW, f64::INFINITY),
            RoutePolicy::Adaptive { qps_threshold, window } => {
                (*window.max(&2), *qps_threshold)
            }
        };
        Router {
            policy,
            arrivals: RateWindow::new(window),
            qps_threshold: Adaptive::new(threshold),
        }
    }

    /// Estimate recent arrival rate (req/s) from the observation window.
    pub fn recent_qps(&self) -> f64 {
        self.arrivals.rate()
    }

    /// Arrival-estimator window size.
    pub fn window(&self) -> usize {
        self.arrivals.window()
    }

    /// The QPS threshold currently in force (+inf for pinned policies).
    pub fn qps_threshold(&self) -> f64 {
        self.qps_threshold.get()
    }

    /// Live handle onto the threshold, for the control plane's
    /// adaptive-router loop.
    pub fn qps_threshold_handle(&self) -> Adaptive<f64> {
        self.qps_threshold.handle()
    }

    /// Route a request arriving at time `t`.
    pub fn route(&mut self, t: f64) -> PathKind {
        self.arrivals.record(t);
        match &self.policy {
            RoutePolicy::Always(p) => *p,
            RoutePolicy::Adaptive { .. } => {
                if self.arrivals.rate() >= self.qps_threshold.get() {
                    PathKind::Batched
                } else {
                    PathKind::Direct
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_policy_is_constant() {
        let mut r = Router::new(RoutePolicy::Always(PathKind::Direct));
        for i in 0..10 {
            assert_eq!(r.route(i as f64), PathKind::Direct);
        }
    }

    #[test]
    fn adaptive_picks_direct_at_low_qps() {
        let mut r = Router::new(RoutePolicy::adaptive(50.0));
        // 1 req/s
        for i in 0..10 {
            assert_eq!(r.route(i as f64), PathKind::Direct);
        }
        assert!((r.recent_qps() - 1.0).abs() < 0.2);
    }

    #[test]
    fn adaptive_switches_to_batched_under_load() {
        let mut r = Router::new(RoutePolicy::adaptive(50.0));
        let mut last = PathKind::Direct;
        // 1000 req/s burst
        for i in 0..64 {
            last = r.route(i as f64 * 0.001);
        }
        assert_eq!(last, PathKind::Batched);
        assert!(r.recent_qps() > 500.0);
    }

    #[test]
    fn adaptive_recovers_when_load_drops() {
        let mut r = Router::new(RoutePolicy::adaptive(50.0));
        for i in 0..64 {
            r.route(i as f64 * 0.001);
        }
        // now sporadic again: window refills with slow arrivals
        let mut last = PathKind::Batched;
        for i in 0..64 {
            last = r.route(1.0 + i as f64);
        }
        assert_eq!(last, PathKind::Direct);
    }

    #[test]
    fn window_size_is_configurable() {
        // A small window locks onto a burst within a few arrivals; a wide
        // window still averages the burst against the calm history.
        let mut small =
            Router::new(RoutePolicy::Adaptive { qps_threshold: 50.0, window: 4 });
        let mut wide =
            Router::new(RoutePolicy::Adaptive { qps_threshold: 50.0, window: 64 });
        assert_eq!(small.window(), 4);
        assert_eq!(wide.window(), 64);
        // calm regime: 1 req/s
        for i in 0..32 {
            small.route(1.0 + i as f64);
            wide.route(1.0 + i as f64);
        }
        // burst: 1000 req/s for 6 requests
        let (mut s, mut w) = (PathKind::Direct, PathKind::Direct);
        for i in 0..6 {
            let t = 33.0 + i as f64 * 0.001;
            s = small.route(t);
            w = wide.route(t);
        }
        assert_eq!(s, PathKind::Batched, "small window reacts to the burst");
        assert_eq!(w, PathKind::Direct, "wide window still averages the calm past");
    }

    #[test]
    fn threshold_handle_retunes_live() {
        let mut r = Router::new(RoutePolicy::adaptive(50.0));
        for i in 0..64 {
            r.route(i as f64 * 0.01); // 100 req/s
        }
        assert_eq!(r.route(0.65), PathKind::Batched);
        // control loop raises the threshold above the observed rate
        r.qps_threshold_handle().set(500.0);
        assert_eq!(r.route(0.66), PathKind::Direct);
        assert_eq!(r.qps_threshold(), 500.0);
    }

    #[test]
    fn clones_share_the_threshold_cell() {
        let r = Router::new(RoutePolicy::adaptive(50.0));
        let r2 = r.clone();
        r.qps_threshold_handle().set(75.0);
        assert_eq!(r2.qps_threshold(), 75.0);
    }

    #[test]
    fn path_names() {
        assert_eq!(PathKind::Direct.as_str(), "direct");
        assert_eq!(PathKind::Batched.as_str(), "batched");
        assert_eq!(PathKind::CacheSkip.as_str(), "cache");
    }

    #[test]
    fn path_parse_accepts_requestable_paths_only() {
        assert_eq!(PathKind::parse("direct"), Some(PathKind::Direct));
        assert_eq!(PathKind::parse("batched"), Some(PathKind::Batched));
        assert_eq!(PathKind::parse("cache"), None);
        assert_eq!(PathKind::parse("auto"), None);
    }
}
