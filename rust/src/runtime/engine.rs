//! The PJRT engine: compile HLO-text artifacts once, execute many times.
//!
//! One `Engine` per worker thread (PjRtClient is not Send). Executables
//! are cached per (model, batch-bucket). Weights are materialised once at
//! load: as host literals (`ExecMode::Literals`) or pre-transferred device
//! buffers (`ExecMode::DeviceBuffers` — the ORT I/O-binding analog, which
//! removes the per-request host→device weight copy and is the §Perf L3
//! optimisation).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::time::Instant;

use crate::runtime::manifest::ModelManifest;
use crate::runtime::tensor::{InputBatch, OutputBatch};
use crate::runtime::RuntimeError;

/// How weights are fed to the executable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Host literals passed on every call (baseline; extra H2D copies).
    Literals,
    /// Weights live as device buffers; per-call H2D is just the input.
    DeviceBuffers,
}

/// Execution statistics for one call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecStats {
    /// Bucket the batch was padded to.
    pub bucket: usize,
    /// Wallclock seconds of the PJRT execute (including H2D/D2H).
    pub exec_secs: f64,
    /// Analytic FLOPs attributed to the padded batch.
    pub flops: f64,
}

struct LoadedModel {
    manifest: ModelManifest,
    weight_literals: Vec<xla::Literal>,
    weight_buffers: Option<Vec<xla::PjRtBuffer>>,
    execs: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

/// Thread-confined PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    mode: ExecMode,
    models: HashMap<String, LoadedModel>,
}

impl Engine {
    /// Create a CPU-PJRT engine.
    pub fn cpu(mode: ExecMode) -> Result<Self, RuntimeError> {
        Ok(Engine { client: xla::PjRtClient::cpu()?, mode, models: HashMap::new() })
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn loaded_models(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    pub fn manifest(&self, model: &str) -> Option<&ModelManifest> {
        self.models.get(model).map(|m| &m.manifest)
    }

    /// Load one model directory (manifest + weights + all bucket HLOs),
    /// compiling every bucket's executable eagerly so the serve path never
    /// pays compilation latency.
    pub fn load_model(&mut self, dir: &Path) -> Result<(), RuntimeError> {
        let manifest = ModelManifest::load(dir)?;

        // ---- weights.bin -> one literal per parameter
        let wpath = dir.join(&manifest.weights_file);
        let bytes = std::fs::read(&wpath)
            .map_err(|e| RuntimeError::Io { path: wpath.display().to_string(), source: e })?;
        if bytes.len() != manifest.weights_bytes() {
            return Err(RuntimeError::Manifest(format!(
                "weights.bin is {} bytes, manifest wants {}",
                bytes.len(),
                manifest.weights_bytes()
            )));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut weight_literals = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let start = p.offset / 4;
            let slice = &floats[start..start + p.numel];
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            weight_literals.push(xla::Literal::vec1(slice).reshape(&dims)?);
        }

        // ---- optional device-buffer pre-transfer (I/O binding analog)
        let weight_buffers = if self.mode == ExecMode::DeviceBuffers {
            let mut bufs = Vec::with_capacity(manifest.params.len());
            for p in &manifest.params {
                let start = p.offset / 4;
                let slice = &floats[start..start + p.numel];
                bufs.push(self.client.buffer_from_host_buffer(slice, &p.shape, None)?);
            }
            Some(bufs)
        } else {
            None
        };

        // ---- compile every bucket
        let mut execs = BTreeMap::new();
        for (&bucket, file) in &manifest.hlo_files {
            let hpath = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                hpath.to_str().ok_or_else(|| RuntimeError::Manifest("bad path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            execs.insert(bucket, self.client.compile(&comp)?);
        }

        self.models.insert(
            manifest.name.clone(),
            LoadedModel { manifest, weight_literals, weight_buffers, execs },
        );
        Ok(())
    }

    /// Execute a batch: pick the smallest fitting bucket, pad, run,
    /// decode (logits, probs, entropy), slice padding away.
    pub fn execute(
        &self,
        model: &str,
        input: &InputBatch,
    ) -> Result<(OutputBatch, ExecStats), RuntimeError> {
        let lm =
            self.models.get(model).ok_or_else(|| RuntimeError::UnknownModel(model.to_string()))?;
        input.check(&lm.manifest)?;
        let batch = input.batch();
        let bucket = lm.manifest.bucket_for(batch).ok_or_else(|| RuntimeError::BatchTooLarge {
            model: model.to_string(),
            requested: batch,
            max: lm.manifest.max_bucket(),
        })?;
        let exe = &lm.execs[&bucket];
        let padded = input.pad_to(bucket);

        // input dims: (bucket, *shape_per_item)
        let mut dims: Vec<i64> = vec![bucket as i64];
        dims.extend(lm.manifest.input_shape.iter().map(|&d| d as i64));

        let t0 = Instant::now();
        let result_literal = match self.mode {
            ExecMode::Literals => {
                let input_lit = match &padded {
                    InputBatch::Tokens { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
                    InputBatch::Dense { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
                };
                let mut args: Vec<&xla::Literal> = lm.weight_literals.iter().collect();
                args.push(&input_lit);
                let out = exe.execute::<&xla::Literal>(&args)?;
                out[0][0].to_literal_sync()?
            }
            ExecMode::DeviceBuffers => {
                let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
                let input_buf = match &padded {
                    InputBatch::Tokens { data, .. } => {
                        self.client.buffer_from_host_buffer(data, &udims, None)?
                    }
                    InputBatch::Dense { data, .. } => {
                        self.client.buffer_from_host_buffer(data, &udims, None)?
                    }
                };
                let wb = lm.weight_buffers.as_ref().expect("DeviceBuffers mode has buffers");
                let mut args: Vec<&xla::PjRtBuffer> = wb.iter().collect();
                args.push(&input_buf);
                let out = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
                out[0][0].to_literal_sync()?
            }
        };
        let exec_secs = t0.elapsed().as_secs_f64();

        let (lo, pr, en) = result_literal.to_tuple3()?;
        let out = OutputBatch {
            batch: bucket,
            classes: lm.manifest.classes,
            logits: lo.to_vec::<f32>()?,
            probs: pr.to_vec::<f32>()?,
            entropy: en.to_vec::<f32>()?,
        }
        .truncate(batch);

        let flops = lm.manifest.flops_per_batch.get(&bucket).copied().unwrap_or(0.0);
        Ok((out, ExecStats { bucket, exec_secs, flops }))
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests against the real artifacts (skipped when `make
    //! artifacts` has not run — CI always builds them first).
    use super::*;
    use crate::models::inputgen;

    fn repo_dir() -> Option<std::path::PathBuf> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then_some(root)
    }

    fn engine_with(model: &str, mode: ExecMode) -> Option<Engine> {
        let root = repo_dir()?;
        let mut e = Engine::cpu(mode).unwrap();
        e.load_model(&root.join(model)).unwrap();
        Some(e)
    }

    #[test]
    fn screener_executes_and_decodes() {
        let Some(e) = engine_with("screener", ExecMode::Literals) else { return };
        let m = e.manifest("screener").unwrap().clone();
        let input = inputgen::tokens_for(&m, &[1, 2], 42);
        let (out, stats) = e.execute("screener", &input).unwrap();
        assert_eq!(out.batch, 2);
        assert_eq!(out.classes, 2);
        assert_eq!(stats.bucket, 4, "2 rows pad into the 4-bucket");
        // probs rows sum to 1
        for i in 0..out.batch {
            let s: f32 = out.probs[i * 2..(i + 1) * 2].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {i} sums to {s}");
            let ent = out.entropy[i];
            assert!((0.0..=(2f32).ln() + 1e-4).contains(&ent));
        }
    }

    #[test]
    fn padding_does_not_change_results() {
        let Some(e) = engine_with("screener", ExecMode::Literals) else { return };
        let m = e.manifest("screener").unwrap().clone();
        let one = inputgen::tokens_for(&m, &[7], 1);
        let (o1, s1) = e.execute("screener", &one).unwrap();
        assert_eq!(s1.bucket, 1);
        // Same item inside a padded 4-batch must produce the same row.
        let three = inputgen::tokens_for(&m, &[7, 8, 9], 1);
        let (o3, s3) = e.execute("screener", &three).unwrap();
        assert_eq!(s3.bucket, 4);
        for c in 0..2 {
            assert!((o1.probs[c] - o3.probs[c]).abs() < 1e-5);
        }
    }

    #[test]
    fn device_buffer_mode_matches_literal_mode() {
        let Some(el) = engine_with("screener", ExecMode::Literals) else { return };
        let eb = engine_with("screener", ExecMode::DeviceBuffers).unwrap();
        let m = el.manifest("screener").unwrap().clone();
        let input = inputgen::tokens_for(&m, &[3, 4, 5, 6], 9);
        let (ol, _) = el.execute("screener", &input).unwrap();
        let (ob, _) = eb.execute("screener", &input).unwrap();
        for (a, b) in ol.probs.iter().zip(&ob.probs) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn unknown_model_errors() {
        let Some(e) = engine_with("screener", ExecMode::Literals) else { return };
        let input = InputBatch::Tokens { data: vec![0; 32], batch: 1, per_item: 32 };
        assert!(matches!(e.execute("nope", &input), Err(RuntimeError::UnknownModel(_))));
    }

    #[test]
    fn batch_too_large_errors() {
        let Some(e) = engine_with("screener", ExecMode::Literals) else { return };
        let m = e.manifest("screener").unwrap().clone();
        let ids: Vec<u64> = (0..9).collect();
        let input = inputgen::tokens_for(&m, &ids, 2);
        assert!(matches!(
            e.execute("screener", &input),
            Err(RuntimeError::BatchTooLarge { .. })
        ));
    }

    #[test]
    fn wrong_input_kind_errors() {
        let Some(e) = engine_with("screener", ExecMode::Literals) else { return };
        let input = InputBatch::Dense { data: vec![0.0; 32], batch: 1, per_item: 32 };
        assert!(matches!(e.execute("screener", &input), Err(RuntimeError::InputMismatch(_))));
    }
}
