//! The async model-lifecycle executor: a bounded background work queue
//! that runs load/unload jobs **off the gateway handler path**, so a
//! slow engine spawn (compile + weight transfer — the energy the paper's
//! restartless swaps avoid re-paying) never holds an HTTP thread.
//!
//! Scheduling contract:
//!
//! * **Per-model serialization** — at most one job per model executes at
//!   a time, so load/unload transitions for one model can never
//!   interleave mid-flight.
//! * **Cross-model concurrency** — jobs for *different* models run on
//!   whichever of the worker threads is free; two slow loads complete in
//!   ~max of their times, not the sum (arXiv 2402.07585's "asynchronous
//!   model management" design decision).
//! * **Bounded queue** — past [`LifecycleExecutor::capacity`] pending
//!   jobs, submission fails (the gateway maps it to `BACKPRESSURE`/429)
//!   instead of buffering unbounded operator mistakes.
//! * **Cancellation** — a *queued* (not yet started) load job can be
//!   cancelled by a later unload of the same version; its `cancel`
//!   closure runs instead of `work` (reverting `Loading → Unloaded` and
//!   failing any synchronous waiter). A job already executing is not
//!   interruptible — callers see the version as busy.
//!
//! The executor knows nothing about engines or registries: jobs are
//! opaque closures tagged with `(model, version, kind)` for scheduling
//! and cancellation. [`crate::pipeline::system::ServingSystem`] owns the
//! instance and builds the closures.
//!
//! Telemetry: `gf_lifecycle_queue_depth` (pending jobs),
//! `gf_lifecycle_wait_seconds.<model>.<version>` (enqueue → start),
//! `gf_lifecycle_jobs_total` / `gf_lifecycle_cancelled_total`.

use std::collections::{BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::RuntimeError;
use crate::telemetry::MetricsRegistry;

/// What a job does to its version (cancellation only targets loads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    Load,
    Unload,
    /// Replica-set reconciliation (spawn/retire replicas toward the
    /// scaler's target). Like unloads, scale jobs bypass the queue
    /// bound: they are issued by the control tick (naturally
    /// rate-limited) and refusing one would strand a version's replica
    /// set away from its published target.
    Scale,
}

/// One lifecycle job as handed to [`LifecycleExecutor::submit_all`].
pub struct JobSpec {
    pub version: u64,
    pub kind: JobKind,
    /// Runs on a worker thread once the model's slot is free.
    pub work: Box<dyn FnOnce() + Send>,
    /// Runs (inline, on the cancelling thread) if the job is cancelled
    /// or the executor shuts down before `work` starts.
    pub cancel: Box<dyn FnOnce() + Send>,
}

/// One queued lifecycle job.
struct Job {
    model: String,
    version: u64,
    kind: JobKind,
    enqueued: Instant,
    work: Box<dyn FnOnce() + Send>,
    cancel: Box<dyn FnOnce() + Send>,
}

struct QueueState {
    pending: VecDeque<Job>,
    /// Models with a job currently executing on some worker.
    running: BTreeSet<String>,
}

struct Inner {
    state: Mutex<QueueState>,
    work_ready: Condvar,
    capacity: usize,
    stop: AtomicBool,
}

impl Inner {
    fn publish_depth(&self, depth: usize) {
        MetricsRegistry::global().gauge("gf_lifecycle_queue_depth").set(depth as f64);
    }
}

/// The background executor. Dropping it drains the queue (cancelling
/// pending jobs) and joins the workers after their current job.
pub struct LifecycleExecutor {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl LifecycleExecutor {
    /// Start `workers` threads over a queue bounded at `capacity`
    /// pending jobs.
    pub fn start(workers: usize, capacity: usize) -> Self {
        let inner = Arc::new(Inner {
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                running: BTreeSet::new(),
            }),
            work_ready: Condvar::new(),
            capacity: capacity.max(1),
            stop: AtomicBool::new(false),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("gf-lifecycle-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn lifecycle worker")
            })
            .collect();
        LifecycleExecutor { inner, workers: handles }
    }

    /// Pending-job capacity (the bound `submit` enforces).
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Jobs waiting for a worker (excludes the ones executing).
    pub fn queue_depth(&self) -> usize {
        self.inner.state.lock().unwrap().pending.len()
    }

    /// Enqueue one job (see [`LifecycleExecutor::submit_all`]).
    pub fn submit(
        &self,
        model: &str,
        version: u64,
        kind: JobKind,
        work: Box<dyn FnOnce() + Send>,
        cancel: Box<dyn FnOnce() + Send>,
    ) -> Result<(), RuntimeError> {
        self.submit_all(model, vec![JobSpec { version, kind, work, cancel }])
    }

    /// Enqueue a batch of jobs for one model **atomically**: either
    /// every job is accepted or none is. A batch containing **load**
    /// jobs that would push the queue past its bound fails whole with
    /// [`RuntimeError::Backpressure`] — the caller unwinds its state
    /// changes and reports 429; partially-enqueued multi-version loads
    /// must not exist (the stranded siblings would read as "busy" to
    /// every retry). Unload-only batches always enqueue: refusing one
    /// would strand a version in `Unloading` with its snapshot entry
    /// already swapped out.
    pub fn submit_all(&self, model: &str, jobs: Vec<JobSpec>) -> Result<(), RuntimeError> {
        if jobs.is_empty() {
            return Ok(());
        }
        let n = jobs.len() as u64;
        {
            let mut st = self.inner.state.lock().unwrap();
            let has_load = jobs.iter().any(|j| j.kind == JobKind::Load);
            if has_load && st.pending.len() + jobs.len() > self.inner.capacity {
                return Err(RuntimeError::Backpressure(format!(
                    "lifecycle queue full ({} jobs pending, {} submitted, bound {})",
                    st.pending.len(),
                    jobs.len(),
                    self.inner.capacity
                )));
            }
            let now = Instant::now();
            for spec in jobs {
                st.pending.push_back(Job {
                    model: model.to_string(),
                    version: spec.version,
                    kind: spec.kind,
                    enqueued: now,
                    work: spec.work,
                    cancel: spec.cancel,
                });
            }
            self.inner.publish_depth(st.pending.len());
        }
        MetricsRegistry::global().counter("gf_lifecycle_jobs_total").add(n);
        self.inner.work_ready.notify_all();
        Ok(())
    }

    /// Cancel **queued** load jobs for a model: an explicit `version`
    /// targets that one, `None` every queued load of the model. Each
    /// cancelled job's `cancel` closure runs inline; jobs already
    /// executing are untouched. Returns the cancelled versions.
    pub fn cancel_queued_loads(&self, model: &str, version: Option<u64>) -> Vec<u64> {
        let mut cancelled = Vec::new();
        let mut dropped = Vec::new();
        {
            let mut st = self.inner.state.lock().unwrap();
            let mut keep = VecDeque::with_capacity(st.pending.len());
            while let Some(job) = st.pending.pop_front() {
                let hit = job.kind == JobKind::Load
                    && job.model == model
                    && version.map(|v| v == job.version).unwrap_or(true);
                if hit {
                    cancelled.push(job.version);
                    dropped.push(job);
                } else {
                    keep.push_back(job);
                }
            }
            st.pending = keep;
            self.inner.publish_depth(st.pending.len());
        }
        // Run the cancel hooks outside the queue lock: they touch the
        // registry (its own lock) and may wake synchronous waiters.
        let reg = MetricsRegistry::global();
        for job in dropped {
            reg.counter("gf_lifecycle_cancelled_total").inc();
            (job.cancel)();
        }
        cancelled
    }

    /// Whether a load of `(model, version)` is still waiting in the
    /// queue (test introspection; production callers observe queued
    /// loads through the registry's `Loading` state instead).
    #[cfg(test)]
    fn load_queued(&self, model: &str, version: u64) -> bool {
        self.inner
            .state
            .lock()
            .unwrap()
            .pending
            .iter()
            .any(|j| j.kind == JobKind::Load && j.model == model && j.version == version)
    }
}

impl Drop for LifecycleExecutor {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        // Cancel everything still queued so synchronous waiters are
        // released instead of hanging on a dead channel.
        let drained: Vec<Job> = {
            let mut st = self.inner.state.lock().unwrap();
            let jobs = std::mem::take(&mut st.pending).into_iter().collect();
            self.inner.publish_depth(0);
            jobs
        };
        for job in drained {
            (job.cancel)();
        }
        self.inner.work_ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &Arc<Inner>) {
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if inner.stop.load(Ordering::SeqCst) {
                    return;
                }
                // First pending job whose model is not mid-job: FIFO
                // per model, concurrent across models.
                let idx = st
                    .pending
                    .iter()
                    .position(|j| !st.running.contains(&j.model));
                if let Some(i) = idx {
                    let job = st.pending.remove(i).expect("indexed job");
                    st.running.insert(job.model.clone());
                    inner.publish_depth(st.pending.len());
                    break job;
                }
                st = inner.work_ready.wait(st).unwrap();
            }
        };
        MetricsRegistry::global()
            .gauge(&format!(
                "gf_lifecycle_wait_seconds.{}.{}",
                job.model, job.version
            ))
            .set(job.enqueued.elapsed().as_secs_f64());
        // A panicking job must not wedge its model's slot (the worker
        // would unwind before releasing it, leaving every later job for
        // that model queued forever) or kill the worker thread.
        let work = job.work;
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(work)).is_err() {
            MetricsRegistry::global().counter("gf_lifecycle_job_panics_total").inc();
        }
        {
            let mut st = inner.state.lock().unwrap();
            st.running.remove(&job.model);
        }
        // A freed model slot may unblock a queued same-model job on
        // another worker.
        inner.work_ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc;
    use std::time::Duration;

    type JobFn = Box<dyn FnOnce() + Send>;

    fn recorder() -> (Arc<Mutex<Vec<&'static str>>>, impl Fn(&'static str) -> JobFn) {
        let log: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
        let l2 = log.clone();
        let mk = move |tag: &'static str| -> Box<dyn FnOnce() + Send> {
            let log = l2.clone();
            Box::new(move || log.lock().unwrap().push(tag))
        };
        (log, mk)
    }

    fn wait_until<F: Fn() -> bool>(cond: F, ms: u64) -> bool {
        let deadline = Instant::now() + Duration::from_millis(ms);
        while Instant::now() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        cond()
    }

    #[test]
    fn jobs_run_and_complete() {
        let ex = LifecycleExecutor::start(2, 16);
        let (log, mk) = recorder();
        ex.submit("a", 1, JobKind::Load, mk("a1"), Box::new(|| {})).unwrap();
        ex.submit("b", 1, JobKind::Load, mk("b1"), Box::new(|| {})).unwrap();
        assert!(wait_until(|| log.lock().unwrap().len() == 2, 2000));
    }

    #[test]
    fn same_model_serialises_different_models_overlap() {
        let ex = LifecycleExecutor::start(4, 16);
        let in_flight = Arc::new(AtomicUsize::new(0));
        let peak_same = Arc::new(AtomicUsize::new(0));
        let overlap_seen = Arc::new(AtomicBool::new(false));
        let mk = |model_counter: Arc<AtomicUsize>,
                  peak: Arc<AtomicUsize>,
                  cross: Arc<AtomicUsize>,
                  overlap: Arc<AtomicBool>| {
            Box::new(move || {
                let now_same = model_counter.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now_same, Ordering::SeqCst);
                let now_cross = cross.fetch_add(1, Ordering::SeqCst) + 1;
                if now_cross >= 2 {
                    overlap.store(true, Ordering::SeqCst);
                }
                std::thread::sleep(Duration::from_millis(60));
                cross.fetch_sub(1, Ordering::SeqCst);
                model_counter.fetch_sub(1, Ordering::SeqCst);
            }) as Box<dyn FnOnce() + Send>
        };
        let a_inflight = Arc::new(AtomicUsize::new(0));
        let b_inflight = Arc::new(AtomicUsize::new(0));
        for _ in 0..3 {
            ex.submit(
                "a",
                1,
                JobKind::Load,
                mk(a_inflight.clone(), peak_same.clone(), in_flight.clone(), overlap_seen.clone()),
                Box::new(|| {}),
            )
            .unwrap();
            ex.submit(
                "b",
                1,
                JobKind::Load,
                mk(b_inflight.clone(), peak_same.clone(), in_flight.clone(), overlap_seen.clone()),
                Box::new(|| {}),
            )
            .unwrap();
        }
        assert!(wait_until(
            || ex.queue_depth() == 0
                && a_inflight.load(Ordering::SeqCst) == 0
                && b_inflight.load(Ordering::SeqCst) == 0,
            5000
        ));
        assert_eq!(peak_same.load(Ordering::SeqCst), 1, "per-model serialization");
        assert!(overlap_seen.load(Ordering::SeqCst), "cross-model concurrency");
    }

    #[test]
    fn bounded_queue_refuses_past_capacity() {
        let ex = LifecycleExecutor::start(1, 2);
        // One long job occupies the worker; the queue holds 2 more.
        let (tx, rx) = mpsc::channel::<()>();
        ex.submit(
            "a",
            1,
            JobKind::Load,
            Box::new(move || {
                let _ = rx.recv_timeout(Duration::from_secs(5));
            }),
            Box::new(|| {}),
        )
        .unwrap();
        assert!(wait_until(|| ex.queue_depth() == 0, 2000), "worker picked up the job");
        ex.submit("a", 2, JobKind::Load, Box::new(|| {}), Box::new(|| {})).unwrap();
        ex.submit("a", 3, JobKind::Load, Box::new(|| {}), Box::new(|| {})).unwrap();
        let err = ex
            .submit("a", 4, JobKind::Load, Box::new(|| {}), Box::new(|| {}))
            .unwrap_err();
        assert!(matches!(err, RuntimeError::Backpressure(_)), "{err}");
        // Scale jobs (control-tick driven) bypass the bound like unloads.
        ex.submit("a", 5, JobKind::Scale, Box::new(|| {}), Box::new(|| {})).unwrap();
        tx.send(()).unwrap();
    }

    #[test]
    fn batch_submit_is_all_or_nothing() {
        let ex = LifecycleExecutor::start(1, 3);
        // Occupy the worker so everything else stays pending.
        let (tx, rx) = mpsc::channel::<()>();
        ex.submit(
            "a",
            1,
            JobKind::Load,
            Box::new(move || {
                let _ = rx.recv_timeout(Duration::from_secs(5));
            }),
            Box::new(|| {}),
        )
        .unwrap();
        assert!(wait_until(|| ex.queue_depth() == 0, 2000));
        ex.submit("a", 2, JobKind::Load, Box::new(|| {}), Box::new(|| {})).unwrap();
        // A 3-job load batch over the remaining 2 slots is refused
        // whole: nothing from it may linger in the queue.
        let specs: Vec<JobSpec> = (3..6)
            .map(|v| JobSpec {
                version: v,
                kind: JobKind::Load,
                work: Box::new(|| {}),
                cancel: Box::new(|| {}),
            })
            .collect();
        let err = ex.submit_all("b", specs).unwrap_err();
        assert!(matches!(err, RuntimeError::Backpressure(_)), "{err}");
        assert_eq!(ex.queue_depth(), 1, "refused batch left nothing behind");
        assert!(!ex.load_queued("b", 3));
        // Unload batches bypass the bound entirely.
        let drains: Vec<JobSpec> = (3..6)
            .map(|v| JobSpec {
                version: v,
                kind: JobKind::Unload,
                work: Box::new(|| {}),
                cancel: Box::new(|| {}),
            })
            .collect();
        ex.submit_all("b", drains).unwrap();
        assert_eq!(ex.queue_depth(), 4);
        tx.send(()).unwrap();
    }

    #[test]
    fn queued_load_cancels_but_running_does_not() {
        let ex = LifecycleExecutor::start(1, 16);
        let (log, mk) = recorder();
        let (tx, rx) = mpsc::channel::<()>();
        let started = Arc::new(AtomicBool::new(false));
        let s2 = started.clone();
        ex.submit(
            "a",
            1,
            JobKind::Load,
            Box::new(move || {
                s2.store(true, Ordering::SeqCst);
                let _ = rx.recv_timeout(Duration::from_secs(5));
            }),
            mk("a1-cancelled"),
        )
        .unwrap();
        assert!(wait_until(|| started.load(Ordering::SeqCst), 2000));
        // a2 queues behind a1 (same model) — cancellable.
        ex.submit("a", 2, JobKind::Load, mk("a2-ran"), mk("a2-cancelled")).unwrap();
        assert!(ex.load_queued("a", 2));
        // Running a1 is not cancellable; queued a2 is.
        assert_eq!(ex.cancel_queued_loads("a", Some(1)), Vec::<u64>::new());
        assert_eq!(ex.cancel_queued_loads("a", Some(2)), vec![2]);
        assert!(!ex.load_queued("a", 2));
        tx.send(()).unwrap();
        assert!(wait_until(|| !log.lock().unwrap().is_empty(), 2000));
        assert_eq!(*log.lock().unwrap(), vec!["a2-cancelled"], "work never ran");
    }

    #[test]
    fn panicking_job_frees_the_model_slot() {
        let ex = LifecycleExecutor::start(1, 16);
        let (log, mk) = recorder();
        ex.submit("a", 1, JobKind::Load, Box::new(|| panic!("boom")), Box::new(|| {}))
            .unwrap();
        // The model's serialization slot must be released despite the
        // panic, so the next job for the same model still runs.
        ex.submit("a", 2, JobKind::Load, mk("a2-ran"), Box::new(|| {})).unwrap();
        assert!(wait_until(|| log.lock().unwrap().contains(&"a2-ran"), 2000));
    }

    #[test]
    fn drop_cancels_pending_jobs() {
        let (log, mk) = recorder();
        {
            let ex = LifecycleExecutor::start(1, 16);
            let (_tx, rx) = mpsc::channel::<()>();
            ex.submit(
                "a",
                1,
                JobKind::Load,
                Box::new(move || {
                    // Held only until drop closes the channel.
                    let _ = rx.recv_timeout(Duration::from_millis(500));
                }),
                Box::new(|| {}),
            )
            .unwrap();
            std::thread::sleep(Duration::from_millis(30));
            ex.submit("a", 2, JobKind::Load, mk("a2-ran"), mk("a2-cancelled")).unwrap();
        } // drop: a2 never started → its cancel hook runs
        assert_eq!(*log.lock().unwrap(), vec!["a2-cancelled"]);
    }
}
