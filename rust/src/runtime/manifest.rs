//! `manifest.json` schema: the contract between `python/compile/aot.py`
//! and the Rust runtime (parameter table, input spec, batch buckets,
//! FLOPs for the energy model).

use std::collections::BTreeMap;
use std::path::Path;

use crate::json;
use crate::runtime::RuntimeError;

/// One parameter tensor in `weights.bin`.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Byte offset in weights.bin.
    pub offset: usize,
    pub numel: usize,
}

/// What the model's (single) input tensor is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    /// i32 token ids in [0, vocab).
    Tokens,
    /// f32 dense tensor (images).
    Dense,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub family: String,
    pub classes: usize,
    pub batch_buckets: Vec<usize>,
    pub weights_file: String,
    /// bucket -> hlo file name.
    pub hlo_files: BTreeMap<usize, String>,
    /// bucket -> analytic FLOPs for the whole batch.
    pub flops_per_batch: BTreeMap<usize, f64>,
    pub params: Vec<ParamEntry>,
    pub input_kind: InputKind,
    /// Per-item input shape (batch dim excluded).
    pub input_shape: Vec<usize>,
    /// Vocab size for token inputs.
    pub vocab: Option<usize>,
}

impl ModelManifest {
    /// Parse from JSON text.
    pub fn from_json(text: &str) -> Result<Self, RuntimeError> {
        let v = json::parse(text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let get_str = |k: &str| -> Result<String, RuntimeError> {
            Ok(v.get(k)
                .and_then(|x| x.as_str().map(|s| s.to_string()))
                .map_err(|e| RuntimeError::Manifest(format!("{k}: {e}")))?)
        };

        let name = get_str("name")?;
        let family = get_str("family")?;
        let weights_file = get_str("weights_file")?;
        let classes = v
            .get("classes")
            .and_then(|x| x.as_i64())
            .map_err(|e| RuntimeError::Manifest(format!("classes: {e}")))? as usize;

        let batch_buckets: Vec<usize> = v
            .get("batch_buckets")
            .and_then(|x| x.as_arr().map(|a| a.to_vec()))
            .map_err(|e| RuntimeError::Manifest(format!("batch_buckets: {e}")))?
            .iter()
            .map(|x| x.as_i64().unwrap_or(0) as usize)
            .collect();

        let mut hlo_files = BTreeMap::new();
        for (k, val) in v
            .get("hlo_files")
            .and_then(|x| x.as_obj().map(|o| o.clone()))
            .map_err(|e| RuntimeError::Manifest(format!("hlo_files: {e}")))?
        {
            let bucket: usize =
                k.parse().map_err(|_| RuntimeError::Manifest(format!("bad bucket key {k}")))?;
            hlo_files.insert(
                bucket,
                val.as_str().map_err(|e| RuntimeError::Manifest(e.to_string()))?.to_string(),
            );
        }

        let mut flops_per_batch = BTreeMap::new();
        if let Ok(Some(fp)) = v.opt("flops_per_batch") {
            for (k, val) in fp.as_obj().map_err(|e| RuntimeError::Manifest(e.to_string()))? {
                if let (Ok(bucket), Ok(f)) = (k.parse::<usize>(), val.as_f64()) {
                    flops_per_batch.insert(bucket, f);
                }
            }
        }

        let mut params = Vec::new();
        for p in v
            .get("params")
            .and_then(|x| x.as_arr().map(|a| a.to_vec()))
            .map_err(|e| RuntimeError::Manifest(format!("params: {e}")))?
        {
            params.push(ParamEntry {
                name: p
                    .get("name")
                    .and_then(|x| x.as_str().map(|s| s.to_string()))
                    .map_err(|e| RuntimeError::Manifest(e.to_string()))?,
                shape: p
                    .get("shape")
                    .and_then(|x| x.as_arr().map(|a| a.to_vec()))
                    .map_err(|e| RuntimeError::Manifest(e.to_string()))?
                    .iter()
                    .map(|d| d.as_i64().unwrap_or(0) as usize)
                    .collect(),
                offset: p
                    .get("offset")
                    .and_then(|x| x.as_i64())
                    .map_err(|e| RuntimeError::Manifest(e.to_string()))? as usize,
                numel: p
                    .get("numel")
                    .and_then(|x| x.as_i64())
                    .map_err(|e| RuntimeError::Manifest(e.to_string()))? as usize,
            });
        }

        let input = v.get("input").map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let kind_str = input
            .get("kind")
            .and_then(|x| x.as_str().map(|s| s.to_string()))
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let input_kind = match kind_str.as_str() {
            "tokens" => InputKind::Tokens,
            _ => InputKind::Dense,
        };
        let input_shape: Vec<usize> = input
            .get("shape_per_item")
            .and_then(|x| x.as_arr().map(|a| a.to_vec()))
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?
            .iter()
            .map(|d| d.as_i64().unwrap_or(0) as usize)
            .collect();
        let vocab = input.opt("vocab").ok().flatten().and_then(|x| x.as_i64().ok()).map(|x| x as usize);

        let m = ModelManifest {
            name,
            family,
            classes,
            batch_buckets,
            weights_file,
            hlo_files,
            flops_per_batch,
            params,
            input_kind,
            input_shape,
            vocab,
        };
        m.validate()?;
        Ok(m)
    }

    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, RuntimeError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| RuntimeError::Io { path: path.display().to_string(), source: e })?;
        Self::from_json(&text)
    }

    /// Internal consistency checks.
    pub fn validate(&self) -> Result<(), RuntimeError> {
        if self.batch_buckets.is_empty() {
            return Err(RuntimeError::Manifest("no batch buckets".into()));
        }
        for b in &self.batch_buckets {
            if !self.hlo_files.contains_key(b) {
                return Err(RuntimeError::Manifest(format!("bucket {b} has no HLO file")));
            }
        }
        let mut offset = 0usize;
        for p in &self.params {
            let numel: usize = p.shape.iter().product();
            if numel != p.numel {
                return Err(RuntimeError::Manifest(format!(
                    "param {}: shape product {numel} != numel {}",
                    p.name, p.numel
                )));
            }
            if p.offset != offset {
                return Err(RuntimeError::Manifest(format!(
                    "param {}: offset {} != expected {offset}",
                    p.name, p.offset
                )));
            }
            offset += numel * 4;
        }
        if self.input_kind == InputKind::Tokens && self.vocab.is_none() {
            return Err(RuntimeError::Manifest("token input requires vocab".into()));
        }
        Ok(())
    }

    /// Smallest bucket that fits `batch` items.
    pub fn bucket_for(&self, batch: usize) -> Option<usize> {
        self.batch_buckets.iter().copied().filter(|&b| b >= batch).min()
    }

    /// Largest supported batch.
    pub fn max_bucket(&self) -> usize {
        self.batch_buckets.iter().copied().max().unwrap_or(1)
    }

    /// Total byte size weights.bin must have.
    pub fn weights_bytes(&self) -> usize {
        self.params.iter().map(|p| p.numel * 4).sum()
    }

    /// Analytic FLOPs for one item at the given bucket (per-item share).
    pub fn flops_per_item(&self, bucket: usize) -> f64 {
        self.flops_per_batch.get(&bucket).map(|f| f / bucket as f64).unwrap_or(0.0)
    }

    /// Elements per input item.
    pub fn input_numel(&self) -> usize {
        self.input_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "name": "toy", "family": "transformer", "classes": 2,
      "batch_buckets": [1, 4],
      "weights_file": "weights.bin",
      "hlo_files": {"1": "model.b1.hlo.txt", "4": "model.b4.hlo.txt"},
      "flops_per_batch": {"1": 100.0, "4": 400.0},
      "params": [
        {"name": "embed", "shape": [8, 4], "offset": 0, "numel": 32},
        {"name": "w", "shape": [4, 2], "offset": 128, "numel": 8}
      ],
      "input": {"name": "tokens", "kind": "tokens", "shape_per_item": [16],
                "dtype": "i32", "vocab": 8}
    }"#;

    #[test]
    fn parses_and_validates() {
        let m = ModelManifest::from_json(MANIFEST).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.classes, 2);
        assert_eq!(m.batch_buckets, vec![1, 4]);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.input_kind, InputKind::Tokens);
        assert_eq!(m.vocab, Some(8));
        assert_eq!(m.weights_bytes(), 160);
        assert_eq!(m.input_numel(), 16);
    }

    #[test]
    fn bucket_selection() {
        let m = ModelManifest::from_json(MANIFEST).unwrap();
        assert_eq!(m.bucket_for(1), Some(1));
        assert_eq!(m.bucket_for(2), Some(4));
        assert_eq!(m.bucket_for(4), Some(4));
        assert_eq!(m.bucket_for(5), None);
        assert_eq!(m.max_bucket(), 4);
    }

    #[test]
    fn per_item_flops() {
        let m = ModelManifest::from_json(MANIFEST).unwrap();
        assert_eq!(m.flops_per_item(4), 100.0);
        assert_eq!(m.flops_per_item(9), 0.0);
    }

    #[test]
    fn rejects_bad_offsets() {
        let bad = MANIFEST.replace("\"offset\": 128", "\"offset\": 64");
        assert!(ModelManifest::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_shape_numel_mismatch() {
        let bad = MANIFEST.replace("\"numel\": 32", "\"numel\": 31");
        assert!(ModelManifest::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_missing_bucket_hlo() {
        let bad = MANIFEST.replace("\"batch_buckets\": [1, 4]", "\"batch_buckets\": [1, 2]");
        assert!(ModelManifest::from_json(&bad).is_err());
    }

    #[test]
    fn real_manifests_parse_if_built() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("repository.json").exists() {
            return;
        }
        for m in ["distilbert_mini", "resnet_tiny", "screener"] {
            let man = ModelManifest::load(&root.join(m)).unwrap();
            assert_eq!(man.name, m);
            let wsize = std::fs::metadata(root.join(m).join(&man.weights_file)).unwrap().len();
            assert_eq!(wsize as usize, man.weights_bytes());
        }
    }
}
