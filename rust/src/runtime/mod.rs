//! PJRT runtime: loads the AOT model repository (HLO text + weights +
//! config.pbtxt) and executes models via the `xla` crate's PJRT CPU
//! client.
//!
//! Threading model: `PjRtClient` is `Rc`-backed and **not** `Send`, so an
//! [`engine::Engine`] is thread-confined. The serving pipeline gives each
//! Triton-style *instance* its own engine on its own worker thread
//! (exactly Triton's instance-group semantics); the direct path owns one
//! engine behind its request loop.
//!
//! I/O-binding analog: weights can be pre-transferred to device buffers
//! once at load time (`ExecMode::DeviceBuffers`) so the per-request host→
//! device traffic is just the input tensor — the ORT "device tensors"
//! optimisation the paper leans on (§III-B, §VII "device tensors
//! matter").

pub mod engine;
pub mod lifecycle;
pub mod manifest;
pub mod registry;
pub mod repository;
pub mod tensor;

pub use engine::{Engine, ExecMode};
pub use lifecycle::{JobKind, JobSpec, LifecycleExecutor};
pub use manifest::{InputKind, ModelManifest, ParamEntry};
pub use registry::{LoadStats, ModelRegistry, ModelState, VersionView};
pub use repository::Repository;
pub use tensor::{InputBatch, OutputBatch};

// Error impls are hand-written (no `thiserror`): the crate builds from
// path dependencies alone, so the committed Cargo.lock never references
// a registry and the build stays hermetic offline.
#[derive(Debug)]
pub enum RuntimeError {
    Io {
        path: String,
        source: std::io::Error,
    },
    Manifest(String),
    Xla(String),
    UnknownModel(String),
    BatchTooLarge { model: String, requested: usize, max: usize },
    InputMismatch(String),
    Backpressure(String),
    DeadlineExceeded { elapsed_ms: u64, timeout_ms: u64 },
    /// The model is registered but no version matching the request is
    /// in `Ready` state (unloaded, still loading, or failed) — the
    /// typed 503 the v2 protocol reports as `MODEL_UNAVAILABLE`.
    ModelUnavailable { model: String },
    /// A present-but-malformed `config.pbtxt`: loading must fail loudly
    /// (HTTP 400), never silently serve with defaults.
    InvalidConfig { model: String, reason: String },
    /// An invalid lifecycle operation (unloading a model that is not
    /// loaded, loading a version that is mid-transition, ...).
    Lifecycle { model: String, reason: String },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Io { path, source } => write!(f, "io error on {path}: {source}"),
            RuntimeError::Manifest(m) => write!(f, "manifest error: {m}"),
            RuntimeError::Xla(m) => write!(f, "xla error: {m}"),
            RuntimeError::UnknownModel(m) => write!(f, "unknown model {m:?}"),
            RuntimeError::BatchTooLarge { model, requested, max } => write!(
                f,
                "no batch bucket >= {requested} for model {model} (max {max})"
            ),
            RuntimeError::InputMismatch(m) => write!(f, "input mismatch: {m}"),
            RuntimeError::Backpressure(m) => {
                write!(f, "queue full (backpressure) for model {m:?}")
            }
            RuntimeError::DeadlineExceeded { elapsed_ms, timeout_ms } => write!(
                f,
                "deadline exceeded: {elapsed_ms} ms elapsed against a {timeout_ms} ms budget"
            ),
            RuntimeError::ModelUnavailable { model } => {
                write!(f, "model {model:?} has no loaded version to serve")
            }
            RuntimeError::InvalidConfig { model, reason } => {
                write!(f, "model {model:?}: invalid config.pbtxt: {reason}")
            }
            RuntimeError::Lifecycle { model, reason } => write!(f, "model {model:?}: {reason}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}
