//! PJRT runtime: loads the AOT model repository (HLO text + weights +
//! config.pbtxt) and executes models via the `xla` crate's PJRT CPU
//! client.
//!
//! Threading model: `PjRtClient` is `Rc`-backed and **not** `Send`, so an
//! [`engine::Engine`] is thread-confined. The serving pipeline gives each
//! Triton-style *instance* its own engine on its own worker thread
//! (exactly Triton's instance-group semantics); the direct path owns one
//! engine behind its request loop.
//!
//! I/O-binding analog: weights can be pre-transferred to device buffers
//! once at load time (`ExecMode::DeviceBuffers`) so the per-request host→
//! device traffic is just the input tensor — the ORT "device tensors"
//! optimisation the paper leans on (§III-B, §VII "device tensors
//! matter").

pub mod engine;
pub mod manifest;
pub mod registry;
pub mod repository;
pub mod tensor;

pub use engine::{Engine, ExecMode};
pub use manifest::{InputKind, ModelManifest, ParamEntry};
pub use registry::{LoadStats, ModelRegistry, ModelState, VersionView};
pub use repository::Repository;
pub use tensor::{InputBatch, OutputBatch};

use thiserror::Error;

#[derive(Debug, Error)]
pub enum RuntimeError {
    #[error("io error on {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("unknown model {0:?}")]
    UnknownModel(String),
    #[error("no batch bucket >= {requested} for model {model} (max {max})")]
    BatchTooLarge { model: String, requested: usize, max: usize },
    #[error("input mismatch: {0}")]
    InputMismatch(String),
    #[error("queue full (backpressure) for model {0:?}")]
    Backpressure(String),
    #[error("deadline exceeded: {elapsed_ms} ms elapsed against a {timeout_ms} ms budget")]
    DeadlineExceeded { elapsed_ms: u64, timeout_ms: u64 },
    /// The model is registered but no version matching the request is
    /// in `Ready` state (unloaded, still loading, or failed) — the
    /// typed 503 the v2 protocol reports as `MODEL_UNAVAILABLE`.
    #[error("model {model:?} has no loaded version to serve")]
    ModelUnavailable { model: String },
    /// A present-but-malformed `config.pbtxt`: loading must fail loudly
    /// (HTTP 400), never silently serve with defaults.
    #[error("model {model:?}: invalid config.pbtxt: {reason}")]
    InvalidConfig { model: String, reason: String },
    /// An invalid lifecycle operation (unloading a model that is not
    /// loaded, loading a version that is mid-transition, ...).
    #[error("model {model:?}: {reason}")]
    Lifecycle { model: String, reason: String },
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}
