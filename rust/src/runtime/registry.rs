//! Dynamic model registry: per-model, per-version lifecycle state
//! machines behind the `/v2/repository` control API (the Triton
//! explicit model-control mode, arXiv 2403.17574's "model-lifecycle
//! management" design decision).
//!
//! The registry owns *facts and state* — which numbered versions exist
//! on disk, what lifecycle state each is in, what loading cost — while
//! [`crate::pipeline::system`] owns the *resources* (engines, batcher
//! threads) attached to `Ready` versions and the atomically-swapped
//! serving snapshot the hot path reads. Transitions:
//!
//! ```text
//! Unloaded ──begin_load──▶ Loading ──finish_load(Ok)──▶ Ready
//!     ▲                       │                           │
//!     │                       └─finish_load(Err)─▶ Failed{reason}
//!     │                                               (begin_load retries)
//!     └──finish_unload─── Unloading ◀──begin_unload──────┘
//! ```
//!
//! Repository layout: `repository.json` names the models; each model
//! directory either holds numbered version subdirectories
//! (`<model>/<N>/manifest.json`, Triton layout) or is itself version 1
//! (the flat layout `aot.py` has always exported). `config.pbtxt`
//! stays at the model root and applies to every version; a
//! present-but-malformed config is recorded as a parse error and fails
//! any load of that model — never silently defaulted (the old
//! `Repository::scan` `ok()/ok()` bug).
//!
//! Every state transition publishes the `gf_model_state.<model>.<v>`
//! gauge ([`ModelState::code`]); loads additionally publish
//! `gf_model_load_seconds.<model>.<v>` and bump the
//! `gf_model_loads_total` / `gf_model_load_failures_total` /
//! `gf_model_unloads_total` counters.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::configsys::{ModelConfig, VersionPolicy};
use crate::json;
use crate::runtime::RuntimeError;
use crate::telemetry::MetricsRegistry;

/// Lifecycle state of one model version.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelState {
    Unloaded,
    Loading,
    Ready,
    Unloading,
    Failed { reason: String },
}

impl ModelState {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelState::Unloaded => "UNLOADED",
            ModelState::Loading => "LOADING",
            ModelState::Ready => "READY",
            ModelState::Unloading => "UNLOADING",
            ModelState::Failed { .. } => "FAILED",
        }
    }

    /// Numeric code published as the `gf_model_state.<model>.<v>` gauge.
    pub fn code(&self) -> f64 {
        match self {
            ModelState::Unloaded => 0.0,
            ModelState::Loading => 1.0,
            ModelState::Ready => 2.0,
            ModelState::Unloading => 3.0,
            ModelState::Failed { .. } => -1.0,
        }
    }
}

/// One discovered version's on-disk identity (what a loader needs).
#[derive(Debug, Clone)]
pub struct VersionInfo {
    pub version: u64,
    pub dir: PathBuf,
}

/// What loading a version cost (reported by `/v2/models/{name}` — the
/// compile + weight-transfer energy a restartless swap avoids re-paying).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoadStats {
    /// Wallclock seconds from load start to Ready (engine spawn +
    /// per-bucket compilation + weight materialisation).
    pub load_secs: f64,
    /// Bytes of weights materialised.
    pub weight_bytes: u64,
    /// Estimated joules burned loading (device profile at full draw
    /// over `load_secs`).
    pub est_load_joules: f64,
}

/// Introspection view of one version (the `/v2/repository/index` row).
#[derive(Debug, Clone)]
pub struct VersionView {
    pub version: u64,
    pub state: ModelState,
    pub stats: Option<LoadStats>,
}

#[derive(Debug)]
struct VersionSlot {
    dir: PathBuf,
    state: ModelState,
    stats: Option<LoadStats>,
}

#[derive(Debug)]
struct ModelSlot {
    /// The model's root directory (rescanned on every load so version
    /// directories and config fixes deployed after boot are seen).
    dir: PathBuf,
    config: Option<ModelConfig>,
    /// Parse error from a present-but-malformed config.pbtxt.
    config_err: Option<String>,
    policy: VersionPolicy,
    versions: BTreeMap<u64, VersionSlot>,
}

/// The registry: models discovered from `repository.json` with their
/// per-version lifecycle state. All methods are `&self` (one internal
/// mutex) so the gateway's concurrent load/unload handlers serialise
/// on state transitions without holding any lock during actual
/// engine work.
#[derive(Debug)]
pub struct ModelRegistry {
    root: PathBuf,
    slots: Mutex<BTreeMap<String, ModelSlot>>,
}

impl ModelRegistry {
    /// Scan a repository root. Discovers models and versions and parses
    /// configs; nothing is loaded (every version starts `Unloaded`).
    pub fn scan(root: &Path) -> Result<Self, RuntimeError> {
        let idx_path = root.join("repository.json");
        let text = std::fs::read_to_string(&idx_path)
            .map_err(|e| RuntimeError::Io { path: idx_path.display().to_string(), source: e })?;
        let idx = json::parse(&text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let mut slots = BTreeMap::new();
        for name_v in idx
            .get("models")
            .and_then(|m| m.as_arr().map(|a| a.to_vec()))
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?
        {
            let name = name_v
                .as_str()
                .map_err(|e| RuntimeError::Manifest(e.to_string()))?
                .to_string();
            let dir = root.join(&name);
            // An index entry must have at least one loadable version.
            discover_versions(&dir)?;
            let mut slot = ModelSlot {
                dir,
                config: None,
                config_err: None,
                policy: VersionPolicy::default(),
                versions: BTreeMap::new(),
            };
            refresh_slot(&name, &mut slot);
            slots.insert(name, slot);
        }
        Ok(ModelRegistry { root: root.to_path_buf(), slots: Mutex::new(slots) })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Every registered model name (loaded or not), sorted.
    pub fn model_names(&self) -> Vec<String> {
        self.slots.lock().unwrap().keys().cloned().collect()
    }

    pub fn has_model(&self, model: &str) -> bool {
        self.slots.lock().unwrap().contains_key(model)
    }

    /// The model's parsed config; `Err(InvalidConfig)` when the file is
    /// present but malformed, `Ok(None)` when absent.
    pub fn config(&self, model: &str) -> Result<Option<ModelConfig>, RuntimeError> {
        let g = self.slots.lock().unwrap();
        let slot = g
            .get(model)
            .ok_or_else(|| RuntimeError::UnknownModel(model.to_string()))?;
        if let Some(reason) = &slot.config_err {
            return Err(RuntimeError::InvalidConfig {
                model: model.to_string(),
                reason: reason.clone(),
            });
        }
        Ok(slot.config.clone())
    }

    /// One version's current lifecycle state (None = unknown model or
    /// version). The async executor's pollable source of truth.
    pub fn state(&self, model: &str, version: u64) -> Option<ModelState> {
        let g = self.slots.lock().unwrap();
        g.get(model)?.versions.get(&version).map(|vs| vs.state.clone())
    }

    /// Per-version introspection for one model.
    pub fn views(&self, model: &str) -> Result<Vec<VersionView>, RuntimeError> {
        let g = self.slots.lock().unwrap();
        let slot = g
            .get(model)
            .ok_or_else(|| RuntimeError::UnknownModel(model.to_string()))?;
        Ok(slot
            .versions
            .iter()
            .map(|(&version, vs)| VersionView {
                version,
                state: vs.state.clone(),
                stats: vs.stats,
            })
            .collect())
    }

    /// The whole repository: (model, per-version views), sorted by name.
    pub fn index(&self) -> Vec<(String, Vec<VersionView>)> {
        let g = self.slots.lock().unwrap();
        g.iter()
            .map(|(name, slot)| {
                (
                    name.clone(),
                    slot.versions
                        .iter()
                        .map(|(&version, vs)| VersionView {
                            version,
                            state: vs.state.clone(),
                            stats: vs.stats,
                        })
                        .collect(),
                )
            })
            .collect()
    }

    /// Start loading: marks the target versions `Loading` and returns
    /// their on-disk info for the caller to attach engines to. With no
    /// explicit version the model's version policy picks the set.
    /// Already-`Ready` versions are skipped (idempotent load); a version
    /// mid-transition is a `Lifecycle` error; a malformed config fails
    /// every targeted version with `Failed{reason}`.
    pub fn begin_load(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Vec<VersionInfo>, RuntimeError> {
        let mut g = self.slots.lock().unwrap();
        let slot = g
            .get_mut(model)
            .ok_or_else(|| RuntimeError::UnknownModel(model.to_string()))?;
        // Re-read the model directory so versions and config fixes
        // deployed after boot are loadable without a restart (the whole
        // point of the lifecycle API). fs reads under the registry lock
        // are fine: this is a control-plane op, never the serve path.
        refresh_slot(model, slot);

        let available: Vec<u64> = slot.versions.keys().copied().collect();
        let targets: Vec<u64> = match version {
            Some(v) => vec![v],
            None => slot.policy.select(&available),
        };
        for &v in &targets {
            if !slot.versions.contains_key(&v) {
                return Err(RuntimeError::Lifecycle {
                    model: model.to_string(),
                    reason: format!("unknown version {v} (available: {available:?})"),
                });
            }
        }
        if targets.is_empty() {
            return Err(RuntimeError::Lifecycle {
                model: model.to_string(),
                reason: "version policy selects no versions".to_string(),
            });
        }

        if let Some(reason) = slot.config_err.clone() {
            for &v in &targets {
                set_state(model, v, slot, ModelState::Failed { reason: reason.clone() });
            }
            MetricsRegistry::global().counter("gf_model_load_failures_total").inc();
            return Err(RuntimeError::InvalidConfig { model: model.to_string(), reason });
        }

        // Validate before mutating: a busy sibling must not leave other
        // targets half-marked.
        for &v in &targets {
            match &slot.versions[&v].state {
                ModelState::Loading | ModelState::Unloading => {
                    return Err(RuntimeError::Lifecycle {
                        model: model.to_string(),
                        reason: format!(
                            "version {v} is busy ({})",
                            slot.versions[&v].state.as_str()
                        ),
                    });
                }
                _ => {}
            }
        }
        let mut out = Vec::new();
        for &v in &targets {
            if slot.versions[&v].state == ModelState::Ready {
                continue; // already serving
            }
            set_state(model, v, slot, ModelState::Loading);
            out.push(VersionInfo { version: v, dir: slot.versions[&v].dir.clone() });
        }
        Ok(out)
    }

    /// Abandon a load begun with [`ModelRegistry::begin_load`] without
    /// recording a failure: `Loading → Unloaded`. Used for sibling
    /// versions that were never attempted because an earlier one in the
    /// same request failed — leaving them `Loading` would brick them
    /// (every later load/unload sees "busy").
    pub fn abort_load(&self, model: &str, version: u64) {
        let mut g = self.slots.lock().unwrap();
        let Some(slot) = g.get_mut(model) else { return };
        let loading = slot
            .versions
            .get(&version)
            .map(|vs| vs.state == ModelState::Loading)
            .unwrap_or(false);
        if loading {
            set_state(model, version, slot, ModelState::Unloaded);
        }
    }

    /// Complete a load begun with [`ModelRegistry::begin_load`].
    pub fn finish_load(&self, model: &str, version: u64, result: Result<LoadStats, String>) {
        let mut g = self.slots.lock().unwrap();
        let Some(slot) = g.get_mut(model) else { return };
        if !slot.versions.contains_key(&version) {
            return;
        }
        let reg = MetricsRegistry::global();
        match result {
            Ok(stats) => {
                slot.versions.get_mut(&version).unwrap().stats = Some(stats);
                set_state(model, version, slot, ModelState::Ready);
                reg.gauge(&format!("gf_model_load_seconds.{model}.{version}"))
                    .set(stats.load_secs);
                reg.counter("gf_model_loads_total").inc();
            }
            Err(reason) => {
                set_state(model, version, slot, ModelState::Failed { reason });
                reg.counter("gf_model_load_failures_total").inc();
            }
        }
    }

    /// Start unloading: `Ready` → `Unloading` for the explicit version,
    /// or every ready version when none is given. Unloading a model with
    /// nothing loaded is a `Lifecycle` error (nothing to detach).
    pub fn begin_unload(
        &self,
        model: &str,
        version: Option<u64>,
    ) -> Result<Vec<u64>, RuntimeError> {
        let mut g = self.slots.lock().unwrap();
        let slot = g
            .get_mut(model)
            .ok_or_else(|| RuntimeError::UnknownModel(model.to_string()))?;
        let targets: Vec<u64> = match version {
            Some(v) => {
                let vs = slot.versions.get(&v).ok_or_else(|| RuntimeError::Lifecycle {
                    model: model.to_string(),
                    reason: format!("unknown version {v}"),
                })?;
                if vs.state != ModelState::Ready {
                    return Err(RuntimeError::Lifecycle {
                        model: model.to_string(),
                        reason: format!("version {v} is not loaded ({})", vs.state.as_str()),
                    });
                }
                vec![v]
            }
            None => slot
                .versions
                .iter()
                .filter(|(_, vs)| vs.state == ModelState::Ready)
                .map(|(&v, _)| v)
                .collect(),
        };
        if targets.is_empty() {
            return Err(RuntimeError::Lifecycle {
                model: model.to_string(),
                reason: "no loaded versions".to_string(),
            });
        }
        for &v in &targets {
            set_state(model, v, slot, ModelState::Unloading);
        }
        Ok(targets)
    }

    /// Complete an unload begun with [`ModelRegistry::begin_unload`].
    pub fn finish_unload(&self, model: &str, version: u64) {
        let mut g = self.slots.lock().unwrap();
        let Some(slot) = g.get_mut(model) else { return };
        if !slot.versions.contains_key(&version) {
            return;
        }
        slot.versions.get_mut(&version).unwrap().stats = None;
        set_state(model, version, slot, ModelState::Unloaded);
        MetricsRegistry::global().counter("gf_model_unloads_total").inc();
    }
}

fn set_state(model: &str, version: u64, slot: &mut ModelSlot, state: ModelState) {
    publish_state(model, version, &state);
    slot.versions.get_mut(&version).unwrap().state = state;
}

/// Re-read a model's on-disk facts: config.pbtxt (including its parse
/// error and version policy) and the set of version directories. New
/// numbered versions appear as `Unloaded`; directories that vanished
/// are dropped only while `Unloaded` (a loaded version keeps serving
/// until explicitly unloaded, Triton-style).
fn refresh_slot(model: &str, slot: &mut ModelSlot) {
    let (config, config_err) = match std::fs::read_to_string(slot.dir.join("config.pbtxt")) {
        Ok(text) => match ModelConfig::from_pbtxt(&text) {
            Ok(c) => (Some(c), None),
            Err(e) => (None, Some(e.to_string())),
        },
        // config.pbtxt is optional; only a *present* broken one is an
        // error state.
        Err(_) => (None, None),
    };
    slot.policy = config
        .as_ref()
        .and_then(|c| c.version_policy.clone())
        .unwrap_or_default();
    slot.config = config;
    slot.config_err = config_err;

    if let Ok(found) = discover_versions(&slot.dir) {
        let on_disk: Vec<u64> = found.iter().map(|i| i.version).collect();
        for info in found {
            if !slot.versions.contains_key(&info.version) {
                publish_state(model, info.version, &ModelState::Unloaded);
                slot.versions.insert(
                    info.version,
                    VersionSlot { dir: info.dir, state: ModelState::Unloaded, stats: None },
                );
            }
        }
        slot.versions
            .retain(|v, vs| on_disk.contains(v) || vs.state != ModelState::Unloaded);
    }
}

fn publish_state(model: &str, version: u64, state: &ModelState) {
    MetricsRegistry::global()
        .gauge(&format!("gf_model_state.{model}.{version}"))
        .set(state.code());
}

/// Numbered version subdirectories (`<model>/<N>/manifest.json`); a flat
/// layout (manifest at the model root) is version 1. A model with
/// neither is a scan error — an index entry must be loadable.
fn discover_versions(dir: &Path) -> Result<Vec<VersionInfo>, RuntimeError> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let p = e.path();
            if !p.is_dir() {
                continue;
            }
            let Some(v) = p
                .file_name()
                .and_then(|n| n.to_str())
                .and_then(|n| n.parse::<u64>().ok())
            else {
                continue;
            };
            if v >= 1 && p.join("manifest.json").exists() {
                out.push(VersionInfo { version: v, dir: p });
            }
        }
    }
    if out.is_empty() {
        if dir.join("manifest.json").exists() {
            out.push(VersionInfo { version: 1, dir: dir.to_path_buf() });
        } else {
            return Err(RuntimeError::Manifest(format!(
                "{}: no versions (no manifest.json at the model root or under \
                 numbered version directories)",
                dir.display()
            )));
        }
    }
    out.sort_by_key(|i| i.version);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Write one version's artifact set (manifest + weights + HLO text).
    fn write_version_files(dir: &Path, name: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = format!(
            "{{\"name\": {name:?}, \"family\": \"toy\", \"classes\": 2,
               \"batch_buckets\": [1],
               \"weights_file\": \"weights.bin\",
               \"hlo_files\": {{\"1\": \"model.b1.hlo.txt\"}},
               \"params\": [{{\"name\": \"w\", \"shape\": [2, 2],
                             \"offset\": 0, \"numel\": 4}}],
               \"input\": {{\"name\": \"tokens\", \"kind\": \"tokens\",
                           \"shape_per_item\": [4], \"dtype\": \"i32\",
                           \"vocab\": 8}}}}"
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(dir.join("weights.bin"), [0u8; 16]).unwrap();
        std::fs::write(dir.join("model.b1.hlo.txt"), "HloModule toy").unwrap();
    }

    /// Build a throwaway repository on disk: `(name, versions, config)`
    /// per model; `versions` empty = flat layout.
    fn synth_repo(models: &[(&str, &[u64], Option<&str>)]) -> PathBuf {
        let root = std::env::temp_dir().join(format!(
            "gf-registry-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let names: Vec<String> =
            models.iter().map(|(n, _, _)| format!("{n:?}")).collect();
        std::fs::write(
            root.join("repository.json"),
            format!("{{\"models\": [{}]}}", names.join(", ")),
        )
        .unwrap();
        for (name, versions, config) in models {
            let dir = root.join(name);
            std::fs::create_dir_all(&dir).unwrap();
            if versions.is_empty() {
                write_version_files(&dir, name);
            } else {
                for v in *versions {
                    write_version_files(&dir.join(v.to_string()), name);
                }
            }
            if let Some(cfg) = config {
                std::fs::write(dir.join("config.pbtxt"), cfg).unwrap();
            }
        }
        root
    }

    const GOOD_CONFIG: &str = "name: \"versioned\"\nmax_batch_size: 1\n\
        input [ { name: \"tokens\" data_type: TYPE_INT32 dims: [ 4 ] } ]\n\
        version_policy { latest { num_versions: 2 } }\n";

    #[test]
    fn scans_flat_and_versioned_layouts() {
        let root = synth_repo(&[
            ("flat", &[], None),
            ("versioned", &[1, 2, 5], Some(GOOD_CONFIG)),
        ]);
        let reg = ModelRegistry::scan(&root).unwrap();
        assert_eq!(reg.model_names(), vec!["flat", "versioned"]);
        let flat = reg.views("flat").unwrap();
        assert_eq!(flat.len(), 1);
        assert_eq!(flat[0].version, 1);
        assert_eq!(flat[0].state, ModelState::Unloaded);
        let v: Vec<u64> =
            reg.views("versioned").unwrap().iter().map(|x| x.version).collect();
        assert_eq!(v, vec![1, 2, 5]);
        assert!(reg.views("nope").is_err());
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn load_unload_state_machine() {
        let root = synth_repo(&[("versioned", &[1, 2, 5], Some(GOOD_CONFIG))]);
        let reg = ModelRegistry::scan(&root).unwrap();

        // Policy (latest 2) picks versions 2 and 5.
        let targets = reg.begin_load("versioned", None).unwrap();
        let vs: Vec<u64> = targets.iter().map(|t| t.version).collect();
        assert_eq!(vs, vec![2, 5]);
        assert_eq!(reg.views("versioned").unwrap()[1].state, ModelState::Loading);

        // A version mid-load is busy.
        let err = reg.begin_load("versioned", Some(2)).unwrap_err();
        assert!(matches!(err, RuntimeError::Lifecycle { .. }), "{err}");

        let stats = LoadStats { load_secs: 0.5, weight_bytes: 16, est_load_joules: 9.0 };
        reg.finish_load("versioned", 2, Ok(stats));
        reg.finish_load("versioned", 5, Err("compile exploded".into()));
        let views = reg.views("versioned").unwrap();
        assert_eq!(views[1].state, ModelState::Ready);
        assert_eq!(views[1].stats, Some(stats));
        assert!(matches!(
            &views[2].state,
            ModelState::Failed { reason } if reason.contains("exploded")
        ));

        // Re-loading an already-Ready version is an idempotent no-op;
        // Failed versions retry.
        let retry = reg.begin_load("versioned", None).unwrap();
        assert_eq!(retry.len(), 1);
        assert_eq!(retry[0].version, 5);
        reg.finish_load("versioned", 5, Ok(stats));

        // Unload everything ready.
        let unloading = reg.begin_unload("versioned", None).unwrap();
        assert_eq!(unloading, vec![2, 5]);
        for v in unloading {
            reg.finish_unload("versioned", v);
        }
        assert!(reg
            .views("versioned")
            .unwrap()
            .iter()
            .all(|v| v.state == ModelState::Unloaded));
        // Nothing loaded → unload errors.
        assert!(matches!(
            reg.begin_unload("versioned", None),
            Err(RuntimeError::Lifecycle { .. })
        ));
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn explicit_version_bypasses_policy_and_unknown_versions_error() {
        let root = synth_repo(&[("versioned", &[1, 2, 5], Some(GOOD_CONFIG))]);
        let reg = ModelRegistry::scan(&root).unwrap();
        let targets = reg.begin_load("versioned", Some(1)).unwrap();
        assert_eq!(targets.len(), 1);
        assert_eq!(targets[0].version, 1);
        assert!(matches!(
            reg.begin_load("versioned", Some(9)),
            Err(RuntimeError::Lifecycle { .. })
        ));
        assert!(matches!(
            reg.begin_load("nope", None),
            Err(RuntimeError::UnknownModel(_))
        ));
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn malformed_config_fails_load_loudly() {
        let root = synth_repo(&[("flat", &[], Some("max_batch_size: {{{ garbage"))]);
        let reg = ModelRegistry::scan(&root).unwrap();
        assert!(matches!(
            reg.config("flat"),
            Err(RuntimeError::InvalidConfig { .. })
        ));
        let err = reg.begin_load("flat", None).unwrap_err();
        assert!(matches!(err, RuntimeError::InvalidConfig { .. }), "{err}");
        assert!(matches!(
            &reg.views("flat").unwrap()[0].state,
            ModelState::Failed { .. }
        ));
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn load_rescans_versions_and_config_deployed_after_boot() {
        let root =
            synth_repo(&[("versioned", &[1], Some("max_batch_size: {{{ garbage"))]);
        let reg = ModelRegistry::scan(&root).unwrap();
        // Broken config at boot: load fails loudly.
        assert!(matches!(
            reg.begin_load("versioned", None),
            Err(RuntimeError::InvalidConfig { .. })
        ));
        // Operator fixes the config and deploys version 2 on the live
        // server — the next load sees both without a restart.
        std::fs::write(
            root.join("versioned").join("config.pbtxt"),
            GOOD_CONFIG, // policy: latest 2
        )
        .unwrap();
        write_version_files(&root.join("versioned").join("2"), "versioned");
        let targets = reg.begin_load("versioned", None).unwrap();
        let vs: Vec<u64> = targets.iter().map(|t| t.version).collect();
        assert_eq!(vs, vec![1, 2], "policy latest-2 over the rescanned set");
        let views: Vec<u64> =
            reg.views("versioned").unwrap().iter().map(|v| v.version).collect();
        assert_eq!(views, vec![1, 2]);
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn abort_load_reverts_loading_to_unloaded() {
        let root = synth_repo(&[("versioned", &[1, 2, 5], Some(GOOD_CONFIG))]);
        let reg = ModelRegistry::scan(&root).unwrap();
        let targets = reg.begin_load("versioned", None).unwrap(); // [2, 5]
        assert_eq!(targets.len(), 2);
        // Version 2's attach failed; version 5 was never attempted and
        // must not stay bricked in Loading.
        reg.finish_load("versioned", 2, Err("engine spawn failed".into()));
        reg.abort_load("versioned", 5);
        let views = reg.views("versioned").unwrap();
        assert!(matches!(&views[1].state, ModelState::Failed { .. }));
        assert_eq!(views[2].state, ModelState::Unloaded);
        // Both are loadable again.
        let retry = reg.begin_load("versioned", None).unwrap();
        assert_eq!(retry.len(), 2);
        // abort_load never clobbers a non-Loading state.
        reg.finish_load("versioned", 2, Ok(LoadStats::default()));
        reg.abort_load("versioned", 2);
        assert_eq!(reg.views("versioned").unwrap()[1].state, ModelState::Ready);
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn model_without_manifest_is_a_scan_error() {
        let root = synth_repo(&[("flat", &[], None)]);
        std::fs::remove_file(root.join("flat").join("manifest.json")).unwrap();
        assert!(ModelRegistry::scan(&root).is_err());
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn state_gauges_track_transitions() {
        // Unique model name: the gauge namespace is process-global and
        // other tests in this module also mint a "flat" model.
        let root = synth_repo(&[("gauge_probe", &[], None)]);
        let reg = ModelRegistry::scan(&root).unwrap();
        let gauge = || {
            MetricsRegistry::global()
                .gauge("gf_model_state.gauge_probe.1")
                .get()
        };
        assert_eq!(gauge(), ModelState::Unloaded.code());
        reg.begin_load("gauge_probe", None).unwrap();
        assert_eq!(gauge(), ModelState::Loading.code());
        reg.finish_load("gauge_probe", 1, Ok(LoadStats::default()));
        assert_eq!(gauge(), ModelState::Ready.code());
        reg.begin_unload("gauge_probe", None).unwrap();
        reg.finish_unload("gauge_probe", 1);
        assert_eq!(gauge(), ModelState::Unloaded.code());
        let _ = std::fs::remove_dir_all(root);
    }
}
