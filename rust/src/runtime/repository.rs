//! Model repository: the directory layout `aot.py` exports (Triton's
//! model-repository concept). Scans `repository.json`, loads every
//! model's manifest + serving config without touching PJRT (so the
//! coordinator can plan batching before spawning engine workers).
//!
//! This is the *static* flat-layout view used by `greenflow report`
//! and the offline benches. The serving system itself runs on the
//! dynamic, versioned [`super::registry::ModelRegistry`], which adds
//! numbered version directories and the load/unload lifecycle.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::configsys::ModelConfig;
use crate::json;
use crate::runtime::manifest::ModelManifest;
use crate::runtime::RuntimeError;

/// One repository entry: manifest + optional serving config.
#[derive(Debug, Clone)]
pub struct RepoEntry {
    pub dir: PathBuf,
    pub manifest: ModelManifest,
    pub config: Option<ModelConfig>,
}

/// The scanned repository.
#[derive(Debug, Clone)]
pub struct Repository {
    pub root: PathBuf,
    pub entries: BTreeMap<String, RepoEntry>,
}

impl Repository {
    /// Scan a repository root (reads `repository.json` for the index).
    pub fn scan(root: &Path) -> Result<Self, RuntimeError> {
        let idx_path = root.join("repository.json");
        let text = std::fs::read_to_string(&idx_path)
            .map_err(|e| RuntimeError::Io { path: idx_path.display().to_string(), source: e })?;
        let idx = json::parse(&text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let mut entries = BTreeMap::new();
        for name in idx
            .get("models")
            .and_then(|m| m.as_arr().map(|a| a.to_vec()))
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?
        {
            let name = name.as_str().map_err(|e| RuntimeError::Manifest(e.to_string()))?;
            let dir = root.join(name);
            let manifest = ModelManifest::load(&dir)?;
            // config.pbtxt is optional, but a *present* malformed one is
            // an error — silently serving with defaults would hide a
            // corrupt deployment (the lifecycle API reports the same
            // condition as a load `Failed{reason}` / HTTP 400).
            let config = match std::fs::read_to_string(dir.join("config.pbtxt")) {
                Ok(text) => Some(ModelConfig::from_pbtxt(&text).map_err(|e| {
                    RuntimeError::InvalidConfig {
                        model: name.to_string(),
                        reason: e.to_string(),
                    }
                })?),
                Err(_) => None,
            };
            entries.insert(
                manifest.name.clone(),
                RepoEntry { dir, manifest, config },
            );
        }
        Ok(Repository { root: root.to_path_buf(), entries })
    }

    pub fn get(&self, model: &str) -> Result<&RepoEntry, RuntimeError> {
        self.entries.get(model).ok_or_else(|| RuntimeError::UnknownModel(model.to_string()))
    }

    pub fn model_names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Max queue delay for the model's dynamic batcher (µs), from
    /// config.pbtxt (0 = no batching window).
    pub fn queue_delay_us(&self, model: &str) -> u64 {
        self.entries
            .get(model)
            .and_then(|e| e.config.as_ref())
            .and_then(|c| c.dynamic_batching.as_ref())
            .map(|d| d.max_queue_delay_us)
            .unwrap_or(0)
    }

    /// Validate all entries against their configs (shape/dtype discipline,
    /// the paper's §VII "practical gotchas").
    pub fn validate(&self) -> Result<(), RuntimeError> {
        for (name, e) in &self.entries {
            e.manifest.validate()?;
            if let Some(cfg) = &e.config {
                cfg.validate().map_err(|err| {
                    RuntimeError::Manifest(format!("{name}: config.pbtxt invalid: {err}"))
                })?;
                // batch discipline: config max must be a known bucket
                if e.manifest.bucket_for(cfg.max_batch_size).is_none() {
                    return Err(RuntimeError::Manifest(format!(
                        "{name}: config max_batch_size {} exceeds buckets {:?}",
                        cfg.max_batch_size, e.manifest.batch_buckets
                    )));
                }
                // shape discipline: config dims must match manifest input
                if let Some(inp) = cfg.inputs.first() {
                    if inp.dims != e.manifest.input_shape {
                        return Err(RuntimeError::Manifest(format!(
                            "{name}: config dims {:?} != manifest {:?}",
                            inp.dims, e.manifest.input_shape
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo() -> Option<Repository> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("repository.json").exists().then(|| Repository::scan(&root).unwrap())
    }

    #[test]
    fn scans_all_models() {
        let Some(r) = repo() else { return };
        assert_eq!(
            r.model_names(),
            vec!["distilbert_mini", "resnet_tiny", "screener"]
        );
        r.validate().unwrap();
    }

    #[test]
    fn configs_are_attached() {
        let Some(r) = repo() else { return };
        let e = r.get("distilbert_mini").unwrap();
        let cfg = e.config.as_ref().expect("config.pbtxt present");
        assert_eq!(cfg.max_batch_size, 8);
        assert_eq!(r.queue_delay_us("distilbert_mini"), 2000);
    }

    #[test]
    fn unknown_model_errors() {
        let Some(r) = repo() else { return };
        assert!(r.get("nope").is_err());
    }

    #[test]
    fn missing_root_errors() {
        assert!(Repository::scan(Path::new("/nonexistent/path")).is_err());
    }

    #[test]
    fn malformed_config_is_a_scan_error_not_a_silent_default() {
        let root = std::env::temp_dir().join(format!(
            "gf-repo-scan-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&root);
        let dir = root.join("toy");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(root.join("repository.json"), r#"{"models": ["toy"]}"#).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"name": "toy", "family": "t", "classes": 2,
                "batch_buckets": [1], "weights_file": "weights.bin",
                "hlo_files": {"1": "m.hlo.txt"},
                "params": [{"name": "w", "shape": [2], "offset": 0, "numel": 2}],
                "input": {"name": "tokens", "kind": "tokens",
                          "shape_per_item": [4], "dtype": "i32", "vocab": 4}}"#,
        )
        .unwrap();
        std::fs::write(dir.join("config.pbtxt"), "name: \"toy\" max_batch_size: {{{").unwrap();
        let err = Repository::scan(&root).unwrap_err();
        assert!(
            matches!(err, RuntimeError::InvalidConfig { .. }),
            "corrupt config must fail the scan, got {err}"
        );
        // Removing the corrupt file makes the same repository scan fine.
        std::fs::remove_file(dir.join("config.pbtxt")).unwrap();
        assert!(Repository::scan(&root).is_ok());
        let _ = std::fs::remove_dir_all(root);
    }
}
