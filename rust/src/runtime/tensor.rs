//! Typed input/output batches and the padding logic for batch bucketing.

use crate::runtime::manifest::{InputKind, ModelManifest};
use crate::runtime::RuntimeError;

/// A host-side input batch for one model.
#[derive(Debug, Clone, PartialEq)]
pub enum InputBatch {
    /// i32 token ids, row-major (batch, numel_per_item).
    Tokens { data: Vec<i32>, batch: usize, per_item: usize },
    /// f32 dense tensor, row-major (batch, numel_per_item).
    Dense { data: Vec<f32>, batch: usize, per_item: usize },
}

impl InputBatch {
    pub fn batch(&self) -> usize {
        match self {
            InputBatch::Tokens { batch, .. } | InputBatch::Dense { batch, .. } => *batch,
        }
    }

    pub fn per_item(&self) -> usize {
        match self {
            InputBatch::Tokens { per_item, .. } | InputBatch::Dense { per_item, .. } => *per_item,
        }
    }

    /// Check the batch against a manifest's input spec.
    pub fn check(&self, m: &ModelManifest) -> Result<(), RuntimeError> {
        let want_kind = match self {
            InputBatch::Tokens { .. } => InputKind::Tokens,
            InputBatch::Dense { .. } => InputKind::Dense,
        };
        if want_kind != m.input_kind {
            return Err(RuntimeError::InputMismatch(format!(
                "model {} expects {:?} input, got {:?}",
                m.name, m.input_kind, want_kind
            )));
        }
        if self.per_item() != m.input_numel() {
            return Err(RuntimeError::InputMismatch(format!(
                "model {} expects {} elements per item, got {}",
                m.name,
                m.input_numel(),
                self.per_item()
            )));
        }
        let (len, batch) = match self {
            InputBatch::Tokens { data, batch, .. } => (data.len(), *batch),
            InputBatch::Dense { data, batch, .. } => (data.len(), *batch),
        };
        if len != batch * self.per_item() {
            return Err(RuntimeError::InputMismatch(format!(
                "data length {} != batch {} x per_item {}",
                len,
                batch,
                self.per_item()
            )));
        }
        Ok(())
    }

    /// Pad the batch up to `bucket` rows by repeating the last row
    /// (zero-filling a token row could index embedding row 0; repeating a
    /// real row keeps the padded compute numerically harmless and is what
    /// Triton's batcher does with ragged fills).
    pub fn pad_to(&self, bucket: usize) -> InputBatch {
        assert!(bucket >= self.batch(), "bucket smaller than batch");
        match self {
            InputBatch::Tokens { data, batch, per_item } => {
                let mut d = data.clone();
                let last = data[(batch - 1) * per_item..].to_vec();
                for _ in *batch..bucket {
                    d.extend_from_slice(&last);
                }
                InputBatch::Tokens { data: d, batch: bucket, per_item: *per_item }
            }
            InputBatch::Dense { data, batch, per_item } => {
                let mut d = data.clone();
                let last = data[(batch - 1) * per_item..].to_vec();
                for _ in *batch..bucket {
                    d.extend_from_slice(&last);
                }
                InputBatch::Dense { data: d, batch: bucket, per_item: *per_item }
            }
        }
    }

    /// Concatenate single-item batches (the dynamic batcher's fuse step).
    pub fn concat(items: &[InputBatch]) -> Result<InputBatch, RuntimeError> {
        assert!(!items.is_empty());
        let per_item = items[0].per_item();
        match &items[0] {
            InputBatch::Tokens { .. } => {
                let mut data = Vec::with_capacity(items.len() * per_item);
                let mut batch = 0;
                for it in items {
                    match it {
                        InputBatch::Tokens { data: d, batch: b, per_item: p } if *p == per_item => {
                            data.extend_from_slice(d);
                            batch += b;
                        }
                        _ => {
                            return Err(RuntimeError::InputMismatch(
                                "heterogeneous batch items".into(),
                            ))
                        }
                    }
                }
                Ok(InputBatch::Tokens { data, batch, per_item })
            }
            InputBatch::Dense { .. } => {
                let mut data = Vec::with_capacity(items.len() * per_item);
                let mut batch = 0;
                for it in items {
                    match it {
                        InputBatch::Dense { data: d, batch: b, per_item: p } if *p == per_item => {
                            data.extend_from_slice(d);
                            batch += b;
                        }
                        _ => {
                            return Err(RuntimeError::InputMismatch(
                                "heterogeneous batch items".into(),
                            ))
                        }
                    }
                }
                Ok(InputBatch::Dense { data, batch, per_item })
            }
        }
    }
}

/// Decoded model outputs for a batch (padding rows already sliced away).
#[derive(Debug, Clone, PartialEq)]
pub struct OutputBatch {
    pub batch: usize,
    pub classes: usize,
    /// (batch, classes) row-major.
    pub logits: Vec<f32>,
    /// (batch, classes) row-major.
    pub probs: Vec<f32>,
    /// (batch,) entropy in nats — the L(x) signal.
    pub entropy: Vec<f32>,
}

impl OutputBatch {
    /// Argmax class of item `i`.
    pub fn predicted(&self, i: usize) -> u32 {
        let row = &self.probs[i * self.classes..(i + 1) * self.classes];
        let mut best = 0usize;
        for (j, &p) in row.iter().enumerate() {
            if p > row[best] {
                best = j;
            }
        }
        best as u32
    }

    /// Max probability (confidence) of item `i`.
    pub fn confidence(&self, i: usize) -> f32 {
        let row = &self.probs[i * self.classes..(i + 1) * self.classes];
        row.iter().copied().fold(f32::MIN, f32::max)
    }

    /// Keep only the first `n` rows (drop padding).
    pub fn truncate(mut self, n: usize) -> OutputBatch {
        assert!(n <= self.batch);
        self.logits.truncate(n * self.classes);
        self.probs.truncate(n * self.classes);
        self.entropy.truncate(n);
        self.batch = n;
        self
    }

    /// Split into per-item outputs (to answer fused batch members).
    pub fn split(&self) -> Vec<OutputBatch> {
        (0..self.batch)
            .map(|i| OutputBatch {
                batch: 1,
                classes: self.classes,
                logits: self.logits[i * self.classes..(i + 1) * self.classes].to_vec(),
                probs: self.probs[i * self.classes..(i + 1) * self.classes].to_vec(),
                entropy: vec![self.entropy[i]],
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens(batch: usize, per_item: usize) -> InputBatch {
        InputBatch::Tokens {
            data: (0..batch * per_item).map(|x| x as i32).collect(),
            batch,
            per_item,
        }
    }

    #[test]
    fn pad_repeats_last_row() {
        let b = tokens(2, 3);
        let p = b.pad_to(4);
        match p {
            InputBatch::Tokens { data, batch, .. } => {
                assert_eq!(batch, 4);
                assert_eq!(data, vec![0, 1, 2, 3, 4, 5, 3, 4, 5, 3, 4, 5]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn pad_noop_at_same_size() {
        let b = tokens(2, 3);
        assert_eq!(b.pad_to(2), b);
    }

    #[test]
    #[should_panic]
    fn pad_smaller_panics() {
        tokens(3, 2).pad_to(2);
    }

    #[test]
    fn concat_fuses_batches() {
        let a = tokens(1, 3);
        let b = tokens(2, 3);
        let c = InputBatch::concat(&[a, b]).unwrap();
        assert_eq!(c.batch(), 3);
        assert_eq!(c.per_item(), 3);
    }

    #[test]
    fn concat_rejects_mixed_kinds() {
        let a = tokens(1, 3);
        let b = InputBatch::Dense { data: vec![0.0; 3], batch: 1, per_item: 3 };
        assert!(InputBatch::concat(&[a, b]).is_err());
    }

    #[test]
    fn output_argmax_and_confidence() {
        let o = OutputBatch {
            batch: 2,
            classes: 3,
            logits: vec![0.0; 6],
            probs: vec![0.1, 0.7, 0.2, 0.5, 0.3, 0.2],
            entropy: vec![0.8, 1.0],
        };
        assert_eq!(o.predicted(0), 1);
        assert_eq!(o.predicted(1), 0);
        assert!((o.confidence(0) - 0.7).abs() < 1e-6);
    }

    #[test]
    fn truncate_drops_padding() {
        let o = OutputBatch {
            batch: 4,
            classes: 2,
            logits: vec![0.0; 8],
            probs: vec![0.5; 8],
            entropy: vec![0.1, 0.2, 0.3, 0.4],
        };
        let t = o.truncate(2);
        assert_eq!(t.batch, 2);
        assert_eq!(t.entropy, vec![0.1, 0.2]);
        assert_eq!(t.probs.len(), 4);
    }

    #[test]
    fn split_gives_per_item_views() {
        let o = OutputBatch {
            batch: 2,
            classes: 2,
            logits: vec![1.0, 2.0, 3.0, 4.0],
            probs: vec![0.3, 0.7, 0.6, 0.4],
            entropy: vec![0.6, 0.7],
        };
        let parts = o.split();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].logits, vec![3.0, 4.0]);
        assert_eq!(parts[1].entropy, vec![0.7]);
        assert_eq!(parts[0].predicted(0), 1);
    }
}
