//! The v2 inference protocol: typed request/response/error structs with
//! stable JSON encodings and HTTP mappings (KServe/Triton-inspired).
//!
//! The gateway's route handlers parse bodies into these types, run the
//! serving system, and serialise the results back — no ad-hoc JSON
//! plumbing inside handlers. Error codes are part of the contract
//! (`docs/API.md`): clients dispatch on `error.code`, not on prose.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::json::{self, Value};
use crate::pipeline::system::{InferResult, VersionHandle};
use crate::router::PathKind;
use crate::runtime::registry::{ModelState, VersionView};
use crate::runtime::RuntimeError;
use crate::workload::stream::Priority;

use super::http::HttpResponse;

/// Most items accepted in one batch-infer body.
pub const MAX_BATCH_ITEMS: usize = 64;

/// Seeds are JSON numbers; above 2^53 an f64 silently loses integers.
const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0; // 2^53

/// Stable v2 error codes with their HTTP mappings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    BadRequest,
    /// A well-formed request carrying a malformed QoS header
    /// (`X-Request-Deadline`, `X-Retry-Attempt`, `X-Tenant-Id`): typed
    /// 400 so clients cannot believe they set a deadline that was
    /// silently dropped.
    InvalidArgument,
    NotFound,
    ModelNotFound,
    /// The model exists in the repository but has no ready version
    /// matching the request (unloaded / loading / failed).
    ModelUnavailable,
    Unsupported,
    PayloadTooLarge,
    Backpressure,
    /// The tenant is over its GCRA quota; `Retry-After` carries the
    /// theoretical-arrival-time hint.
    RateLimited,
    /// The tenant's retry budget is exhausted; the retry was shed
    /// before admission.
    RetryBudgetExhausted,
    DeadlineExceeded,
    Internal,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "BAD_REQUEST",
            ErrorCode::InvalidArgument => "INVALID_ARGUMENT",
            ErrorCode::NotFound => "NOT_FOUND",
            ErrorCode::ModelNotFound => "MODEL_NOT_FOUND",
            ErrorCode::ModelUnavailable => "MODEL_UNAVAILABLE",
            ErrorCode::Unsupported => "UNSUPPORTED",
            ErrorCode::PayloadTooLarge => "PAYLOAD_TOO_LARGE",
            ErrorCode::Backpressure => "BACKPRESSURE",
            ErrorCode::RateLimited => "RATE_LIMITED",
            ErrorCode::RetryBudgetExhausted => "RETRY_BUDGET_EXHAUSTED",
            ErrorCode::DeadlineExceeded => "DEADLINE_EXCEEDED",
            ErrorCode::Internal => "INTERNAL",
        }
    }

    pub fn http_status(&self) -> u16 {
        match self {
            ErrorCode::BadRequest | ErrorCode::InvalidArgument => 400,
            ErrorCode::NotFound | ErrorCode::ModelNotFound => 404,
            ErrorCode::ModelUnavailable => 503,
            ErrorCode::Unsupported => 405,
            ErrorCode::PayloadTooLarge => 413,
            ErrorCode::Backpressure
            | ErrorCode::RateLimited
            | ErrorCode::RetryBudgetExhausted => 429,
            ErrorCode::DeadlineExceeded => 504,
            ErrorCode::Internal => 500,
        }
    }
}

/// A protocol-level error: code + human message, plus an optional
/// `Retry-After` hint every 429 should carry.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    /// Seconds the client should wait before retrying; rendered as a
    /// `Retry-After` response header (shed responses without a hint
    /// teach clients to hammer).
    pub retry_after_secs: Option<u64>,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        ApiError { code, message: message.into(), retry_after_secs: None }
    }

    pub fn bad_request(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::BadRequest, message)
    }

    /// Typed 400 for a malformed QoS header.
    pub fn invalid_argument(message: impl Into<String>) -> Self {
        Self::new(ErrorCode::InvalidArgument, message)
    }

    /// Attach a `Retry-After` hint (seconds, floored at 1 so a
    /// sub-second wait never renders as `Retry-After: 0`).
    pub fn with_retry_after(mut self, secs: f64) -> Self {
        self.retry_after_secs = Some(secs.max(0.0).ceil().max(1.0) as u64);
        self
    }

    /// Map a serving-system error onto the protocol.
    pub fn from_runtime(e: &RuntimeError) -> Self {
        let code = match e {
            RuntimeError::UnknownModel(_) => ErrorCode::ModelNotFound,
            RuntimeError::ModelUnavailable { .. } => ErrorCode::ModelUnavailable,
            RuntimeError::Backpressure(_) => ErrorCode::Backpressure,
            RuntimeError::DeadlineExceeded { .. } => ErrorCode::DeadlineExceeded,
            RuntimeError::BatchTooLarge { .. }
            | RuntimeError::InputMismatch(_)
            | RuntimeError::InvalidConfig { .. }
            | RuntimeError::Lifecycle { .. } => ErrorCode::BadRequest,
            RuntimeError::Io { .. } | RuntimeError::Manifest(_) | RuntimeError::Xla(_) => {
                ErrorCode::Internal
            }
        };
        ApiError { code, message: e.to_string(), retry_after_secs: None }
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![(
            "error",
            json::obj(vec![
                ("code", json::s(self.code.as_str())),
                ("message", json::s(&self.message)),
            ]),
        )])
    }

    pub fn to_response(&self) -> HttpResponse {
        let resp = HttpResponse::json(self.code.http_status(), self.to_json().to_json());
        match self.retry_after_secs {
            Some(secs) => resp.with_header("Retry-After", &secs.to_string()),
            None => resp,
        }
    }
}

/// Which serving path the client asked for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PathChoice {
    /// Defer to the shared router (arrival window + adaptive threshold).
    #[default]
    Auto,
    Pinned(PathKind),
}

impl PathChoice {
    pub fn parse(s: &str) -> Option<PathChoice> {
        if s == "auto" {
            return Some(PathChoice::Auto);
        }
        PathKind::parse(s).map(PathChoice::Pinned)
    }

    /// The `prefer` argument for `ServingSystem::submit_opts`.
    pub fn prefer(&self) -> Option<PathKind> {
        match self {
            PathChoice::Auto => None,
            PathChoice::Pinned(p) => Some(*p),
        }
    }
}

/// Parsed `/v2/models/{name}/infer` body.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    /// Model name (from the route path, not the body).
    pub model: String,
    /// Payload seeds, one per batch item, in order.
    pub seeds: Vec<u64>,
    /// Optional client correlation id, echoed back verbatim.
    pub client_id: Option<String>,
    pub path: PathChoice,
    /// Relative deadline; None = no deadline.
    pub timeout_ms: Option<u64>,
    pub priority: Priority,
    /// Explicit model version (from the
    /// `/v2/models/{name}/versions/{v}/infer` route, never the body);
    /// None = the highest ready version.
    pub version: Option<u64>,
}

/// Parse a JSON number as an exact non-negative integer seed (shared with
/// the legacy `/infer` shim, which fixes the old silent `as u64` wrap of
/// negative seeds).
pub fn parse_seed(v: &Value) -> Result<u64, ApiError> {
    let n = v
        .as_f64()
        .map_err(|_| ApiError::bad_request("seed must be a number"))?;
    if !n.is_finite() || n.fract() != 0.0 {
        return Err(ApiError::bad_request(format!("seed must be an integer, got {n}")));
    }
    if n < 0.0 {
        return Err(ApiError::bad_request(format!(
            "seed must be non-negative, got {n}"
        )));
    }
    if n >= MAX_EXACT_INT {
        return Err(ApiError::bad_request(format!("seed {n} exceeds 2^53")));
    }
    Ok(n as u64)
}

/// Parse a JSON number as a non-negative integer (timeout_ms).
fn parse_u64(v: &Value, what: &str) -> Result<u64, ApiError> {
    let n = v
        .as_f64()
        .map_err(|_| ApiError::bad_request(format!("{what} must be a number")))?;
    if !n.is_finite() || n.fract() != 0.0 || n < 0.0 || n >= MAX_EXACT_INT {
        return Err(ApiError::bad_request(format!(
            "{what} must be a non-negative integer, got {n}"
        )));
    }
    Ok(n as u64)
}

impl InferRequest {
    /// Parse a v2 infer body. Accepted shapes:
    ///
    /// ```json
    /// {"inputs": [{"seed": 7}, {"seed": 9}],
    ///  "id": "client-42",
    ///  "parameters": {"path": "auto", "timeout_ms": 250, "priority": "high"}}
    /// ```
    ///
    /// plus the single-item shorthand `{"seed": 7, ...}` the legacy
    /// `/infer` shim also uses. Bare numbers are accepted inside
    /// `inputs` (`"inputs": [7, 9]`).
    pub fn from_json(model: &str, v: &Value) -> Result<InferRequest, ApiError> {
        let obj = v
            .as_obj()
            .map_err(|_| ApiError::bad_request("body must be a JSON object"))?;

        let mut seeds = Vec::new();
        if let Some(inputs) = obj.get("inputs") {
            let arr = inputs
                .as_arr()
                .map_err(|_| ApiError::bad_request("\"inputs\" must be an array"))?;
            if arr.is_empty() {
                return Err(ApiError::bad_request("\"inputs\" must not be empty"));
            }
            if arr.len() > MAX_BATCH_ITEMS {
                return Err(ApiError::bad_request(format!(
                    "batch of {} items exceeds the {MAX_BATCH_ITEMS}-item cap",
                    arr.len()
                )));
            }
            for item in arr {
                let seed_val = match item {
                    Value::Obj(_) => item
                        .get("seed")
                        .map_err(|_| ApiError::bad_request("each input needs a \"seed\""))?,
                    _ => item,
                };
                seeds.push(parse_seed(seed_val)?);
            }
        } else if let Some(seed) = obj.get("seed") {
            seeds.push(parse_seed(seed)?);
        } else {
            return Err(ApiError::bad_request("body needs \"inputs\" or \"seed\""));
        }

        let client_id = match obj.get("id") {
            Some(Value::Str(s)) => Some(s.clone()),
            Some(_) => return Err(ApiError::bad_request("\"id\" must be a string")),
            None => None,
        };

        // Parameters live in "parameters". Only "path" is also accepted
        // at the top level (legacy-shim parity) — timeout_ms/priority are
        // parameters-only, so no undocumented API surface is minted.
        let params = match obj.get("parameters") {
            Some(p) => Some(p.as_obj().map_err(|_| {
                ApiError::bad_request("\"parameters\" must be an object")
            })?),
            None => None,
        };
        let param = |key: &str| params.and_then(|p| p.get(key));

        let path = match param("path").or_else(|| obj.get("path")) {
            Some(Value::Str(s)) => PathChoice::parse(s)
                .ok_or_else(|| ApiError::bad_request(format!("unknown path {s:?}")))?,
            Some(_) => return Err(ApiError::bad_request("\"path\" must be a string")),
            None => PathChoice::Auto,
        };
        let timeout_ms = match param("timeout_ms") {
            Some(v) => Some(parse_u64(v, "timeout_ms")?),
            None => None,
        };
        let priority = match param("priority") {
            Some(Value::Str(s)) => Priority::parse(s)
                .ok_or_else(|| ApiError::bad_request(format!("unknown priority {s:?}")))?,
            Some(_) => return Err(ApiError::bad_request("\"priority\" must be a string")),
            None => Priority::Normal,
        };

        Ok(InferRequest {
            model: model.to_string(),
            seeds,
            client_id,
            path,
            timeout_ms,
            priority,
            version: None,
        })
    }
}

/// Server-assigned monotonic request id (never the payload seed — ids
/// from concurrent clients must not collide).
pub fn next_request_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// One item's serialised outcome inside a batch response. `bucket` is
/// the batch bucket the execution fused into — how clients observe
/// multi-item bodies coalescing. `bucket` alone is ambiguous for
/// non-executed answers (a cache answer reports 0, a coalesced
/// follower reports its leader's bucket), so `served` states who
/// actually produced the answer: `"model"` (an engine execution ran),
/// `"cache"` (admission skip answered from the response cache or
/// screener argmax), or `"coalesced"` (a concurrent duplicate shared
/// the in-flight leader's result).
pub fn item_json(seed: u64, r: &InferResult) -> Value {
    let mut fields = vec![
        ("seed", json::num(seed as f64)),
        ("predicted", json::num(r.predicted as f64)),
        ("confidence", json::num(r.confidence as f64)),
        ("entropy", json::num(r.entropy as f64)),
        ("latency_secs", json::num(r.latency_secs)),
        ("joules", json::num(r.joules)),
        ("path", json::s(r.path.as_str())),
        ("bucket", json::num(r.bucket as f64)),
        ("served", json::s(r.served.as_str())),
    ];
    if r.j.is_finite() && r.tau.is_finite() {
        fields.push(("j", json::num(r.j)));
        fields.push(("tau", json::num(r.tau)));
    }
    json::obj(fields)
}

/// The v2 infer response: per-item outputs in request order under one
/// server-assigned id.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub request_id: u64,
    pub model: String,
    pub client_id: Option<String>,
    pub outputs: Vec<Value>,
}

impl InferResponse {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("request_id", json::num(self.request_id as f64)),
            ("model_name", json::s(&self.model)),
            ("outputs", Value::Arr(self.outputs.clone())),
        ];
        if let Some(id) = &self.client_id {
            fields.push(("id", json::s(id)));
        }
        json::obj(fields)
    }

    pub fn to_response(&self) -> HttpResponse {
        HttpResponse::ok_json(self.to_json().to_json())
    }
}

/// One version's lifecycle row (`/v2/repository/index` and the
/// `versions` array of `/v2/models/{name}`): state, failure reason, and
/// the load stats the green-serving argument cares about (compile time
/// + weight bytes + estimated load energy — what a live swap avoids
/// re-paying versus a restart).
pub fn version_view_json(v: &VersionView) -> Value {
    let mut fields = vec![
        ("version", json::num(v.version as f64)),
        ("state", json::s(v.state.as_str())),
    ];
    if let ModelState::Failed { reason } = &v.state {
        fields.push(("reason", json::s(reason)));
    }
    if let Some(s) = &v.stats {
        fields.push((
            "load",
            json::obj(vec![
                ("seconds", json::num(s.load_secs)),
                ("weight_bytes", json::num(s.weight_bytes as f64)),
                ("est_joules", json::num(s.est_load_joules)),
            ]),
        ));
    }
    json::obj(fields)
}

/// Roll a model's per-version states up into the one-word summary
/// `GET /v2/models/{name}` reports as its top-level `state`. `READY`
/// wins (something serves), then `LOADING` (an async load is in flight
/// — poll again), then `UNLOADING`, then `FAILED`, else `UNLOADED`.
pub fn aggregate_state(views: &[VersionView]) -> &'static str {
    let any = |f: fn(&ModelState) -> bool| views.iter().any(|v| f(&v.state));
    if any(|s| matches!(s, ModelState::Ready)) {
        "READY"
    } else if any(|s| matches!(s, ModelState::Loading)) {
        "LOADING"
    } else if any(|s| matches!(s, ModelState::Unloading)) {
        "UNLOADING"
    } else if any(|s| matches!(s, ModelState::Failed { .. })) {
        "FAILED"
    } else {
        "UNLOADED"
    }
}

/// `/v2/models/{name}` metadata: per-version lifecycle state plus — when
/// a version is ready to serve — manifest + serving config + live queue
/// state (the batching decisions arXiv 2402.07585 calls the
/// green-serving levers, made inspectable).
pub fn model_metadata_json(
    name: &str,
    handle: Option<&VersionHandle>,
    views: &[VersionView],
    queue_capacity: usize,
) -> Value {
    let versions: Vec<Value> = views.iter().map(version_view_json).collect();
    let state = aggregate_state(views);
    let Some(h) = handle else {
        // Registered but nothing ready: lifecycle state only. `state`
        // distinguishes "still loading — poll again" from "failed" for
        // clients of the async lifecycle API.
        return json::obj(vec![
            ("name", json::s(name)),
            ("ready", Value::Bool(false)),
            ("state", json::s(state)),
            ("versions", Value::Arr(versions)),
        ]);
    };
    let m = h.manifest();
    let config = h.config();
    let buckets: Vec<Value> = m.batch_buckets.iter().map(|&b| json::num(b as f64)).collect();
    let platform = config
        .map(|c| c.platform.clone())
        .unwrap_or_else(|| "greenflow_pjrt".to_string());
    let max_batch = config.map(|c| c.max_batch_size).unwrap_or_else(|| m.max_bucket());
    let dynamic_batching = match config.and_then(|c| c.dynamic_batching.as_ref()) {
        Some(d) => json::obj(vec![
            (
                "preferred_batch_sizes",
                Value::Arr(d.preferred_batch_sizes.iter().map(|&b| json::num(b as f64)).collect()),
            ),
            ("max_queue_delay_us", json::num(d.max_queue_delay_us as f64)),
        ]),
        None => Value::Null,
    };
    let instances = config.map(|c| c.total_instances()).unwrap_or(1);
    json::obj(vec![
        ("name", json::s(name)),
        ("ready", Value::Bool(true)),
        ("state", json::s(state)),
        ("version", json::num(h.version() as f64)),
        ("versions", Value::Arr(versions)),
        ("platform", json::s(&platform)),
        ("family", json::s(&m.family)),
        ("classes", json::num(m.classes as f64)),
        (
            "input_kind",
            json::s(match m.input_kind {
                crate::runtime::InputKind::Tokens => "tokens",
                crate::runtime::InputKind::Dense => "dense",
            }),
        ),
        ("batch_buckets", Value::Arr(buckets)),
        ("max_batch_size", json::num(max_batch as f64)),
        ("dynamic_batching", dynamic_batching),
        ("instances", json::num(instances as f64)),
        ("batched_path", Value::Bool(h.has_batched())),
        (
            "replicas",
            json::obj(vec![
                ("ready", json::num(h.replica_count() as f64)),
                ("target", json::num(h.target_replicas() as f64)),
                ("in_flight", json::num(h.in_flight() as f64)),
            ]),
        ),
        (
            "queue",
            json::obj(vec![
                ("depth", json::num(h.queue_depth() as f64)),
                ("capacity", json::num(queue_capacity as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_map_to_http() {
        assert_eq!(ErrorCode::Backpressure.http_status(), 429);
        assert_eq!(ErrorCode::RateLimited.http_status(), 429);
        assert_eq!(ErrorCode::RetryBudgetExhausted.http_status(), 429);
        assert_eq!(ErrorCode::InvalidArgument.http_status(), 400);
        assert_eq!(ErrorCode::ModelNotFound.http_status(), 404);
        assert_eq!(ErrorCode::ModelUnavailable.http_status(), 503);
        assert_eq!(ErrorCode::DeadlineExceeded.http_status(), 504);
        assert_eq!(ErrorCode::PayloadTooLarge.http_status(), 413);
        assert_eq!(ErrorCode::BadRequest.as_str(), "BAD_REQUEST");
        assert_eq!(ErrorCode::ModelUnavailable.as_str(), "MODEL_UNAVAILABLE");
        assert_eq!(ErrorCode::RateLimited.as_str(), "RATE_LIMITED");
        assert_eq!(ErrorCode::RetryBudgetExhausted.as_str(), "RETRY_BUDGET_EXHAUSTED");
        assert_eq!(ErrorCode::InvalidArgument.as_str(), "INVALID_ARGUMENT");
    }

    #[test]
    fn runtime_errors_map_to_codes() {
        let e = ApiError::from_runtime(&RuntimeError::Backpressure("m".into()));
        assert_eq!(e.code, ErrorCode::Backpressure);
        let e = ApiError::from_runtime(&RuntimeError::UnknownModel("m".into()));
        assert_eq!(e.code, ErrorCode::ModelNotFound);
        let e = ApiError::from_runtime(&RuntimeError::DeadlineExceeded {
            elapsed_ms: 5,
            timeout_ms: 1,
        });
        assert_eq!(e.code, ErrorCode::DeadlineExceeded);
        let e = ApiError::from_runtime(&RuntimeError::Xla("boom".into()));
        assert_eq!(e.code, ErrorCode::Internal);
        // Lifecycle errors: unavailable = 503, misuse / bad config = 400.
        let e = ApiError::from_runtime(&RuntimeError::ModelUnavailable { model: "m".into() });
        assert_eq!(e.code, ErrorCode::ModelUnavailable);
        let e = ApiError::from_runtime(&RuntimeError::InvalidConfig {
            model: "m".into(),
            reason: "bad".into(),
        });
        assert_eq!(e.code, ErrorCode::BadRequest);
        let e = ApiError::from_runtime(&RuntimeError::Lifecycle {
            model: "m".into(),
            reason: "not loaded".into(),
        });
        assert_eq!(e.code, ErrorCode::BadRequest);
    }

    #[test]
    fn error_response_shape() {
        let resp = ApiError::new(ErrorCode::Backpressure, "queue full").to_response();
        assert_eq!(resp.status, 429);
        let v = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("error").unwrap().get("code").unwrap().as_str().unwrap(), "BACKPRESSURE");
        assert!(resp.extra_headers.is_empty(), "no hint attached, no header");
    }

    #[test]
    fn retry_after_renders_as_header() {
        let resp = ApiError::new(ErrorCode::RateLimited, "tenant over quota")
            .with_retry_after(0.037)
            .to_response();
        assert_eq!(resp.status, 429);
        // Sub-second waits round up: "Retry-After: 0" would teach
        // clients to hammer.
        assert_eq!(
            resp.extra_headers,
            vec![("Retry-After".to_string(), "1".to_string())]
        );
        let resp = ApiError::new(ErrorCode::Backpressure, "queue full")
            .with_retry_after(2.4)
            .to_response();
        assert_eq!(resp.extra_headers[0].1, "3", "ceil, not round");
    }

    #[test]
    fn parses_batch_body() {
        let v = json::parse(
            r#"{"inputs": [{"seed": 7}, {"seed": 9}, 11],
                "id": "c-1",
                "parameters": {"path": "batched", "timeout_ms": 250, "priority": "high"}}"#,
        )
        .unwrap();
        let r = InferRequest::from_json("distilbert_mini", &v).unwrap();
        assert_eq!(r.seeds, vec![7, 9, 11]);
        assert_eq!(r.client_id.as_deref(), Some("c-1"));
        assert_eq!(r.path, PathChoice::Pinned(PathKind::Batched));
        assert_eq!(r.timeout_ms, Some(250));
        assert_eq!(r.priority, Priority::High);
    }

    #[test]
    fn parses_single_item_shorthand() {
        let v = json::parse(r#"{"seed": 42, "path": "direct"}"#).unwrap();
        let r = InferRequest::from_json("m", &v).unwrap();
        assert_eq!(r.seeds, vec![42]);
        assert_eq!(r.path, PathChoice::Pinned(PathKind::Direct));
        assert_eq!(r.timeout_ms, None);
        assert_eq!(r.priority, Priority::Normal);
    }

    #[test]
    fn rejects_negative_and_fractional_seeds() {
        for body in [
            r#"{"seed": -3}"#,
            r#"{"seed": 1.5}"#,
            r#"{"seed": "x"}"#,
            r#"{"inputs": [{"seed": -1}]}"#,
            r#"{"inputs": [1e300]}"#,
        ] {
            let v = json::parse(body).unwrap();
            let e = InferRequest::from_json("m", &v).unwrap_err();
            assert_eq!(e.code, ErrorCode::BadRequest, "{body}");
        }
    }

    #[test]
    fn rejects_empty_oversized_and_malformed_batches() {
        let v = json::parse(r#"{"inputs": []}"#).unwrap();
        assert!(InferRequest::from_json("m", &v).is_err());

        let big: Vec<String> = (0..(MAX_BATCH_ITEMS + 1)).map(|i| i.to_string()).collect();
        let v = json::parse(&format!("{{\"inputs\": [{}]}}", big.join(","))).unwrap();
        assert!(InferRequest::from_json("m", &v).is_err());

        let v = json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(InferRequest::from_json("m", &v).is_err());

        let v = json::parse(r#"{"seed": 1, "parameters": {"priority": "urgent"}}"#).unwrap();
        assert!(InferRequest::from_json("m", &v).is_err());

        let v = json::parse(r#"{"seed": 1, "parameters": {"path": "cache"}}"#).unwrap();
        assert!(InferRequest::from_json("m", &v).is_err());
    }

    #[test]
    fn timeout_and_priority_are_parameters_only() {
        // Top-level "timeout_ms"/"priority" are not part of the protocol
        // and must be ignored, not honored.
        let v = json::parse(r#"{"seed": 1, "timeout_ms": 0, "priority": "low"}"#).unwrap();
        let r = InferRequest::from_json("m", &v).unwrap();
        assert_eq!(r.timeout_ms, None);
        assert_eq!(r.priority, Priority::Normal);

        // A non-object "parameters" is a 400, not silently dropped.
        let v = json::parse(r#"{"seed": 1, "parameters": 7}"#).unwrap();
        assert!(InferRequest::from_json("m", &v).is_err());
    }

    #[test]
    fn aggregate_state_rolls_up_versions() {
        let view = |state: ModelState| VersionView { version: 1, state, stats: None };
        assert_eq!(aggregate_state(&[]), "UNLOADED");
        assert_eq!(aggregate_state(&[view(ModelState::Unloaded)]), "UNLOADED");
        assert_eq!(
            aggregate_state(&[view(ModelState::Loading), view(ModelState::Unloaded)]),
            "LOADING"
        );
        // Something serving beats a sibling still loading.
        assert_eq!(
            aggregate_state(&[view(ModelState::Ready), view(ModelState::Loading)]),
            "READY"
        );
        assert_eq!(
            aggregate_state(&[view(ModelState::Failed { reason: "x".into() })]),
            "FAILED"
        );
        assert_eq!(
            aggregate_state(&[
                view(ModelState::Unloading),
                view(ModelState::Failed { reason: "x".into() })
            ]),
            "UNLOADING"
        );
    }

    #[test]
    fn request_ids_are_monotonic_and_unique() {
        let a = next_request_id();
        let b = next_request_id();
        assert!(b > a);
    }

    #[test]
    fn infer_response_serialises_outputs_in_order() {
        let resp = InferResponse {
            request_id: 7,
            model: "m".into(),
            client_id: Some("c".into()),
            outputs: vec![
                json::obj(vec![("seed", json::num(1.0))]),
                json::obj(vec![("seed", json::num(2.0))]),
            ],
        };
        let v = resp.to_json();
        assert_eq!(v.get("request_id").unwrap().as_i64().unwrap(), 7);
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), "c");
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].get("seed").unwrap().as_i64().unwrap(), 1);
        assert_eq!(outs[1].get("seed").unwrap().as_i64().unwrap(), 2);
    }
}
