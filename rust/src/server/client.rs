//! Minimal in-process HTTP/1.1 client with keep-alive.
//!
//! Exists so the CLI's `--serve-bench` round-trip mode and the
//! integration tests can drive the gateway over a real socket —
//! including connection reuse — without hand-rolling request strings
//! everywhere. One connection per client; requests are sequential.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::json::{self, Value};

/// A parsed HTTP response.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn body_str(&self) -> Result<&str, String> {
        std::str::from_utf8(&self.body).map_err(|e| e.to_string())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Value, String> {
        json::parse(self.body_str()?).map_err(|e| e.to_string())
    }

    /// Case-insensitive header lookup (names are lowercased at parse).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// Whether the server will keep the connection open.
    pub fn keep_alive(&self) -> bool {
        self.header("connection").map(|v| v.eq_ignore_ascii_case("keep-alive")).unwrap_or(false)
    }
}

/// A keep-alive HTTP/1.1 client over one TCP connection.
#[derive(Debug)]
pub struct HttpClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl HttpClient {
    /// Connect to a server (e.g. `Gateway::addr()`).
    pub fn connect(addr: SocketAddr) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HttpClient { stream, reader })
    }

    /// `GET path` on the shared connection.
    pub fn get(&mut self, path: &str) -> Result<ClientResponse, String> {
        self.request("GET", path, &[], None)
    }

    /// `POST path` with a JSON body on the shared connection.
    pub fn post_json(&mut self, path: &str, body: &str) -> Result<ClientResponse, String> {
        self.request(
            "POST",
            path,
            &[("Content-Type", "application/json")],
            Some(body.as_bytes()),
        )
    }

    /// Issue one request and block for its response. The connection is
    /// reused across calls (keep-alive) until the server closes it.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> Result<ClientResponse, String> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nHost: localhost\r\n");
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("Content-Length: {}\r\n\r\n", body.map_or(0, |b| b.len())));
        self.stream.write_all(head.as_bytes()).map_err(|e| e.to_string())?;
        if let Some(b) = body {
            self.stream.write_all(b).map_err(|e| e.to_string())?;
        }
        self.stream.flush().map_err(|e| e.to_string())?;
        self.read_response()
    }

    fn read_line(&mut self) -> Result<String, String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("connection closed by server".into());
        }
        Ok(line.trim_end().to_string())
    }

    fn read_response(&mut self) -> Result<ClientResponse, String> {
        // Status line: HTTP/1.1 <code> <reason...>
        let status_line = self.read_line()?;
        let mut parts = status_line.split_whitespace();
        let version = parts.next().ok_or("empty status line")?;
        if !version.starts_with("HTTP/1.") {
            return Err(format!("bad status line {status_line:?}"));
        }
        let status: u16 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format!("bad status in {status_line:?}"))?;

        let mut headers = BTreeMap::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }

        let len: usize = headers
            .get("content-length")
            .and_then(|v| v.parse().ok())
            .ok_or("response without Content-Length")?;
        let mut body = vec![0u8; len];
        if len > 0 {
            self.reader.read_exact(&mut body).map_err(|e| e.to_string())?;
        }
        Ok(ClientResponse { status, headers, body })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Serve `responses` verbatim on one accepted connection, reading one
    /// request (headers + Content-Length body) before each write.
    fn canned_server(responses: Vec<String>) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            for resp in responses {
                // Drain one request.
                let mut content_length = 0usize;
                loop {
                    let mut line = String::new();
                    if reader.read_line(&mut line).unwrap() == 0 {
                        return;
                    }
                    let line = line.trim_end();
                    if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
                        content_length = v.trim().parse().unwrap();
                    }
                    if line.is_empty() {
                        break;
                    }
                }
                let mut body = vec![0u8; content_length];
                if content_length > 0 {
                    reader.read_exact(&mut body).unwrap();
                }
                stream.write_all(resp.as_bytes()).unwrap();
            }
        });
        addr
    }

    fn resp(status: &str, keep_alive: bool, body: &str) -> String {
        format!(
            "HTTP/1.1 {status}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: {}\r\n\r\n{body}",
            body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )
    }

    #[test]
    fn get_parses_status_headers_and_body() {
        let addr = canned_server(vec![resp("200 OK", false, "{\"a\":1}")]);
        let mut c = HttpClient::connect(addr).unwrap();
        let r = c.get("/x").unwrap();
        assert_eq!(r.status, 200);
        assert!(!r.keep_alive());
        assert_eq!(r.json().unwrap().get("a").unwrap().as_i64().unwrap(), 1);
    }

    #[test]
    fn keep_alive_reuses_one_connection() {
        let addr = canned_server(vec![
            resp("200 OK", true, "{\"n\":1}"),
            resp("429 Too Many Requests", true, "{\"n\":2}"),
            resp("200 OK", false, "{\"n\":3}"),
        ]);
        let mut c = HttpClient::connect(addr).unwrap();
        for (expect_status, n) in [(200u16, 1i64), (429, 2), (200, 3)] {
            let r = c.post_json("/x", "{\"seed\": 1}").unwrap();
            assert_eq!(r.status, expect_status);
            assert_eq!(r.json().unwrap().get("n").unwrap().as_i64().unwrap(), n);
        }
        // Server sent Connection: close on the last response and stopped.
        assert!(c.get("/x").is_err());
    }

    #[test]
    fn server_vanishing_is_an_error_not_a_hang() {
        let addr = canned_server(vec![]);
        let mut c = HttpClient::connect(addr).unwrap();
        assert!(c.get("/x").is_err());
    }
}
