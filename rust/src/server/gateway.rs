//! The inference gateway: routes HTTP requests onto the serving system.
//!
//! Serves the **v2 protocol** (KServe/Triton-style, typed in
//! [`super::api`]) plus thin legacy shims:
//!
//! * `GET  /v2`                        — server metadata
//! * `GET  /v2/health/live|ready`      — liveness / readiness
//! * `GET  /v2/models`                 — model index
//! * `GET  /v2/models/{name}[/versions/{v}]` — metadata + per-version
//!   lifecycle state + live queue state
//! * `POST /v2/models/{name}[/versions/{v}]/infer` — single or batch
//!   inference with `timeout_ms` deadlines and `priority`
//! * `POST /v2/repository/index`       — repository-wide version states
//! * `POST /v2/repository/models/{name}/load|unload` — **async** model
//!   lifecycle control: `202 Accepted` with the work queued on the
//!   lifecycle executor (optional `{"parameters": {"version": N}}`
//!   body; `?wait=true` blocks for the old synchronous semantics)
//! * `GET  /v2/control/loops`          — control-plane introspection
//! * `GET  /v2/admission/stats`        — admission-controller stats
//! * `GET  /v2/tenants`                — per-tenant QoS accounting
//! * legacy: `POST /infer`, `GET /health`, `GET /models`, `GET /metrics`
//!
//! Every infer request first clears the per-tenant QoS layer
//! ([`crate::qos`]): `X-Tenant-Id` names the tenant (absent = the
//! `default` tenant), `X-Retry-Attempt` charges the retry budget, and
//! `X-Request-Deadline` (absolute unix millis) propagates a deadline
//! the pipeline enforces at every hand-off. Shed requests answer 429
//! (`RATE_LIMITED` with a GCRA-derived `Retry-After`, or
//! `RETRY_BUDGET_EXHAUSTED`); malformed QoS headers answer a typed 400
//! (`INVALID_ARGUMENT`) rather than being silently ignored.
//!
//! Connections are HTTP/1.1 **keep-alive**, served by the epoll
//! reactor in [`super::reactor`] on Linux (`docs/REACTOR.md`): a small
//! pool of event-loop threads owns every connection, parsed requests
//! hand off to a bounded worker pool, and per-connection buffers are
//! recycled across requests. Non-Linux builds fall back to the old
//! one-thread-per-connection loop ([`serve_connection`], which also
//! remains the reference implementation for unit tests). Either way a
//! connection lives until the peer closes, sends `Connection: close`,
//! or idles past [`KEEP_ALIVE_IDLE`]; live connections are capped at
//! `pool_size × `[`CONNECTIONS_PER_POOL_UNIT`], and over the cap new
//! connections get an immediate 503.

#[cfg(not(target_os = "linux"))]
use std::collections::HashMap;
use std::io::{BufReader, Read};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(not(target_os = "linux"))]
use std::sync::atomic::AtomicUsize;
use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(not(target_os = "linux"))]
use std::sync::Mutex;
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::json::{self, Value};
use crate::pipeline::system::{InferResult, ServingSystem, SubmitOptions};
use crate::qos::{self, QosVerdict};
use crate::router::PathKind;
use crate::telemetry::{MetricsRegistry, ShardedCounter};
use crate::util::Clock;
use crate::workload::stream::Request;

use super::api::{self, ApiError, ErrorCode, InferRequest, InferResponse, PathChoice};
use super::http::{HttpRequest, HttpResponse};

/// Idle keep-alive connections are closed after this long without a new
/// request.
pub const KEEP_ALIVE_IDLE: Duration = Duration::from_secs(5);

/// Hard cap on requests served per connection (rotation guard).
pub(crate) const MAX_REQUESTS_PER_CONNECTION: usize = 100_000;

/// Concurrent connections per unit of `pool_size`. On Linux the reactor
/// holds connections as slab entries, not threads, so the cap scales to
/// thousands; the thread-per-connection fallback keeps the old 16×.
#[cfg(target_os = "linux")]
pub const CONNECTIONS_PER_POOL_UNIT: usize = 512;
#[cfg(not(target_os = "linux"))]
pub const CONNECTIONS_PER_POOL_UNIT: usize = 16;

/// Pre-resolved sharded counters for the per-request hot path. Looking
/// a counter up by name takes the registry lock; incrementing through
/// these handles touches only a per-thread shard (see
/// `telemetry::sharded`).
pub(crate) struct HotCounters {
    pub(crate) requests: Arc<ShardedCounter>,
    pub(crate) keepalive_reuse: Arc<ShardedCounter>,
    pub(crate) infer_items: Arc<ShardedCounter>,
    pub(crate) backpressure: Arc<ShardedCounter>,
    pub(crate) deadline_exceeded: Arc<ShardedCounter>,
    pub(crate) model_unavailable: Arc<ShardedCounter>,
    pub(crate) rate_limited: Arc<ShardedCounter>,
    pub(crate) retry_budget: Arc<ShardedCounter>,
}

/// The gateway's hot-path counters, resolved once per process. Readers
/// (`/metrics`, `/v2/admission/stats`) still see them through the
/// registry's `counter_value`/`render_prometheus` fold.
pub(crate) fn hot() -> &'static HotCounters {
    static HOT: OnceLock<HotCounters> = OnceLock::new();
    HOT.get_or_init(|| {
        let reg = MetricsRegistry::global();
        HotCounters {
            requests: reg.sharded_counter("gf_http_requests_total"),
            keepalive_reuse: reg.sharded_counter("gf_http_keepalive_reuse_total"),
            infer_items: reg.sharded_counter("gf_http_infer_total"),
            backpressure: reg.sharded_counter("gf_http_backpressure_total"),
            deadline_exceeded: reg.sharded_counter("gf_http_deadline_exceeded_total"),
            model_unavailable: reg.sharded_counter("gf_http_model_unavailable_total"),
            rate_limited: reg.sharded_counter("gf_http_rate_limited_total"),
            retry_budget: reg.sharded_counter("gf_http_retry_budget_total"),
        }
    })
}

/// Live-connection registry for the thread-per-connection fallback:
/// per-connection socket handles (so `shutdown` can force blocked reads
/// to return) plus the live count the acceptor enforces the cap
/// against.
#[cfg(not(target_os = "linux"))]
#[derive(Default)]
struct ConnTable {
    conns: Mutex<HashMap<u64, TcpStream>>,
    active: AtomicUsize,
}

/// Deregisters a connection when its thread exits, however it exits
/// (panic included).
#[cfg(not(target_os = "linux"))]
struct ConnGuard {
    table: Arc<ConnTable>,
    id: u64,
}

#[cfg(not(target_os = "linux"))]
impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.table.conns.lock().unwrap().remove(&self.id);
        self.table.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The platform-specific connection engine behind a [`Gateway`].
enum Backend {
    /// Linux: epoll reactor + bounded worker pool.
    #[cfg(target_os = "linux")]
    Reactor(super::reactor::ReactorServer),
    /// Fallback: one thread per live connection.
    #[cfg(not(target_os = "linux"))]
    Threads(Arc<ConnTable>),
}

/// A running HTTP gateway bound to a local port.
pub struct Gateway {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    backend: Backend,
}

impl Gateway {
    /// Bind `127.0.0.1:port` (port 0 = ephemeral) and serve `system`.
    /// `pool_size` sizes the worker pool (and scales the
    /// concurrent-connection cap, `pool_size ×
    /// `[`CONNECTIONS_PER_POOL_UNIT`]); over the cap new connections get
    /// an immediate 503 rather than letting long-lived clients starve
    /// everyone else.
    pub fn start(
        system: Arc<ServingSystem>,
        port: u16,
        pool_size: usize,
    ) -> std::io::Result<Gateway> {
        Gateway::start_with_handler(
            Arc::new(move |req: &HttpRequest| dispatch(req, &system)),
            port,
            pool_size,
        )
    }

    /// [`Gateway::start`] with an arbitrary handler instead of a
    /// [`ServingSystem`] — the full network stack (acceptor, reactor,
    /// worker pool, keep-alive, caps) around any request function.
    /// Tests use this to drive connection-level behaviour without
    /// artifacts.
    pub fn start_with_handler(
        handler: Arc<dyn Fn(&HttpRequest) -> HttpResponse + Send + Sync>,
        port: u16,
        pool_size: usize,
    ) -> std::io::Result<Gateway> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let max_connections = pool_size.max(1) * CONNECTIONS_PER_POOL_UNIT;

        #[cfg(target_os = "linux")]
        {
            // Reactors scale with the worker pool but stay few: the
            // event loops are I/O-bound, and every extra one is another
            // epoll instance to wake. Workers absorb the blocking work.
            let reactors = ((pool_size.max(1) + 3) / 4).min(4);
            let server =
                super::reactor::ReactorServer::start(handler, reactors, pool_size.max(1))?;
            let sink = server.sink();

            // Blocking accept; shutdown() wakes it with a self-connect.
            // The acceptor only hands sockets off — never parses — so
            // accept throughput is not gated on request handling.
            let acceptor = std::thread::Builder::new()
                .name("gf-gateway".to_string())
                .spawn(move || loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if stop2.load(Ordering::SeqCst) {
                                break; // the shutdown self-connect
                            }
                            if sink.active() >= max_connections {
                                MetricsRegistry::global()
                                    .counter("gf_gateway_conn_limit_total")
                                    .inc();
                                let _ = HttpResponse::error(503, "connection limit reached")
                                    .write_to_with(&stream, false);
                                continue; // drop closes it
                            }
                            sink.register(stream);
                        }
                        Err(_) => {
                            MetricsRegistry::global()
                                .counter("gf_gateway_accept_errors")
                                .inc();
                            if stop2.load(Ordering::SeqCst) {
                                break;
                            }
                            // Transient accept errors (EMFILE, aborted
                            // handshakes) must not spin the core.
                            std::thread::sleep(Duration::from_millis(20));
                        }
                    }
                })
                .expect("spawn gateway");

            Ok(Gateway {
                addr,
                stop,
                acceptor: Some(acceptor),
                backend: Backend::Reactor(server),
            })
        }
        #[cfg(not(target_os = "linux"))]
        {
            let table = Arc::new(ConnTable::default());
            let table2 = table.clone();
            let acceptor = std::thread::Builder::new()
                .name("gf-gateway".to_string())
                .spawn(move || {
                    let mut next_conn_id = 0u64;
                    loop {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                if stop2.load(Ordering::SeqCst) {
                                    break; // the shutdown self-connect
                                }
                                if table2.active.load(Ordering::SeqCst) >= max_connections {
                                    MetricsRegistry::global()
                                        .counter("gf_gateway_conn_limit_total")
                                        .inc();
                                    let _ =
                                        HttpResponse::error(503, "connection limit reached")
                                            .write_to_with(&stream, false);
                                    continue; // drop closes it
                                }
                                let id = next_conn_id;
                                next_conn_id += 1;
                                table2.active.fetch_add(1, Ordering::SeqCst);
                                if let Ok(clone) = stream.try_clone() {
                                    table2.conns.lock().unwrap().insert(id, clone);
                                }
                                let guard = ConnGuard { table: table2.clone(), id };
                                let handler = handler.clone();
                                // If the spawn fails the closure (and
                                // guard) is dropped with the error,
                                // undoing the count.
                                let _ = std::thread::Builder::new()
                                    .name("gf-http-conn".to_string())
                                    .spawn(move || {
                                        let _guard = guard;
                                        serve_connection(stream, |req| handler(req));
                                    });
                            }
                            Err(_) => {
                                MetricsRegistry::global()
                                    .counter("gf_gateway_accept_errors")
                                    .inc();
                                if stop2.load(Ordering::SeqCst) {
                                    break;
                                }
                                std::thread::sleep(Duration::from_millis(20));
                            }
                        }
                    }
                })
                .expect("spawn gateway");

            Ok(Gateway {
                addr,
                stop,
                acceptor: Some(acceptor),
                backend: Backend::Threads(table),
            })
        }
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, then quiesce: idle connections close at once,
    /// in-flight requests finish (bounded), so callers can assume no
    /// request is still being served afterwards.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept so the acceptor observes `stop`.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Reactor(server) => server.shutdown(),
            #[cfg(not(target_os = "linux"))]
            Backend::Threads(table) => {
                for conn in table.conns.lock().unwrap().values() {
                    let _ = conn.shutdown(Shutdown::Both);
                }
                let deadline = Instant::now() + Duration::from_secs(2);
                while table.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one connection with HTTP/1.1 keep-alive: parse → handle → write,
/// looping until close. Generic over the handler so tests (and future
/// servers) can drive the connection loop without a `ServingSystem`.
pub fn serve_connection<H>(mut stream: TcpStream, mut handler: H)
where
    H: FnMut(&HttpRequest) -> HttpResponse,
{
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(KEEP_ALIVE_IDLE));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let counters = hot();
    for served in 0..MAX_REQUESTS_PER_CONNECTION {
        match HttpRequest::read_from(&mut reader) {
            Ok(req) => {
                counters.requests.inc();
                if served > 0 {
                    counters.keepalive_reuse.inc();
                }
                // Only methods we answer with deterministic framing stay
                // keep-alive. A HEAD client must not read a body (RFC
                // 9110), so our bodied 405 would desync every later
                // exchange on the socket — answer it, then close.
                let keep = req.keep_alive()
                    && served + 1 < MAX_REQUESTS_PER_CONNECTION
                    && matches!(req.method.as_str(), "GET" | "POST");
                let resp = handler(&req);
                if resp.write_to_with(&mut stream, keep).is_err() || !keep {
                    break;
                }
            }
            Err(e) => {
                // Clean close (or idle timeout) gets no response; parse
                // failures get their status (400/413/417/431) and a
                // close (to_response is None only for ConnectionClosed).
                if let Some(resp) = e.to_response() {
                    let _ = resp.write_to_with(&mut stream, false);
                    // Drain what the peer is still sending (bounded)
                    // before closing: a close with unread bytes queued
                    // RSTs the socket, which can discard the error
                    // response we just wrote (a 413 mid-upload would
                    // read as "connection reset", not a clean status).
                    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
                    let _ = stream.shutdown(Shutdown::Write);
                    let mut sink = [0u8; 8192];
                    let t0 = Instant::now();
                    while t0.elapsed() < Duration::from_millis(750) {
                        match reader.read(&mut sink) {
                            Ok(0) | Err(_) => break,
                            Ok(_) => {}
                        }
                    }
                }
                break;
            }
        }
    }
}

/// Route one parsed request (the handler behind every connection).
pub fn dispatch(req: &HttpRequest, system: &ServingSystem) -> HttpResponse {
    let resp = route(req, system);
    // Echo the client's correlation id onto every response.
    match req.header("x-request-id") {
        Some(id) => resp.with_header("X-Request-Id", id),
        None => resp,
    }
}

fn route(req: &HttpRequest, system: &ServingSystem) -> HttpResponse {
    // Routing matches the path with its query string split off
    // (`/load?wait=true` routes like `/load`).
    let segments: Vec<&str> =
        req.path_only().split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segments.as_slice()) {
        // ---------------------------------------------------------- v2
        ("GET", ["v2"]) => HttpResponse::ok_json(
            json::obj(vec![
                ("name", json::s("greenflow")),
                ("version", json::s(crate::VERSION)),
                (
                    "extensions",
                    Value::Arr(vec![
                        json::s("batch_infer"),
                        json::s("deadlines"),
                        json::s("priority"),
                        json::s("control_introspection"),
                    ]),
                ),
            ])
            .to_json(),
        ),
        ("GET", ["v2", "health", "live"]) => {
            HttpResponse::ok_json(json::obj(vec![("live", Value::Bool(true))]).to_json())
        }
        ("GET", ["v2", "health", "ready"]) => {
            // Ready = at least one model has a Ready version to serve.
            let ready = system.ready_models();
            HttpResponse::ok_json(
                json::obj(vec![
                    ("ready", Value::Bool(ready > 0)),
                    ("models", json::num(ready as f64)),
                ])
                .to_json(),
            )
        }
        ("GET", ["v2", "models"]) => {
            let names: Vec<Value> =
                system.model_names().into_iter().map(Value::Str).collect();
            HttpResponse::ok_json(json::obj(vec![("models", Value::Arr(names))]).to_json())
        }
        ("GET", ["v2", "models", name]) => model_metadata(name, None, system),
        ("GET", ["v2", "models", name, "versions", v]) => match parse_version(v) {
            Ok(ver) => model_metadata(name, Some(ver), system),
            Err(e) => e.to_response(),
        },
        ("POST", ["v2", "models", name, "infer"]) => match v2_infer(name, None, req, system) {
            Ok(resp) => resp,
            Err(e) => e.to_response(),
        },
        ("POST", ["v2", "models", name, "versions", v, "infer"]) => {
            match parse_version(v).and_then(|ver| v2_infer(name, Some(ver), req, system)) {
                Ok(resp) => resp,
                Err(e) => e.to_response(),
            }
        }
        ("GET" | "POST", ["v2", "repository", "index"]) => repository_index(system),
        ("POST", ["v2", "repository", "models", name, op @ ("load" | "unload")]) => {
            match repository_control(name, op, req, system) {
                Ok(resp) => resp,
                Err(e) => e.to_response(),
            }
        }
        ("GET", ["v2", "control", "loops"]) => control_loops(system),
        ("GET", ["v2", "admission", "stats"]) => admission_stats(system),
        ("GET", ["v2", "tenants"]) => tenant_stats(system),

        // ------------------------------------------------------ legacy
        ("GET", ["health"]) => HttpResponse::ok_json(
            json::obj(vec![
                ("status", json::s("ok")),
                ("version", json::s(crate::VERSION)),
            ])
            .to_json(),
        ),
        ("GET", ["metrics"]) => {
            HttpResponse::ok_text(MetricsRegistry::global().render_prometheus())
        }
        ("GET", ["models"]) => {
            let names = system.model_names().into_iter().map(Value::Str).collect();
            HttpResponse::ok_json(Value::Arr(names).to_json())
        }
        ("POST", ["infer"]) => match legacy_infer(req, system) {
            Ok(resp) => resp,
            Err(e) => e.to_response(),
        },

        ("GET", _) | ("POST", _) => {
            ApiError::new(ErrorCode::NotFound, format!("no route {}", req.path)).to_response()
        }
        _ => ApiError::new(
            ErrorCode::Unsupported,
            format!("method {} not allowed", req.method),
        )
        .to_response(),
    }
}

/// Per-request QoS context parsed from the gateway headers
/// ([`qos::TENANT_HEADER`], [`qos::RETRY_HEADER`],
/// [`qos::DEADLINE_HEADER`]).
struct QosContext {
    tenant: String,
    retry_attempt: u32,
    deadline_unix_ms: Option<u64>,
}

/// Parse the QoS headers off an infer request. Malformed values are
/// typed 400s (`INVALID_ARGUMENT`), never silently dropped: a client
/// that *tried* to set a deadline must not run without one.
fn parse_qos_headers(req: &HttpRequest) -> Result<QosContext, ApiError> {
    let tenant = match req.header(qos::TENANT_HEADER) {
        Some(v) => {
            qos::validate_tenant_id(v).map_err(ApiError::invalid_argument)?;
            v.to_string()
        }
        None => qos::DEFAULT_TENANT.to_string(),
    };
    let retry_attempt = match req.header(qos::RETRY_HEADER) {
        Some(v) => qos::parse_retry_attempt(v).map_err(ApiError::invalid_argument)?,
        None => 0,
    };
    let deadline_unix_ms = match req.header(qos::DEADLINE_HEADER) {
        Some(v) => Some(qos::parse_deadline_unix_ms(v).map_err(ApiError::invalid_argument)?),
        None => None,
    };
    Ok(QosContext { tenant, retry_attempt, deadline_unix_ms })
}

/// Convert the absolute unix-millis deadline into the serving clock's
/// domain: the serving clock's origin is process-local, so only the
/// *remaining* time transfers between domains. An already-expired
/// deadline maps to `now`, which the pipeline sheds at its first
/// checkpoint (crediting the avoided energy).
fn deadline_to_clock(now: f64, deadline_unix_ms: u64) -> f64 {
    let unix_now_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);
    now + deadline_unix_ms.saturating_sub(unix_now_ms) as f64 / 1e3
}

/// `Retry-After` hint for a full queue: roughly the time to drain the
/// queue at the observed throughput. With no recent traffic to
/// estimate from, fall back to one second.
fn backpressure_retry_after(system: &ServingSystem) -> f64 {
    let snap = system.metrics().snapshot();
    if snap.qps.is_finite() && snap.qps > 0.0 {
        (system.queue_capacity() as f64 / snap.qps).clamp(1.0, 30.0)
    } else {
        1.0
    }
}

/// Run a typed infer request through the serving system as one batch:
/// the whole body goes down [`ServingSystem::submit_batch`], which
/// coalesces multi-item bodies into shared batcher buckets (admission
/// still runs per item) and keeps the all-or-error contract — the
/// first failure aborts the batch and becomes the response status.
///
/// Before anything touches the engine the request clears the
/// per-tenant QoS gates ([`crate::qos`]): the GCRA rate limiter and —
/// when `X-Retry-Attempt` marks it as a retry — the retry budget.
fn run_infer(
    ir: &InferRequest,
    qctx: &QosContext,
    system: &ServingSystem,
) -> Result<(u64, Vec<(u64, InferResult)>), ApiError> {
    // Model existence first: MODEL_NOT_FOUND beats any submit error.
    if !system.registry().has_model(&ir.model) {
        return Err(ApiError::new(
            ErrorCode::ModelNotFound,
            format!("unknown model {:?}", ir.model),
        ));
    }
    let reg = MetricsRegistry::global();
    let request_id = api::next_request_id();
    let now = system.clock().now();
    match system.qos().decide(&qctx.tenant, ir.seeds.len() as u32, qctx.retry_attempt, now) {
        QosVerdict::Admit => {}
        QosVerdict::RateLimited { retry_after_secs } => {
            hot().rate_limited.inc();
            return Err(ApiError::new(
                ErrorCode::RateLimited,
                format!("tenant {:?} is over its rate quota", qctx.tenant),
            )
            .with_retry_after(retry_after_secs));
        }
        QosVerdict::RetryBudgetExhausted => {
            hot().retry_budget.inc();
            return Err(ApiError::new(
                ErrorCode::RetryBudgetExhausted,
                format!(
                    "tenant {:?} has exhausted its retry budget; shed before admission",
                    qctx.tenant
                ),
            ));
        }
    }
    // One deadline for the whole batch: it bounds the client's wait, not
    // each item's share of it. The header deadline min-combines with the
    // body's `timeout_ms` — whichever expires first wins.
    let mut opts = match ir.timeout_ms {
        Some(ms) => SubmitOptions {
            version: ir.version,
            ..SubmitOptions::with_timeout(now, ms, ir.priority)
        },
        None => SubmitOptions {
            priority: ir.priority,
            version: ir.version,
            ..SubmitOptions::default()
        },
    };
    if let Some(dl_ms) = qctx.deadline_unix_ms {
        let abs = deadline_to_clock(now, dl_ms);
        opts.deadline = Some(opts.deadline.map_or(abs, |t| t.min(abs)));
    }
    hot().infer_items.add(ir.seeds.len() as u64);
    let requests: Vec<Request> = ir
        .seeds
        .iter()
        .map(|&seed| {
            Request::external(api::next_request_id(), ir.model.clone(), seed, now)
        })
        .collect();
    match system.submit_batch(&requests, ir.path.prefer(), &opts) {
        Ok(results) => {
            if let Some(last) = results.last() {
                reg.gauge("gf_last_latency_secs").set(last.latency_secs);
            }
            system.qos().record_success(
                &qctx.tenant,
                ir.seeds.len() as u64,
                system.clock().now(),
            );
            Ok((request_id, ir.seeds.iter().copied().zip(results).collect()))
        }
        Err(e) => {
            let mut api_err = ApiError::from_runtime(&e);
            match api_err.code {
                ErrorCode::Backpressure => {
                    hot().backpressure.inc();
                    // A 429 without a hint just invites an immediate
                    // retry; tell the client when a slot is likely free.
                    api_err = api_err.with_retry_after(backpressure_retry_after(system));
                }
                ErrorCode::DeadlineExceeded => hot().deadline_exceeded.inc(),
                ErrorCode::ModelUnavailable => hot().model_unavailable.inc(),
                _ => {}
            }
            Err(api_err)
        }
    }
}

fn v2_infer(
    model: &str,
    version: Option<u64>,
    req: &HttpRequest,
    system: &ServingSystem,
) -> Result<HttpResponse, ApiError> {
    let qctx = parse_qos_headers(req)?;
    let body = req.body_str().map_err(ApiError::bad_request)?;
    let v = json::parse(body).map_err(|e| ApiError::bad_request(e.to_string()))?;
    let mut ir = InferRequest::from_json(model, &v)?;
    ir.version = version;
    let (request_id, results) = run_infer(&ir, &qctx, system)?;
    let outputs = results.iter().map(|(seed, r)| api::item_json(*seed, r)).collect();
    Ok(InferResponse {
        request_id,
        model: ir.model,
        client_id: ir.client_id,
        outputs,
    }
    .to_response())
}

/// Parse a `{v}` route segment as a version number.
fn parse_version(v: &str) -> Result<u64, ApiError> {
    v.parse::<u64>().map_err(|_| {
        ApiError::bad_request(format!("version must be a positive integer, got {v:?}"))
    })
}

/// `GET /v2/models/{name}[/versions/{v}]`: per-version lifecycle state,
/// plus full manifest/config metadata when the requested (or default)
/// version is ready.
fn model_metadata(name: &str, version: Option<u64>, system: &ServingSystem) -> HttpResponse {
    let views = match system.registry().views(name) {
        Ok(v) => v,
        Err(e) => return ApiError::from_runtime(&e).to_response(),
    };
    let views: Vec<_> = match version {
        Some(v) => views.into_iter().filter(|x| x.version == v).collect(),
        None => views,
    };
    if views.is_empty() {
        return ApiError::new(
            ErrorCode::NotFound,
            format!("model {name:?} has no version {}", version.unwrap_or_default()),
        )
        .to_response();
    }
    let handle = system.version_handle(name, version);
    HttpResponse::ok_json(
        api::model_metadata_json(name, handle.as_deref(), &views, system.queue_capacity())
            .to_json(),
    )
}

/// `POST /v2/repository/index`: every registered model with per-version
/// lifecycle state and load stats (Triton's repository-index API), the
/// model-level state rollup, the aggregate ready-replica count, and —
/// when a version sits in `Failed` — its reason at the model level, so
/// an operator sweeping the index sees the failure without expanding
/// every version array.
fn repository_index(system: &ServingSystem) -> HttpResponse {
    let models: Vec<Value> = system
        .registry()
        .index()
        .iter()
        .map(|(name, views)| {
            let mut fields = vec![
                ("name", json::s(name)),
                ("state", json::s(api::aggregate_state(views))),
                // Ready replicas summed over this model's serving
                // versions (0 while unloaded or scaled to zero).
                (
                    "replicas",
                    json::num(
                        views
                            .iter()
                            .filter_map(|v| system.replica_counts(name, Some(v.version)))
                            .map(|(ready, _, _)| ready)
                            .sum::<usize>() as f64,
                    ),
                ),
            ];
            if let Some(reason) = views.iter().find_map(|v| match &v.state {
                crate::runtime::registry::ModelState::Failed { reason } => Some(reason.clone()),
                _ => None,
            }) {
                fields.push(("failed_reason", json::s(&reason)));
            }
            fields.push((
                "versions",
                Value::Arr(views.iter().map(api::version_view_json).collect()),
            ));
            json::obj(fields)
        })
        .collect();
    HttpResponse::ok_json(
        json::obj(vec![
            ("models", Value::Arr(models)),
            // Executor visibility for async-lifecycle clients: how many
            // accepted jobs are still waiting for a worker.
            (
                "lifecycle",
                json::obj(vec![(
                    "queue_depth",
                    json::num(system.lifecycle_queue_depth() as f64),
                )]),
            ),
        ])
        .to_json(),
    )
}

/// `POST /v2/repository/models/{name}/load|unload` with an optional
/// `{"parameters": {"version": N}}` body (no body / `{}` = the model's
/// version policy on load, every ready version on unload).
///
/// **Async by default**: load enqueues the engine spawn on the
/// lifecycle executor and answers `202 Accepted` with the versions now
/// `LOADING` (poll `/v2/repository/index` or `GET /v2/models/{name}`);
/// unload swaps the versions out immediately (new requests 503) and
/// answers `202` while the drain runs in the background — except when
/// it only cancelled still-queued loads, which completes inline (`200`).
/// `?wait=true` restores blocking semantics (CLI `--wait`, tests).
/// Validation failures are synchronous either way: 400/404/429 with a
/// typed code, never a dangling accepted job.
fn repository_control(
    name: &str,
    op: &str,
    req: &HttpRequest,
    system: &ServingSystem,
) -> Result<HttpResponse, ApiError> {
    let version = if req.body.is_empty() {
        None
    } else {
        let body = req.body_str().map_err(ApiError::bad_request)?;
        let v = json::parse(body).map_err(|e| ApiError::bad_request(e.to_string()))?;
        let obj = v
            .as_obj()
            .map_err(|_| ApiError::bad_request("body must be a JSON object"))?;
        match obj.get("parameters") {
            Some(p) => {
                let params = p
                    .as_obj()
                    .map_err(|_| ApiError::bad_request("\"parameters\" must be an object"))?;
                match params.get("version") {
                    Some(v) => Some(api::parse_seed(v).map_err(|_| {
                        ApiError::bad_request("version must be a non-negative integer")
                    })?),
                    None => None,
                }
            }
            None => None,
        }
    };
    let versions_json = |versions: &[u64]| {
        Value::Arr(versions.iter().map(|&v| json::num(v as f64)).collect())
    };
    let wait = req.query_flag("wait");
    if wait {
        // Blocking semantics: the response reports the terminal outcome.
        if op == "load" {
            let versions =
                system.load_model(name, version).map_err(|e| ApiError::from_runtime(&e))?;
            return Ok(HttpResponse::ok_json(
                json::obj(vec![
                    ("model", json::s(name)),
                    ("loaded", versions_json(&versions)),
                ])
                .to_json(),
            ));
        }
        // `unloaded` = versions that actually drained; a cancelled
        // still-queued load never served and is reported separately.
        let ticket = system
            .unload_model_wait(name, version)
            .map_err(|e| ApiError::from_runtime(&e))?;
        let mut fields = vec![
            ("model", json::s(name)),
            ("unloaded", versions_json(&ticket.unloading)),
        ];
        if !ticket.cancelled.is_empty() {
            fields.push(("cancelled", versions_json(&ticket.cancelled)));
        }
        return Ok(HttpResponse::ok_json(json::obj(fields).to_json()));
    }
    if op == "load" {
        let versions = system
            .load_model_async(name, version)
            .map_err(|e| ApiError::from_runtime(&e))?;
        // Everything targeted was already Ready: nothing was enqueued,
        // so there is nothing to "accept" — report it done (200), not
        // LOADING.
        let (status, state) =
            if versions.is_empty() { (200, "READY") } else { (202, "LOADING") };
        Ok(HttpResponse::json(
            status,
            json::obj(vec![
                ("model", json::s(name)),
                ("state", json::s(state)),
                ("loading", versions_json(&versions)),
            ])
            .to_json(),
        ))
    } else {
        let ticket = system
            .unload_model_async(name, version)
            .map_err(|e| ApiError::from_runtime(&e))?;
        let mut fields = vec![
            ("model", json::s(name)),
            ("unloading", versions_json(&ticket.unloading)),
        ];
        if !ticket.cancelled.is_empty() {
            fields.push(("cancelled", versions_json(&ticket.cancelled)));
        }
        // A pure cancellation is already complete — nothing left to
        // accept.
        let status = if ticket.unloading.is_empty() { 200 } else { 202 };
        Ok(HttpResponse::json(status, json::obj(fields).to_json()))
    }
}

/// Legacy `POST /infer` shim: `{"model": ..., "seed": N, "path": ...}` →
/// one-item v2 infer, re-serialised in the old flat shape. Unknown path
/// strings still mean "direct" (historic leniency); negative or
/// fractional seeds are now 400s instead of silently wrapping.
fn legacy_infer(req: &HttpRequest, system: &ServingSystem) -> Result<HttpResponse, ApiError> {
    let qctx = parse_qos_headers(req)?;
    let body = req.body_str().map_err(ApiError::bad_request)?;
    let v = json::parse(body).map_err(|e| ApiError::bad_request(e.to_string()))?;
    let model = v
        .get("model")
        .ok()
        .and_then(|m| m.as_str().ok())
        .ok_or_else(|| ApiError::bad_request("body needs a \"model\" string"))?
        .to_string();
    let seed = api::parse_seed(
        v.get("seed").map_err(|_| ApiError::bad_request("body needs a \"seed\""))?,
    )?;
    let path = match v.opt("path").ok().flatten().and_then(|p| p.as_str().ok()) {
        Some("batched") => PathChoice::Pinned(PathKind::Batched),
        Some("auto") => PathChoice::Auto,
        _ => PathChoice::Pinned(PathKind::Direct),
    };
    let ir = InferRequest {
        model,
        seeds: vec![seed],
        client_id: None,
        path,
        timeout_ms: None,
        priority: Default::default(),
        version: None,
    };
    let (request_id, results) = run_infer(&ir, &qctx, system)?;
    let (_, r) = &results[0];
    Ok(HttpResponse::ok_json(
        json::obj(vec![
            ("request_id", json::num(request_id as f64)),
            ("predicted", json::num(r.predicted as f64)),
            ("confidence", json::num(r.confidence as f64)),
            ("entropy", json::num(r.entropy as f64)),
            ("latency_secs", json::num(r.latency_secs)),
            ("joules", json::num(r.joules)),
            ("path", json::s(r.path.as_str())),
        ])
        .to_json(),
    ))
}

/// Zero out non-finite values (NaN/∞ are not JSON).
fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// `GET /v2/control/loops`: the PR-1 control plane over HTTP — every
/// loop's law + current output, router state, and the windowed-metrics
/// snapshot the loops observe.
fn control_loops(system: &ServingSystem) -> HttpResponse {
    let loops: Vec<Value> = system
        .control_loop_states()
        .iter()
        .map(|s| {
            json::obj(vec![
                ("name", json::s(&s.name)),
                ("law", json::s(&s.law)),
                ("output", json::num(finite(s.output))),
            ])
        })
        .collect();
    let snap = system.metrics().snapshot();
    let threshold = system.router_qps_threshold();
    let router = json::obj(vec![
        ("recent_qps", json::num(finite(system.router_qps()))),
        (
            "qps_threshold",
            if threshold.is_finite() { json::num(threshold) } else { Value::Null },
        ),
    ]);
    let window = json::obj(vec![
        ("qps", json::num(finite(snap.qps))),
        ("p50_latency", json::num(finite(snap.p50_latency))),
        ("p95_latency", json::num(finite(snap.p95_latency))),
        ("p95_direct", json::num(finite(snap.p95_direct))),
        ("p95_batched", json::num(finite(snap.p95_batched))),
        ("watts", json::num(finite(snap.watts))),
        ("events", json::num(snap.events as f64)),
    ]);
    HttpResponse::ok_json(
        json::obj(vec![
            ("running", Value::Bool(system.control_plane_running())),
            ("loops", Value::Arr(loops)),
            ("router", router),
            ("window", window),
        ])
        .to_json(),
    )
}

/// `GET /v2/tenants`: the QoS layer's per-tenant accounting — the live
/// quota scale, each tenant's effective (possibly scaled-down) rate,
/// and its admit/shed counts.
fn tenant_stats(system: &ServingSystem) -> HttpResponse {
    let qos = system.qos();
    let tenants: Vec<Value> = qos
        .tenants()
        .iter()
        .map(|t| {
            json::obj(vec![
                ("name", json::s(&t.name)),
                ("base_rate_rps", json::num(t.base_rate_rps as f64)),
                ("rate_rps", json::num(t.rate_rps as f64)),
                ("burst", json::num(t.burst as f64)),
                ("admitted", json::num(t.admitted as f64)),
                ("shed_rate_limited", json::num(t.shed_rate_limited as f64)),
                ("shed_retry_budget", json::num(t.shed_retry_budget as f64)),
                ("successes", json::num(t.successes as f64)),
                ("retries_admitted", json::num(t.retries_admitted as f64)),
            ])
        })
        .collect();
    HttpResponse::ok_json(
        json::obj(vec![
            ("quota_scale", json::num(finite(qos.quota_scale()))),
            ("tenants", Value::Arr(tenants)),
        ])
        .to_json(),
    )
}

/// `GET /v2/admission/stats`: the closed-loop controller's counters,
/// plus the gateway's own refusal counters (typed view of the same
/// series `/metrics` exposes; `counter_value` reads without minting
/// zero-valued series).
fn admission_stats(system: &ServingSystem) -> HttpResponse {
    let reg = MetricsRegistry::global();
    let count = |name: &str| json::num(reg.counter_value(name).unwrap_or(0) as f64);
    // "items", not "requests": one batch body bumps the counter once
    // per input item.
    let gateway = json::obj(vec![
        ("infer_items", count("gf_http_infer_total")),
        ("backpressure_responses", count("gf_http_backpressure_total")),
        ("deadline_exceeded_responses", count("gf_http_deadline_exceeded_total")),
        ("model_unavailable_responses", count("gf_http_model_unavailable_total")),
        ("rate_limited_responses", count("gf_http_rate_limited_total")),
        ("retry_budget_responses", count("gf_http_retry_budget_total")),
    ]);
    // Per-tenant QoS rollup: enough to spot a misbehaving tenant from
    // this one endpoint; `/v2/tenants` has the full accounting.
    let qos_layer = system.qos();
    let tenant_blocks: Vec<Value> = qos_layer
        .tenants()
        .iter()
        .map(|t| {
            json::obj(vec![
                ("name", json::s(&t.name)),
                ("rate_rps", json::num(t.rate_rps as f64)),
                ("admitted", json::num(t.admitted as f64)),
                ("shed_rate_limited", json::num(t.shed_rate_limited as f64)),
                ("shed_retry_budget", json::num(t.shed_retry_budget as f64)),
            ])
        })
        .collect();
    let qos_block = json::obj(vec![
        ("quota_scale", json::num(finite(qos_layer.quota_scale()))),
        ("retry_shed_total", count("gf_retry_shed_total")),
        ("deadline_abandoned_total", count("gf_deadline_abandoned_total")),
        ("tenants", Value::Arr(tenant_blocks)),
    ]);
    // The coalescing/cache blocks read the *system's* own counters
    // (not the process-global registry, which other systems in the
    // same process would cross-pollute).
    let co = system.coalesce_stats();
    let answered = co.coalesced + co.executions;
    let coalesce = json::obj(vec![
        ("coalesced_total", json::num(co.coalesced as f64)),
        ("inflight", json::num(co.inflight as f64)),
        ("executions", json::num(co.executions as f64)),
        (
            "hit_rate",
            json::num(if answered == 0 { 0.0 } else { co.coalesced as f64 / answered as f64 }),
        ),
        ("joules_saved", json::num(finite(system.meter().total_joules_saved()))),
    ]);
    let cs = system.cache_stats();
    let cache = json::obj(vec![
        ("hits", json::num(cs.hits as f64)),
        ("misses", json::num(cs.misses as f64)),
        ("evictions", json::num(cs.evictions as f64)),
        ("entries", json::num(cs.len as f64)),
        ("hit_rate", json::num(finite(cs.hit_rate()))),
    ]);
    // Carbon pacer block (present only when a pacer runs): grid
    // intensity, deferral pressure, the CO₂ ledger, and total metered
    // joules so one scrape yields joules-per-answer AND CO₂-per-answer.
    let carbon = system.carbon_stats().map(|c| {
        json::obj(vec![
            ("intensity_kg_per_kwh", json::num(finite(c.intensity_kg_per_kwh))),
            ("pressure", json::num(finite(c.pressure))),
            ("co2_total_grams", json::num(finite(c.co2_grams))),
            ("co2_deferred_grams", json::num(finite(c.co2_deferred_grams))),
            ("energy_joules", json::num(finite(system.meter().total_joules()))),
        ])
    });
    let mut fields = match system.controller_stats() {
        Some(s) => vec![
            ("enabled", Value::Bool(true)),
            ("admitted", json::num(s.admitted as f64)),
            ("skipped", json::num(s.skipped as f64)),
            ("total", json::num(s.total() as f64)),
            ("admission_rate", json::num(finite(s.admission_rate()))),
            ("last_j", json::num(finite(s.last_j))),
            ("last_tau", json::num(finite(s.last_tau))),
            ("gateway", gateway),
            ("coalesce", coalesce),
            ("cache", cache),
            ("qos", qos_block),
        ],
        None => vec![
            ("enabled", Value::Bool(false)),
            ("gateway", gateway),
            ("coalesce", coalesce),
            ("cache", cache),
            ("qos", qos_block),
        ],
    };
    if let Some(c) = carbon {
        fields.push(("carbon", c));
    }
    HttpResponse::ok_json(json::obj(fields).to_json())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(path: &str) -> HttpRequest {
        HttpRequest { path: path.into(), ..HttpRequest::default() }
    }

    fn post(path: &str, body: &[u8]) -> HttpRequest {
        HttpRequest {
            method: "POST".into(),
            path: path.into(),
            body: body.to_vec(),
            ..HttpRequest::default()
        }
    }

    fn body_json(resp: &HttpResponse) -> Value {
        json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
    }

    // Endpoint-level tests over a real system (skipped without artifacts).
    #[test]
    fn dispatch_covers_v2_and_legacy_routes() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("repository.json").exists() {
            return;
        }
        let system =
            ServingSystem::start(crate::pipeline::system::SystemConfig::new(root)).unwrap();

        // legacy /health keeps its shape
        let resp = dispatch(&get("/health"), &system);
        assert_eq!(resp.status, 200);
        assert_eq!(body_json(&resp).get("status").unwrap().as_str().unwrap(), "ok");

        // v2 health
        assert_eq!(dispatch(&get("/v2/health/live"), &system).status, 200);
        let ready = dispatch(&get("/v2/health/ready"), &system);
        assert!(body_json(&ready).get("ready").unwrap() == &Value::Bool(true));

        // legacy /models is a bare array; v2 wraps it
        let legacy = dispatch(&get("/models"), &system);
        assert_eq!(body_json(&legacy).as_arr().unwrap().len(), 3);
        let v2 = dispatch(&get("/v2/models"), &system);
        assert_eq!(body_json(&v2).get("models").unwrap().as_arr().unwrap().len(), 3);

        // model metadata carries batching config + queue state
        let meta = dispatch(&get("/v2/models/distilbert_mini"), &system);
        assert_eq!(meta.status, 200);
        let v = body_json(&meta);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "distilbert_mini");
        assert!(v.get("queue").unwrap().get("capacity").unwrap().as_i64().unwrap() > 0);

        // unknown model → MODEL_NOT_FOUND
        let missing = dispatch(&get("/v2/models/nope"), &system);
        assert_eq!(missing.status, 404);
        assert_eq!(
            body_json(&missing).get("error").unwrap().get("code").unwrap().as_str().unwrap(),
            "MODEL_NOT_FOUND"
        );

        // introspection endpoints exist without a control plane
        let loops = dispatch(&get("/v2/control/loops"), &system);
        assert_eq!(loops.status, 200);
        assert_eq!(body_json(&loops).get("running").unwrap(), &Value::Bool(false));
        let adm = dispatch(&get("/v2/admission/stats"), &system);
        assert_eq!(body_json(&adm).get("enabled").unwrap(), &Value::Bool(false));

        // unknown path 404s; bad method 405s
        assert_eq!(dispatch(&get("/nope"), &system).status, 404);
        let del = HttpRequest { method: "DELETE".into(), ..get("/v2/models") };
        assert_eq!(dispatch(&del, &system).status, 405);

        // bad body 400s on both protocols
        assert_eq!(dispatch(&post("/infer", b"not json"), &system).status, 400);
        assert_eq!(
            dispatch(&post("/v2/models/distilbert_mini/infer", b"not json"), &system).status,
            400
        );

        // negative seed no longer wraps silently
        let neg = post("/infer", br#"{"model": "distilbert_mini", "seed": -5}"#);
        assert_eq!(dispatch(&neg, &system).status, 400);

        // X-Request-Id echo
        let mut req = get("/health");
        req.headers.insert("x-request-id".into(), "rid-9".into());
        let resp = dispatch(&req, &system);
        assert!(resp
            .extra_headers
            .iter()
            .any(|(k, v)| k == "X-Request-Id" && v == "rid-9"));

        // Malformed QoS headers are typed 400s, not silently ignored.
        let mut req = post("/v2/models/distilbert_mini/infer", br#"{"seed": 1}"#);
        req.headers.insert("x-request-deadline".into(), "soon".into());
        let resp = dispatch(&req, &system);
        assert_eq!(resp.status, 400);
        assert_eq!(
            body_json(&resp).get("error").unwrap().get("code").unwrap().as_str().unwrap(),
            "INVALID_ARGUMENT"
        );
        let mut req = post("/infer", br#"{"model": "distilbert_mini", "seed": 1}"#);
        req.headers.insert("x-retry-attempt".into(), "-1".into());
        assert_eq!(dispatch(&req, &system).status, 400);

        // An already-expired absolute deadline is shed before execution
        // with the avoided energy credited to the saved-joules ledger.
        let saved0 = system.meter().total_joules_saved();
        let mut req = post("/v2/models/distilbert_mini/infer", br#"{"seed": 2}"#);
        req.headers.insert("x-request-deadline".into(), "1".into());
        let resp = dispatch(&req, &system);
        assert_eq!(resp.status, 504);
        assert_eq!(
            body_json(&resp).get("error").unwrap().get("code").unwrap().as_str().unwrap(),
            "DEADLINE_EXCEEDED"
        );
        assert!(
            system.meter().total_joules_saved() > saved0,
            "pre-execution deadline drop must credit saved joules"
        );

        // A tenant header attributes the request; /v2/tenants shows it.
        let mut req = post("/v2/models/distilbert_mini/infer", br#"{"seed": 3}"#);
        req.headers.insert("x-tenant-id".into(), "acme".into());
        assert_eq!(dispatch(&req, &system).status, 200);
        let tenants = dispatch(&get("/v2/tenants"), &system);
        assert_eq!(tenants.status, 200);
        let v = body_json(&tenants);
        assert!(v.get("quota_scale").unwrap().as_f64().unwrap() > 0.0);
        let list = v.get("tenants").unwrap().as_arr().unwrap();
        let acme = list
            .iter()
            .find(|t| t.get("name").unwrap().as_str().unwrap() == "acme")
            .expect("acme tenant tracked");
        assert!(acme.get("admitted").unwrap().as_f64().unwrap() >= 1.0);
        assert!(acme.get("successes").unwrap().as_f64().unwrap() >= 1.0);

        // The admission-stats rollup carries the per-tenant blocks.
        let adm = dispatch(&get("/v2/admission/stats"), &system);
        let qos_block = body_json(&adm).get("qos").unwrap().clone();
        assert!(qos_block.get("quota_scale").unwrap().as_f64().unwrap() > 0.0);
        assert!(!qos_block.get("tenants").unwrap().as_arr().unwrap().is_empty());
    }

    // Header parsing needs no artifacts: it never touches a system.
    #[test]
    fn qos_headers_parse_and_reject() {
        let ctx = parse_qos_headers(&get("/v2/models/m/infer")).unwrap();
        assert_eq!(ctx.tenant, qos::DEFAULT_TENANT);
        assert_eq!(ctx.retry_attempt, 0);
        assert_eq!(ctx.deadline_unix_ms, None);

        let mut req = get("/v2/models/m/infer");
        req.headers.insert("x-tenant-id".into(), "acme-prod".into());
        req.headers.insert("x-retry-attempt".into(), "2".into());
        req.headers.insert("x-request-deadline".into(), "1754640000000".into());
        let ctx = parse_qos_headers(&req).unwrap();
        assert_eq!(ctx.tenant, "acme-prod");
        assert_eq!(ctx.retry_attempt, 2);
        assert_eq!(ctx.deadline_unix_ms, Some(1_754_640_000_000));

        for (name, value) in [
            ("x-tenant-id", "sp ace"),
            ("x-retry-attempt", "two"),
            ("x-request-deadline", "soon"),
        ] {
            let mut req = get("/v2/models/m/infer");
            req.headers.insert(name.into(), value.into());
            let err = parse_qos_headers(&req).unwrap_err();
            assert_eq!(err.code, ErrorCode::InvalidArgument, "{name}: {value}");
        }
    }

    #[test]
    fn deadline_conversion_clamps_expired_to_now() {
        // An epoch-millis deadline in the distant past lands exactly on
        // `now` (saturating), never before it.
        assert_eq!(deadline_to_clock(12.5, 1), 12.5);
        // A far-future deadline lands after `now`.
        assert!(deadline_to_clock(0.0, u64::MAX / 2) > 0.0);
    }
}
