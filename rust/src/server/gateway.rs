//! The inference gateway: routes HTTP requests onto the serving system.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::json::{self, Value};
use crate::pipeline::system::ServingSystem;
use crate::router::PathKind;
use crate::telemetry::MetricsRegistry;
use crate::util::Clock;
use crate::workload::stream::Request;

use super::http::{HttpRequest, HttpResponse};
use super::threadpool::ThreadPool;

/// A running HTTP gateway bound to a local port.
pub struct Gateway {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Bind `127.0.0.1:port` (port 0 = ephemeral) and serve `system` on
    /// `pool_size` connection-handler threads.
    pub fn start(
        system: Arc<ServingSystem>,
        port: u16,
        pool_size: usize,
    ) -> std::io::Result<Gateway> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();

        let acceptor = std::thread::Builder::new()
            .name("gf-gateway".to_string())
            .spawn(move || {
                let pool = ThreadPool::new(pool_size);
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let system = system.clone();
                            pool.execute(move || handle_connection(stream, &system));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn gateway");

        Ok(Gateway { addr, stop, acceptor: Some(acceptor) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(mut stream: TcpStream, system: &ServingSystem) {
    let resp = match HttpRequest::parse(&stream) {
        Ok(req) => dispatch(&req, system),
        Err(e) => HttpResponse::error(400, &e),
    };
    let _ = resp.write_to(&mut stream);
}

/// Route one parsed request.
pub fn dispatch(req: &HttpRequest, system: &ServingSystem) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => HttpResponse::ok_json(
            json::obj(vec![
                ("status", json::s("ok")),
                ("version", json::s(crate::VERSION)),
            ])
            .to_json(),
        ),
        ("GET", "/metrics") => {
            HttpResponse::ok_text(MetricsRegistry::global().render_prometheus())
        }
        ("GET", "/models") => {
            let names = system
                .repository()
                .model_names()
                .into_iter()
                .map(|n| Value::Str(n))
                .collect();
            HttpResponse::ok_json(Value::Arr(names).to_json())
        }
        ("POST", "/infer") => match infer_endpoint(req, system) {
            Ok(resp) => resp,
            Err(msg) => HttpResponse::error(400, &msg),
        },
        ("POST", _) | ("GET", _) => HttpResponse::error(404, "not found"),
        _ => HttpResponse::error(405, "method not allowed"),
    }
}

fn infer_endpoint(req: &HttpRequest, system: &ServingSystem) -> Result<HttpResponse, String> {
    let body = json::parse(req.body_str()?).map_err(|e| e.to_string())?;
    let model = body.get("model").and_then(|v| v.as_str().map(|s| s.to_string())).map_err(|e| e.to_string())?;
    let seed = body.get("seed").and_then(|v| v.as_i64()).map_err(|e| e.to_string())? as u64;
    // "auto" defers the path choice to the shared router (arrival-rate
    // window + adaptive QPS threshold).
    let path = match body.opt("path").ok().flatten().and_then(|v| v.as_str().ok()) {
        Some("batched") => Some(PathKind::Batched),
        Some("auto") => None,
        _ => Some(PathKind::Direct),
    };

    let request = Request {
        id: seed,
        model,
        arrival: system.clock().now(),
        seed,
        label: 0,
        difficulty: 0.5,
        confidence: 0.75,
    };
    let reg = MetricsRegistry::global();
    reg.counter("gf_http_infer_total").inc();

    let result = match path {
        Some(p) => system.submit(&request, p),
        None => system.submit_auto(&request),
    };
    match result {
        Ok(r) => {
            reg.gauge("gf_last_latency_secs").set(r.latency_secs);
            Ok(HttpResponse::ok_json(
                json::obj(vec![
                    ("request_id", json::num(r.request_id as f64)),
                    ("predicted", json::num(r.predicted as f64)),
                    ("confidence", json::num(r.confidence as f64)),
                    ("entropy", json::num(r.entropy as f64)),
                    ("latency_secs", json::num(r.latency_secs)),
                    ("joules", json::num(r.joules)),
                    ("path", json::s(r.path.as_str())),
                ])
                .to_json(),
            ))
        }
        Err(e) => {
            let msg = e.to_string();
            if msg.contains("backpressure") {
                Ok(HttpResponse::error(429, &msg))
            } else {
                Ok(HttpResponse::error(400, &msg))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Endpoint-level tests that don't need a serving system.
    #[test]
    fn health_without_system_state() {
        // dispatch needs a system only for /infer and /models; check the
        // response shape through a fake request on /health by constructing
        // a minimal system when artifacts exist, else skip.
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("repository.json").exists() {
            return;
        }
        let system =
            ServingSystem::start(crate::pipeline::system::SystemConfig::new(root)).unwrap();
        let req = HttpRequest {
            method: "GET".into(),
            path: "/health".into(),
            headers: Default::default(),
            body: vec![],
        };
        let resp = dispatch(&req, &system);
        assert_eq!(resp.status, 200);
        let v = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");

        // /models lists the repository
        let req = HttpRequest { path: "/models".into(), ..req };
        let resp = dispatch(&req, &system);
        let v = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 3);

        // unknown path 404s
        let req = HttpRequest { path: "/nope".into(), ..req };
        assert_eq!(dispatch(&req, &system).status, 404);

        // bad body 400s
        let req = HttpRequest {
            method: "POST".into(),
            path: "/infer".into(),
            headers: Default::default(),
            body: b"not json".to_vec(),
        };
        assert_eq!(dispatch(&req, &system).status, 400);
    }
}
